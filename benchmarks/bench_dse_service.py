"""DSE-service throughput — the requests/s row of the perf trajectory.

Drains N heterogeneous search requests (mixed workload subsets x
objective kinds x seeds on the ``table`` backend — ``serve.dse.
paper_request_mix``) through the continuous-batching ``DSEService`` and
records:

  * cold_s / warm_s        — first drain (trace + XLA compile of the
                             seeding + GA programs) vs best-of-N cached
                             drains (the steady-state service number),
  * requests_per_s         — warm END-TO-END requests/s (submit through
                             drain wall time; each request = a full
                             P x (G+1) GA search),
  * busy_requests_per_s    — the busy-only figure (wall time inside
                             ``engine.execute``; what ``ServiceStats.
                             requests_per_s`` reports),
  * wait/latency p50/p99   — per-request queue-wait and submit-to-result
                             latency percentiles of the recorded warm
                             drain (``ServiceStats`` samples),
  * designs_per_s          — the e2e figure in designs evaluated/s,
  * launches / programs    — XLA launches in one drain, and how many NEW
                             seeding/GA programs the drain compiled (the
                             acceptance bound is <= 4; steady state is 0),
  * transfer               — host-transfer bytes and launch count of one
                             warm drain under BOTH engine modes
                             (``pipelined=True`` thin epilogue vs the
                             sequential history-syncing default), plus
                             their bytes-per-launch reduction ratio.

``--smoke`` is the CI serve-smoke leg: ~32 mixed requests at a tiny
operating point, asserting every result arrives with a finite best
score — plus an EDF leg (deadline-ordered launches on the sync service)
and an async leg (mixed-priority ``AsyncDSEService`` drain, futures all
finite).  ``--fault-smoke`` is the CI fault-tolerance leg: every chunk
launch over the REAL engine fails once with a transient ``EngineFault``
and the retry lane must recover every request to a full finite result
(see ``fault_smoke``).  ``--cache-smoke`` is the CI cache leg: a
cache-armed service drains the paper mix, then the IDENTICAL mix is
resubmitted — sync and async — and every request must resolve from the
result cache with ZERO new GA launches and bit-identical results (see
``cache_smoke``).  ``python -m benchmarks.bench_dse_service`` appends
the ``service`` row of ``experiments/search_throughput.json`` and
``--cache`` the ``cache`` row (cold populate vs hot all-hits drain —
the request-overlap throughput ceiling; see benchmarks/README.md for
the methodology).
"""
from __future__ import annotations

import sys
import time

PAPER_S_PER_DESIGN = 36.0
POP, GENS = 40, 10


def _fmt(v, spec: str = ".2f") -> str:
    """Format a possibly-``None`` percentile (empty sample window)."""
    return "n/a" if v is None else f"{v:{spec}}"


def _program_cache_sizes() -> int:
    """Compiled-program count of the two jits a drain launches (seeding +
    batched GA) — the 'programs' the acceptance criterion bounds."""
    from repro.core import engine, ga

    return ga._run_ga_batched_jit._cache_size() + engine._seed_batched_jit._cache_size()


def run(quick: bool = False, verbose: bool = True, mesh=None,
        backend: str = "table", n_requests: int = None) -> dict:
    from repro.serve.dse import DSEService, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    n = n_requests or (64 if quick else 256)
    warm_reps = 2 if quick else 3
    per_search = POP * (GENS + 1)

    def drain(seed0: int, pipelined: bool = False) -> "DSEService":
        svc = DSEService(mesh=mesh, pipelined=pipelined)
        svc.submit_all(paper_request_mix(
            ws, n, backend=backend, pop_size=POP, generations=GENS,
            seed0=seed0,
        ))
        res = svc.drain()
        assert len(res) == n
        return svc

    p0 = _program_cache_sizes()
    t0 = time.time()
    svc = drain(0)
    cold = time.time() - t0
    programs = _program_cache_sizes() - p0
    warm = float("inf")
    for rep in range(warm_reps):
        t0 = time.time()
        svc = drain(1000 * (rep + 1))
        warm = min(warm, time.time() - t0)
    st = svc.stats  # per-request telemetry of the last warm drain
    out = {
        "requests": n, "pop": POP, "gens": GENS, "backend": backend,
        "slots": svc.engine.max_slots, "launches": svc.stats.launches,
        "programs_compiled_cold": programs,
        "warm_reps": warm_reps,
        "cold_s": cold,  # includes trace + XLA compile
        "warm_s": warm,  # cached programs: the steady-state number
        "requests_per_s": n / warm,  # end-to-end: submit through drain
        "busy_requests_per_s": st.requests_per_s(),  # execute() wall only
        "wait_p50_s": st.wait_p(50), "wait_p99_s": st.wait_p(99),
        "latency_p50_s": st.latency_p(50), "latency_p99_s": st.latency_p(99),
        "designs_per_s": n * per_search / warm,
        "speedup_vs_paper": (n * per_search / warm) * PAPER_S_PER_DESIGN,
        "paper_s_per_design": PAPER_S_PER_DESIGN,
    }
    # host-transfer footprint of one warm drain under BOTH engine modes:
    # pipelined (thin on-device top-k epilogue + overlapped dispatch/
    # harvest) vs the sequential history-syncing default
    out["transfer"] = {}
    for pipelined in (False, True):
        t0 = time.time()
        svc_x = drain(7777, pipelined=pipelined)
        dt = time.time() - t0
        eng = svc_x.engine
        mode = "pipelined" if pipelined else "sequential"
        out["transfer"][mode] = {
            "warm_s": dt,
            "launches": int(eng.launches),
            "transfer_bytes": int(eng.transfer_bytes),
            "transfer_bytes_per_launch":
                eng.transfer_bytes / max(1, eng.launches),
            "dispatch_gap_p50_s": svc_x.stats.dispatch_gap_p(50),
            "device_idle_s": svc_x.stats.device_idle_s,
        }
    seq_b = out["transfer"]["sequential"]["transfer_bytes_per_launch"]
    pip_b = out["transfer"]["pipelined"]["transfer_bytes_per_launch"]
    out["transfer"]["reduction_x"] = seq_b / max(1.0, pip_b)
    if verbose:
        print(f"[dse-service] {n} mixed requests: cold {cold:.2f}s "
              f"({programs} programs), warm {warm:.2f}s -> "
              f"{n/warm:.1f} req/s e2e ({st.requests_per_s():.1f} busy), "
              f"{n*per_search/warm:.0f} designs/s, latency p50/p99 "
              f"{_fmt(st.latency_p(50))}/{_fmt(st.latency_p(99))}s "
              f"({svc.stats.launches} launches/drain)")
        print(f"[dse-service] transfer/launch: sequential {seq_b:.0f} B, "
              f"pipelined {pip_b:.0f} B "
              f"({out['transfer']['reduction_x']:.1f}x thinner, "
              f"{out['transfer']['pipelined']['launches']} launches)")
    return out


def _assert_all_finite(rids, results):
    missing = [r for r in rids if r not in results]
    assert not missing, f"requests never completed: {missing}"
    import numpy as np

    bad = [
        r for r in rids
        if not (len(results[r].top_scores)
                and np.isfinite(results[r].top_scores[0]))
    ]
    assert not bad, f"requests with no finite best score: {bad}"


def smoke(n: int = 32) -> int:
    """CI serve-smoke, three legs:

    1. sync fifo  — n mixed requests drained, every result present with
       a finite best score (the original smoke),
    2. sync EDF   — the same mix with cycling deadlines at 8 slots:
       launch order must be exactly earliest-absolute-deadline-first
       (deadline-less requests last), still all finite,
    3. async priority — the mixed-PRIORITY mix through AsyncDSEService
       (paused admission -> one deterministic plan), futures all finite
       and per-request telemetry recorded.
    """
    import numpy as np

    from repro.serve.dse import AsyncDSEService, DSEService, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(nm, cnn_workload(nm)) for nm in PAPER_WORKLOADS])
    svc = DSEService()
    # the paper's P=40 population: seeded designs all fit their largest
    # workload, and at P=40 every request reliably finds a feasible
    # (area-satisfying) design within a few generations
    rids = svc.submit_all(paper_request_mix(
        ws, n, backend="table", pop_size=40, generations=6,
    ))
    results = svc.drain()
    _assert_all_finite(rids, results)
    print(f"[dse-service] smoke: {n}/{n} mixed requests drained, "
          f"all finite ({svc.stats.launches} launches)")

    # --- EDF leg: cycling deadlines, 8-slot chunks -> >=4 launches whose
    # dispatch order must be non-decreasing in absolute deadline
    deadlines = [5.0, 60.0, 30.0, None]
    edf = DSEService(policy="edf", max_slots=8)
    edf_reqs = paper_request_mix(ws, n, backend="table", pop_size=40,
                                 generations=6, deadlines_s=deadlines)
    edf_rids = edf.submit_all(edf_reqs)
    edf_results = edf.drain()
    _assert_all_finite(edf_rids, edf_results)
    by_rid = dict(zip(edf_rids, edf_reqs))
    order = [
        np.inf if by_rid[rid].deadline_s is None else by_rid[rid].deadline_s
        for launch in edf.launch_log for rid in launch
    ]
    assert order == sorted(order), f"EDF launch order violated: {order}"
    print(f"[dse-service] smoke: EDF leg ordered {len(edf.launch_log)} "
          f"launches by deadline, all finite")

    # --- async leg: mixed priorities through the threaded front end;
    # paused admission keeps it at the sync leg's one 64-slot program
    with AsyncDSEService(policy="priority", paused=True) as async_svc:
        futs = async_svc.submit_all(paper_request_mix(
            ws, n, backend="table", pop_size=40, generations=6,
            priorities=[3, 0, 1, 2],
        ))
        async_svc.resume()
        async_res = [f.result(timeout=600) for f in futs]
    assert all(
        len(r.top_scores) and np.isfinite(r.top_scores[0]) for r in async_res
    ), "async leg returned a non-finite best score"
    st = async_svc.stats
    assert len(st.latency_samples) == n and len(st.wait_samples) == n
    print(f"[dse-service] smoke: async priority leg {n}/{n} futures "
          f"finite (latency p99 {_fmt(st.latency_p(99))}s)")
    return 0


def _assert_bit_equal(a, b, ctx: str = "") -> None:
    """Two SearchResults must match bit-for-bit (the cache-hit contract:
    a cached answer is THE answer, not an approximation of it).  Thin
    full results (pipelined engines: ``ga is None``) compare on the thin
    fields; both sides must agree on thinness."""
    import numpy as np

    assert a.objective == b.objective and a.workload_names == b.workload_names
    assert a.valid == b.valid and a.partial == b.partial
    assert a.top_designs == b.top_designs, ctx
    for name in ("top_scores", "top_genomes", "convergence"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{ctx}: {name} differs")
    assert (a.ga is None) == (b.ga is None), f"{ctx}: thinness differs"
    if a.ga is None:
        return
    for name in ("genomes", "scores", "best_genome", "best_score"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.ga, name)), np.asarray(getattr(b.ga, name)),
            err_msg=f"{ctx}: ga.{name} differs")


def cache_smoke(n: int = 32) -> int:
    """CI cache-smoke: the zero-launch hot-repeat contract, end to end.

    A cache-armed sync service drains the paper mix cold, then the
    IDENTICAL mix is resubmitted — every request must resolve at submit
    (``stats.cache_hits == n``) with ZERO new GA launches and results
    bit-identical to the cold drain.  An ``AsyncDSEService`` sharing the
    same cache then repeats the mix a third time: all futures arrive
    already resolved, its service never launches at all.
    """
    from repro.core.engine import SearchEngine
    from repro.serve.cache import ResultCache
    from repro.serve.dse import AsyncDSEService, DSEService, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(nm, cnn_workload(nm)) for nm in PAPER_WORKLOADS])
    mix = lambda: paper_request_mix(  # noqa: E731 — the one mix, four times
        ws, n, backend="table", pop_size=40, generations=6)
    cache = ResultCache()
    svc = DSEService(result_cache=cache)
    rids = svc.submit_all(mix())
    cold = dict(svc.drain())
    _assert_all_finite(rids, cold)
    launches = svc.stats.launches
    assert svc.stats.cache_hits == 0 and len(cache) == n

    rids2 = svc.submit_all(mix())
    hot = svc.drain()
    assert svc.stats.launches == launches, \
        f"hot resubmit launched GA work ({svc.stats.launches - launches})"
    assert svc.stats.cache_hits == n, svc.stats.cache_hits
    for r1, r2 in zip(rids, rids2):
        _assert_bit_equal(cold[r1], hot[r2], f"sync rid {r1}->{r2}")
    print(f"[dse-service] cache-smoke: sync hot resubmit {n}/{n} hits, "
          f"0 new launches, bit-identical ({cache.stats.summary()})")

    with AsyncDSEService(result_cache=cache) as async_svc:
        futs = async_svc.submit_all(mix())
        async_res = [f.result(timeout=600) for f in futs]
    assert async_svc.stats.launches == 0, async_svc.stats.launches
    assert async_svc.stats.cache_hits == n
    for r1, res in zip(rids, async_res):
        _assert_bit_equal(cold[r1], res, f"async rid {r1}")
    print(f"[dse-service] cache-smoke: async resubmit {n}/{n} futures "
          f"pre-resolved, 0 launches, bit-identical")

    # --- pipelined leg: THE ISSUE-10 regression.  Pipelined engines
    # return thin full results (ga=None); the cache used to refuse them,
    # so a pipelined service re-ran every resubmitted GA.  Now the same
    # contract holds as above: zero new launches, bit-identical, hot.
    pcache = ResultCache()
    peng = SearchEngine(pipelined=True)
    psvc = DSEService(engine=peng, result_cache=pcache)
    prids = psvc.submit_all(mix())
    pcold = dict(psvc.drain())
    _assert_all_finite(prids, pcold)
    assert all(pcold[r].ga is None for r in prids), \
        "pipelined drain returned non-thin results"
    assert len(pcache) == n, f"thin results not cached ({len(pcache)}/{n})"
    launches_p = peng.launches
    prids2 = psvc.submit_all(mix())
    phot = dict(psvc.drain())
    assert peng.launches == launches_p, \
        f"pipelined hot resubmit launched GA work ({peng.launches - launches_p})"
    assert psvc.stats.cache_hits == n, psvc.stats.cache_hits
    assert pcache.stats.hit_rate() > 0
    for r1, r2 in zip(prids, prids2):
        _assert_bit_equal(pcold[r1], phot[r2], f"pipelined rid {r1}->{r2}")
    print(f"[dse-service] cache-smoke: pipelined thin-result resubmit "
          f"{n}/{n} hits, 0 new launches, bit-identical "
          f"({pcache.stats.summary()})")
    return 0


def cache_run(quick: bool = False, verbose: bool = True) -> dict:
    """The ``cache`` row: cold populate vs hot all-hits drain.

    Same mix and operating point as the ``service`` row, through a
    cache-armed service: the cold drain runs every GA search and fills
    the cache, then ``warm_reps`` hot drains resubmit the identical mix
    — all hits, zero launches — and the best one is the row's hot
    number.  The hot/cold ratio is the throughput ceiling request
    overlap buys (a real stream sits in between, set by its hit rate).
    """
    from repro.core.engine import SearchEngine
    from repro.serve.cache import ResultCache
    from repro.serve.dse import DSEService, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(nm, cnn_workload(nm)) for nm in PAPER_WORKLOADS])
    n = 64 if quick else 256
    warm_reps = 2 if quick else 3
    per_search = POP * (GENS + 1)
    cache = ResultCache(capacity=2 * n)
    svc = DSEService(result_cache=cache)
    mix = paper_request_mix(ws, n, backend="table", pop_size=POP,
                            generations=GENS)

    t0 = time.time()
    svc.submit_all(mix)
    svc.drain()
    cold = time.time() - t0
    launches_cold = svc.stats.launches

    hot = float("inf")
    for _ in range(warm_reps):
        t0 = time.time()
        rids = svc.submit_all(mix)
        res = svc.drain()
        hot = min(hot, time.time() - t0)
        assert all(r in res for r in rids)
    assert svc.stats.launches == launches_cold, "hot drains launched GA work"
    assert svc.stats.cache_hits == warm_reps * n

    # --- pipelined-resubmit measurement (the ISSUE-10 thin-result caching
    # fix, recorded so tools/check_fused_gate.py --cache can gate it):
    # a PIPELINED engine's thin full results must populate the cache, so
    # an identical resubmit drains with zero new GA launches
    n_pipe = 32
    pcache = ResultCache(capacity=2 * n_pipe)
    peng = SearchEngine(pipelined=True)
    psvc = DSEService(engine=peng, result_cache=pcache)
    pmix = paper_request_mix(ws, n_pipe, backend="table", pop_size=POP,
                             generations=GENS, seed0=50_000)
    psvc.submit_all(pmix)
    psvc.drain()
    launches_pipe_cold = peng.launches
    psvc.submit_all(pmix)
    psvc.drain()
    pipe_resubmit_launches = peng.launches - launches_pipe_cold

    out = {
        "requests": n, "pop": POP, "gens": GENS, "backend": "table",
        "warm_reps": warm_reps,
        "cold_s": cold,  # populate: every search launched
        "hot_s": hot,  # all hits: zero launches
        "cold_requests_per_s": n / cold,
        "hot_requests_per_s": n / hot,
        "hot_designs_per_s": n * per_search / hot,
        "hot_vs_cold_speedup": cold / hot,
        "launches_cold": launches_cold,
        "launches_hot": 0,
        "cache": cache.stats.summary(),
        "pipelined_resubmit": {
            "requests": n_pipe,
            "new_launches": int(pipe_resubmit_launches),
            "cache_hits": int(psvc.stats.cache_hits),
            "hit_rate": pcache.stats.hit_rate(),
        },
    }
    if verbose:
        print(f"[dse-service] cache: {n} mixed requests cold {cold:.2f}s "
              f"({launches_cold} launches) -> hot {hot:.3f}s all-hits "
              f"({n/hot:.0f} req/s, {cold/hot:.0f}x, 0 launches)")
        print(f"[dse-service] cache: pipelined resubmit x{n_pipe}: "
              f"{pipe_resubmit_launches} new launches, "
              f"hit rate {pcache.stats.hit_rate():.2f}")
    return out


def fault_smoke(n: int = 16) -> int:
    """CI fault-smoke: the retry lane over the REAL engine.

    A wrapper engine fails every CHUNK launch (plans carrying more than
    one request) the first time it sees that rid set — a transient
    per-chunk ``EngineFault`` — so the service's retry lane must re-plan
    each member in isolation and recover ALL of them to full
    (non-partial) finite results: failures == n, retries == n,
    partials == abandoned == 0.
    """
    from repro.core.engine import EngineFault, SearchEngine
    from repro.serve.dse import DSEService, RetryPolicy, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    class ChunkLaunchFails:
        """Fails the first launch of every distinct multi-request seed
        set; isolated (single-request) retries go through — a transient
        per-chunk fault."""

        def __init__(self, inner):
            self.inner = inner
            self.max_slots = inner.max_slots
            self.seen = set()
            self.injected = 0

        def execute(self, plan, *, mesh=None):
            key = tuple(sorted(r.seed for r in plan.requests))
            if len(key) > 1 and key not in self.seen:
                self.seen.add(key)
                self.injected += 1
                raise EngineFault(f"injected transient fault for {key}")
            return self.inner.execute(plan, mesh=mesh)

    ws = pack_workloads([(nm, cnn_workload(nm)) for nm in PAPER_WORKLOADS])
    eng = ChunkLaunchFails(SearchEngine(max_slots=8))
    svc = DSEService(engine=eng,
                     retry=RetryPolicy(max_attempts=2, backoff_s=0.0),
                     partial_results=True)
    rids = svc.submit_all(paper_request_mix(
        ws, n, backend="table", pop_size=40, generations=6,
    ))
    results = svc.drain()
    _assert_all_finite(rids, results)
    assert not any(results[r].partial for r in rids), \
        "retried request resolved partial instead of recovering fully"
    st = svc.stats
    assert st.retries == n, f"expected {n} retries, got {st.retries}"
    assert st.failures == n, f"expected {n} failures, got {st.failures}"
    assert st.partials == 0 and st.abandoned == 0, (st.partials, st.abandoned)
    print(f"[dse-service] fault-smoke: {n}/{n} requests recovered through "
          f"the retry lane ({st.failures} request failures over "
          f"{eng.injected} faulted chunks, {st.retries} isolated retries, "
          f"0 partials) -- {st.summary()}")
    return 0


def main(argv=None) -> int:
    import argparse

    from benchmarks.run import prepare_search_mesh, write_search_throughput

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="64 requests instead of 256")
    ap.add_argument("--smoke", action="store_true",
                    help="CI serve-smoke: drain ~32 tiny mixed requests, "
                         "assert all present + finite; records nothing")
    ap.add_argument("--fault-smoke", action="store_true",
                    help="CI fault-smoke: every chunk launch fails once "
                         "over the REAL engine; the retry lane must "
                         "recover all requests fully; records nothing")
    ap.add_argument("--cache-smoke", action="store_true",
                    help="CI cache-smoke: resubmit an identical mix "
                         "through a cache-armed service (sync + async); "
                         "zero new launches, bit-identical results; "
                         "records nothing")
    ap.add_argument("--cache", action="store_true",
                    help="record the 'cache' row: cold populate vs hot "
                         "all-hits drain of the same mix")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument(
        "--mesh", nargs="?", const="auto", default=None, metavar="SEARCHxPOP",
        help="shard the service's launches over a (search, population) mesh "
             "(layout proof on fake devices; row not recorded)",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(args.requests or 32)
    if args.fault_smoke:
        return fault_smoke(args.requests or 16)
    if args.cache_smoke:
        return cache_smoke(args.requests or 32)
    if args.cache:
        write_search_throughput(cache_run(quick=args.quick), row="cache")
        return 0
    mesh = prepare_search_mesh(args.mesh) if args.mesh else None
    res = run(quick=args.quick, mesh=mesh, n_requests=args.requests)
    if mesh is not None:
        print("[dse-service] mesh run not recorded (fake-device layout "
              "proof; the tracked service row is the single-host number)")
        return 0
    write_search_throughput(res, row="service")
    return 0


if __name__ == "__main__":
    sys.exit(main())
