"""DSE-service throughput — the requests/s row of the perf trajectory.

Drains N heterogeneous search requests (mixed workload subsets x
objective kinds x seeds on the ``table`` backend — ``serve.dse.
paper_request_mix``) through the continuous-batching ``DSEService`` and
records:

  * cold_s / warm_s        — first drain (trace + XLA compile of the
                             seeding + GA programs) vs best-of-N cached
                             drains (the steady-state service number),
  * requests_per_s         — warm requests/s (each request = a full
                             P x (G+1) GA search),
  * designs_per_s          — the same in designs evaluated/s,
  * launches / programs    — XLA launches in one drain, and how many NEW
                             seeding/GA programs the drain compiled (the
                             acceptance bound is <= 4; steady state is 0).

``--smoke`` is the CI serve-smoke leg: ~32 mixed requests at a tiny
operating point, asserting every result arrives with a finite best score.
``python -m benchmarks.bench_dse_service`` appends the ``service`` row of
``experiments/search_throughput.json`` (see benchmarks/README.md for the
methodology).
"""
from __future__ import annotations

import sys
import time

PAPER_S_PER_DESIGN = 36.0
POP, GENS = 40, 10


def _program_cache_sizes() -> int:
    """Compiled-program count of the two jits a drain launches (seeding +
    batched GA) — the 'programs' the acceptance criterion bounds."""
    from repro.core import engine, ga

    return ga._run_ga_batched_jit._cache_size() + engine._seed_batched_jit._cache_size()


def run(quick: bool = False, verbose: bool = True, mesh=None,
        backend: str = "table", n_requests: int = None) -> dict:
    from repro.serve.dse import DSEService, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    n = n_requests or (64 if quick else 256)
    warm_reps = 2 if quick else 3
    per_search = POP * (GENS + 1)

    def drain(seed0: int) -> "DSEService":
        svc = DSEService(mesh=mesh)
        svc.submit_all(paper_request_mix(
            ws, n, backend=backend, pop_size=POP, generations=GENS,
            seed0=seed0,
        ))
        res = svc.drain()
        assert len(res) == n
        return svc

    p0 = _program_cache_sizes()
    t0 = time.time()
    svc = drain(0)
    cold = time.time() - t0
    programs = _program_cache_sizes() - p0
    warm = float("inf")
    for rep in range(warm_reps):
        t0 = time.time()
        svc = drain(1000 * (rep + 1))
        warm = min(warm, time.time() - t0)
    out = {
        "requests": n, "pop": POP, "gens": GENS, "backend": backend,
        "slots": svc.engine.max_slots, "launches": svc.stats.launches,
        "programs_compiled_cold": programs,
        "warm_reps": warm_reps,
        "cold_s": cold,  # includes trace + XLA compile
        "warm_s": warm,  # cached programs: the steady-state number
        "requests_per_s": n / warm,
        "designs_per_s": n * per_search / warm,
        "speedup_vs_paper": (n * per_search / warm) * PAPER_S_PER_DESIGN,
        "paper_s_per_design": PAPER_S_PER_DESIGN,
    }
    if verbose:
        print(f"[dse-service] {n} mixed requests: cold {cold:.2f}s "
              f"({programs} programs), warm {warm:.2f}s -> "
              f"{n/warm:.1f} req/s, {n*per_search/warm:.0f} designs/s "
              f"({svc.stats.launches} launches/drain)")
    return out


def smoke(n: int = 32) -> int:
    """CI serve-smoke: submit n mixed requests at a tiny operating point,
    drain, assert every result is present with a finite best score."""
    import numpy as np

    from repro.serve.dse import DSEService, paper_request_mix
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(nm, cnn_workload(nm)) for nm in PAPER_WORKLOADS])
    svc = DSEService()
    # the paper's P=40 population: seeded designs all fit their largest
    # workload, and at P=40 every request reliably finds a feasible
    # (area-satisfying) design within a few generations
    rids = svc.submit_all(paper_request_mix(
        ws, n, backend="table", pop_size=40, generations=6,
    ))
    results = svc.drain()
    missing = [r for r in rids if r not in results]
    assert not missing, f"requests never completed: {missing}"
    bad = [
        r for r in rids
        if not (len(results[r].top_scores)
                and np.isfinite(results[r].top_scores[0]))
    ]
    assert not bad, f"requests with no finite best score: {bad}"
    print(f"[dse-service] smoke: {n}/{n} mixed requests drained, "
          f"all finite ({svc.stats.launches} launches)")
    return 0


def main(argv=None) -> int:
    import argparse

    from benchmarks.run import prepare_search_mesh, write_search_throughput

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="64 requests instead of 256")
    ap.add_argument("--smoke", action="store_true",
                    help="CI serve-smoke: drain ~32 tiny mixed requests, "
                         "assert all present + finite; records nothing")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument(
        "--mesh", nargs="?", const="auto", default=None, metavar="SEARCHxPOP",
        help="shard the service's launches over a (search, population) mesh "
             "(layout proof on fake devices; row not recorded)",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(args.requests or 32)
    mesh = prepare_search_mesh(args.mesh) if args.mesh else None
    res = run(quick=args.quick, mesh=mesh, n_requests=args.requests)
    if mesh is not None:
        print("[dse-service] mesh run not recorded (fake-device layout "
              "proof; the tracked service row is the single-host number)")
        return 0
    write_search_throughput(res, row="service")
    return 0


if __name__ == "__main__":
    sys.exit(main())
