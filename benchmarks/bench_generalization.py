"""Paper Fig. 3: generalization score-loss across objectives — batched.

For each objective in {ela, edp, e, l}: joint search + per-workload
separate searches from the SAME seeded initial population; normalize
scores to the joint best; report the % score loss of the generalized
design vs each workload-specific design, and the joint convergence curve.

The exponent-weighted objective (E^wE * L^wL * A^wA with traced weights,
``core.objectives.make_weighted_objective``) makes the objective a traced
INPUT rather than four traced programs — the whole figure is TWO batched
XLA launches: one for the 4 joint searches (batch = objectives) and one
for the 16 separate searches (batch = objectives x workloads).
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objectives import OBJECTIVES, OBJECTIVE_WEIGHTS
from repro.core.search import batched_search, seed_population
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS, TOPK = 40, 10, 10
AREA = 150.0


def run(seed: int = 0, verbose: bool = True) -> dict:
    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    W, n_obj = ws.n, len(OBJECTIVES)
    key = jax.random.PRNGKey(seed)
    init = seed_population(key, ws, POP)  # same initial architectures for all
    weights = jnp.asarray([OBJECTIVE_WEIGHTS[o] for o in OBJECTIVES], jnp.float32)
    ga_key = jax.random.PRNGKey(seed + 7)

    t0 = time.time()
    # joint: batch = objectives (every element same key + init, as in the
    # sequential protocol — only the objective weights differ)
    joints = batched_search(
        jnp.tile(ga_key[None], (n_obj, 1)),
        jnp.broadcast_to(ws.feats[None], (n_obj,) + ws.feats.shape),
        jnp.broadcast_to(ws.mask[None], (n_obj,) + ws.mask.shape),
        names=ws.names,
        obj_weights=weights,
        area_constr=AREA,
        pop_size=POP,
        generations=GENS,
        top_k=TOPK,
        init_genomes=jnp.tile(init[None], (n_obj, 1, 1)),
    )
    # separate: batch = objectives x workloads (objective-major)
    seps = batched_search(
        jnp.tile(ga_key[None], (n_obj * W, 1)),
        jnp.tile(ws.feats[:, None], (n_obj, 1, 1, 1)),
        jnp.tile(ws.mask[:, None], (n_obj, 1, 1)),
        names=[(n,) for n in ws.names] * n_obj,
        obj_weights=jnp.repeat(weights, W, axis=0),
        area_constr=AREA,
        pop_size=POP,
        generations=GENS,
        top_k=TOPK,
        init_genomes=jnp.tile(init[None], (n_obj * W, 1, 1)),
    )
    wall = time.time() - t0

    from benchmarks.bench_joint_vs_separate import per_workload_scores

    out = {}
    for oi, obj in enumerate(OBJECTIVES):
        joint = joints[oi]
        jbest = float(joint.top_scores[0]) if len(joint.top_scores) else float("inf")
        losses: Dict[str, float] = {}
        for i, name in enumerate(ws.names):
            sep = seps[oi * W + i]
            if len(sep.top_scores):
                # loss of generality: how much worse the generalized chip is
                # on THIS workload than its workload-specific optimum.
                joint_on_w = per_workload_scores(
                    joint.top_genomes[0], ws, AREA, objective=obj
                )[name] if len(joint.top_genomes) else float("inf")
                losses[name] = 1.0 - float(sep.top_scores[0]) / joint_on_w \
                    if np.isfinite(joint_on_w) else float("nan")
        out[obj] = {
            "joint_best": jbest,
            "joint_top10_norm": [float(s) / jbest for s in joint.top_scores],
            "convergence": [float(c) for c in joint.convergence],
            "generalization_loss": losses,
            "wall_s": wall / n_obj,
        }
        if verbose:
            print(f"[fig3 {obj:4s}] joint best {jbest:.3g}; loss vs specific: "
                  f"{ {k: f'{v:.0%}' for k, v in losses.items()} }")
    if verbose:
        print(f"[fig3] total wall {wall:.1f}s for {n_obj * (1 + W)} searches "
              f"in 2 XLA programs")
    return out


if __name__ == "__main__":
    from benchmarks.run import exp_dir

    res = run()
    with open(exp_dir() / "fig3_generalization.json", "w") as f:
        json.dump(res, f, indent=1)
