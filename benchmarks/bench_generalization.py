"""Paper Fig. 3: generalization score-loss across objectives.

For each objective in {ela, edp, e, l}: joint search + per-workload
separate searches from the SAME seeded initial population; normalize
scores to the joint best; report the % score loss of the generalized
design vs each workload-specific design, and the joint convergence curve.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import numpy as np

from repro.core.objectives import OBJECTIVES
from repro.core.search import run_search, seed_population
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS, TOPK = 40, 10, 10
AREA = 150.0


def run(seed: int = 0, verbose: bool = True) -> dict:
    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    key = jax.random.PRNGKey(seed)
    init = seed_population(key, ws, POP)  # same initial architectures for all
    out = {}

    for obj in OBJECTIVES:
        t0 = time.time()
        joint = run_search(
            jax.random.PRNGKey(seed + 7), ws,
            objective=obj, area_constr=AREA,
            pop_size=POP, generations=GENS, top_k=TOPK,
            init_genomes=init,
        )
        jbest = float(joint.top_scores[0]) if len(joint.top_scores) else float("inf")
        losses: Dict[str, float] = {}
        for i, name in enumerate(ws.names):
            sep = run_search(
                jax.random.PRNGKey(seed + 7), ws.subset([i]),
                objective=obj, area_constr=AREA,
                pop_size=POP, generations=GENS, top_k=TOPK,
                init_genomes=init,
            )
            if len(sep.top_scores):
                # loss of generality: how much worse the generalized chip is
                # on THIS workload than its workload-specific optimum.
                from benchmarks.bench_joint_vs_separate import per_workload_scores

                joint_on_w = per_workload_scores(joint.top_genomes[0], ws, AREA)[name]
                losses[name] = 1.0 - float(sep.top_scores[0]) / joint_on_w \
                    if np.isfinite(joint_on_w) else float("nan")
        out[obj] = {
            "joint_best": jbest,
            "joint_top10_norm": [float(s) / jbest for s in joint.top_scores],
            "convergence": [float(c) for c in joint.convergence],
            "generalization_loss": losses,
            "wall_s": time.time() - t0,
        }
        if verbose:
            print(f"[fig3 {obj:4s}] joint best {jbest:.3g}; loss vs specific: "
                  f"{ {k: f'{v:.0%}' for k, v in losses.items()} }")
    return out


if __name__ == "__main__":
    res = run()
    with open("experiments/fig3_generalization.json", "w") as f:
        json.dump(res, f, indent=1)
