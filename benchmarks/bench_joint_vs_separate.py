"""Paper Fig. 2: joint vs separate search — batched one-jit drivers.

Per seed (5 random initial populations):
  * joint search top-10 scores,
  * separate per-workload searches re-scored on ALL workloads (fair
    comparison) + % of their top designs that FAIL other workloads,
  * the optimize-for-largest-workload (VGG16) baseline vs joint, per
    workload (the paper's 36/36/20/69 % improvements).

All S joint searches run as ONE vmapped XLA program
(``joint_search_batched``), and all S x W separate searches as another
(``batched_search``) — two launches for the whole figure instead of
S * (1 + W) sequentially retraced GAs (~10x end-to-end on this container).

``--mesh [SEARCHxPOP]`` lays both programs out over a 2-D (search,
population) device mesh (fake 8-device host on CPU) — same scores, the
whole figure sharded over the fleet.  ``--backend table`` runs both
programs through the factorized grid-table cost model (same top designs,
layer-depth-independent eval).
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict

# repro modules build device arrays at import; keep them lazy so main()
# can inject xla_force_host_platform_device_count first (see --mesh).
import jax
import jax.numpy as jnp
import numpy as np

POP, GENS, TOPK = 40, 10, 10
AREA = 150.0


def per_workload_scores(
    genome: np.ndarray, ws, area=AREA, objective: str = "ela"
) -> Dict[str, float]:
    """Score of ONE design on each single workload (one evaluation)."""
    from repro.core import space
    from repro.core.objectives import OBJECTIVE_WEIGHTS
    from repro.imc.cost import evaluate_designs

    d = space.decode(jnp.asarray(genome[None, :]))
    r = evaluate_designs(d, ws)
    e = np.asarray(r.energy_pj[0])  # per-workload columns are independent,
    l = np.asarray(r.latency_ns[0])  # so one full-set eval == W subset evals
    a = float(r.area_mm2[0])
    we, wl, wa = OBJECTIVE_WEIGHTS[objective]
    out = {}
    for i, name in enumerate(ws.names):
        feasible = bool(r.fits[0, i]) and bool(r.valid[0]) and a <= area
        s = float(e[i]) ** we * float(l[i]) ** wl * a ** wa
        out[name] = s if feasible else float("inf")
    return out


def run(seeds: int = 5, verbose: bool = True, mesh=None,
        backend: str = "jnp", fast: bool = False) -> dict:
    from repro.core.search import batched_search, joint_search_batched
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    W = ws.n
    largest = "vgg16"
    results = {"seeds": [], "pop": POP, "gens": GENS, "backend": backend,
               "fast": bool(fast)}
    if mesh is not None:
        from repro.launch.mesh import describe

        results["mesh"] = describe(mesh)

    # --fast: the PR-8 fast path (fused generation step + direct table
    # seeding) for both figure programs.  The fused part is bit-neutral;
    # direct seeding draws DIFFERENT (equally valid) initial populations,
    # so the figure's statistics stay comparable but not bit-identical.
    engine = None
    if fast:
        from repro.core.engine import SearchEngine

        engine = SearchEngine(mesh=mesh, max_slots=max(64, seeds * W),
                              fused=True, direct_seed=True)

    t0 = time.time()
    joint_keys = jnp.stack([jax.random.PRNGKey(s) for s in range(seeds)])
    joints = joint_search_batched(
        joint_keys, ws, pop_size=POP, generations=GENS, top_k=TOPK, mesh=mesh,
        backend=backend, engine=engine,
    )
    t_joint = time.time() - t0

    # seeds x W single-workload GAs, seed-major, in one program
    t0 = time.time()
    sep_keys = jnp.concatenate(
        [jax.random.split(jax.random.PRNGKey(s + 100), W) for s in range(seeds)]
    )
    seps = batched_search(
        sep_keys,
        jnp.tile(ws.feats[:, None], (seeds, 1, 1, 1)),
        jnp.tile(ws.mask[:, None], (seeds, 1, 1)),
        names=[(n,) for n in ws.names] * seeds,
        pop_size=POP,
        generations=GENS,
        top_k=TOPK,
        mesh=mesh,
        backend=backend,
        engine=engine,
    )
    t_sep = time.time() - t0
    results["joint_wall_s_total"] = t_joint
    results["separate_wall_s_total"] = t_sep

    # cross-rescore every separate winner on the FULL set in one evaluation
    from repro.core.search import rescore_designs

    all_top = [r.top_genomes for r in seps]
    counts = [len(g) for g in all_top]
    if sum(counts):
        s_flat, _ = rescore_designs(np.concatenate([g for g in all_top if len(g)]), ws)
    offs = np.cumsum([0] + counts)

    for seed in range(seeds):
        joint = joints[seed]
        sep = {
            name: seps[seed * W + i] for i, name in enumerate(ws.names)
        }
        failed = {}
        for i, name in enumerate(ws.names):
            b = seed * W + i
            s_all = s_flat[offs[b]:offs[b + 1]] if counts[b] else np.zeros((0,))
            failed[name] = float(np.mean(~np.isfinite(s_all))) if counts[b] else 1.0

        # optimize-for-largest vs joint, per workload
        big = sep[largest]
        comparison = {}
        if len(big.top_genomes) and len(joint.top_genomes):
            s_big = per_workload_scores(big.top_genomes[0], ws)
            s_joint = per_workload_scores(joint.top_genomes[0], ws)
            for w in ws.names:
                if np.isfinite(s_big[w]) and np.isfinite(s_joint[w]):
                    comparison[w] = 1.0 - s_joint[w] / s_big[w]  # + = joint better
                else:
                    comparison[w] = None if np.isfinite(s_joint[w]) else float("nan")
        entry = {
            "seed": seed,
            "joint_top10": [float(s) for s in joint.top_scores],
            "separate_failed_frac": failed,
            "joint_vs_largest_improvement": comparison,
            "joint_wall_s": t_joint / seeds,
        }
        results["seeds"].append(entry)
        if verbose:
            jbest = f"{joint.top_scores[0]:.3g}" if len(joint.top_scores) else "fail"
            print(f"[fig2 seed {seed}] joint best {jbest} "
                  f"({t_joint/seeds:.1f}s amortized); failed%: "
                  f"{ {k: f'{v:.0%}' for k, v in failed.items()} }")
            if comparison:
                print(f"          joint-vs-vgg16-optimized improvement: "
                      f"{ {k: (f'{v:.0%}' if v is not None and np.isfinite(v) else 'fail') for k, v in comparison.items()} }")
    if verbose:
        n_designs = seeds * (1 + W) * POP * (GENS + 1)
        print(f"[fig2] total wall {t_joint + t_sep:.1f}s "
              f"({n_designs / (t_joint + t_sep):.0f} designs/s end-to-end)")
    return results


def main(argv=None) -> int:
    import argparse

    from benchmarks.run import exp_dir, prepare_search_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument(
        "--mesh", nargs="?", const="auto", default=None, metavar="SEARCHxPOP",
        help="shard both figure programs over a (search, population) mesh",
    )
    ap.add_argument(
        "--backend", default="jnp", choices=["jnp", "pallas", "table"],
        help="cost-model backend for both figure programs",
    )
    ap.add_argument(
        "--fast", action="store_true",
        help="fused generation step + direct table seeding for both "
             "programs (use with --backend table; different but equally "
             "valid seed pools, so statistics — not bits — match)",
    )
    args = ap.parse_args(argv)
    if args.fast and args.backend != "table":
        ap.error("--fast requires --backend table (direct seeding samples "
                 "the factorized demand tables)")

    mesh = prepare_search_mesh(args.mesh) if args.mesh else None
    out = run(seeds=args.seeds, mesh=mesh, backend=args.backend,
              fast=args.fast)

    with open(exp_dir() / "fig2_joint_vs_separate.json", "w") as f:
        json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
