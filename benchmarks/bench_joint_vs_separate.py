"""Paper Fig. 2: joint vs separate search.

Per seed (5 random initial populations):
  * joint search top-10 scores,
  * separate per-workload searches re-scored on ALL workloads (fair
    comparison) + % of their top designs that FAIL other workloads,
  * the optimize-for-largest-workload (VGG16) baseline vs joint, per
    workload (the paper's 36/36/20/69 % improvements).
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import numpy as np

from repro.core.objectives import make_objective
from repro.core.search import (
    joint_search,
    rescore_designs,
    run_search,
    separate_search,
)
from repro.imc.cost import evaluate_designs
from repro.core import space
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS, TOPK = 40, 10, 10
AREA = 150.0


def per_workload_scores(genome: np.ndarray, ws, area=AREA) -> Dict[str, float]:
    """ELA score of ONE design on each single workload."""
    import jax.numpy as jnp

    d = space.decode(jnp.asarray(genome[None, :]))
    out = {}
    for i, name in enumerate(ws.names):
        r = evaluate_designs(d, ws.subset([i]))
        s = make_objective("ela", area)(r)
        out[name] = float(s[0])
    return out


def run(seeds: int = 5, verbose: bool = True) -> dict:
    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    largest = "vgg16"
    results = {"seeds": [], "pop": POP, "gens": GENS}

    for seed in range(seeds):
        key = jax.random.PRNGKey(seed)
        t0 = time.time()
        joint = joint_search(key, ws, pop_size=POP, generations=GENS, top_k=TOPK)
        t_joint = time.time() - t0

        sep = separate_search(
            jax.random.PRNGKey(seed + 100), ws,
            pop_size=POP, generations=GENS, top_k=TOPK,
        )
        failed = {}
        for name, r in sep.items():
            if len(r.top_genomes):
                s_all, _ = rescore_designs(r.top_genomes, ws)
                failed[name] = float(np.mean(~np.isfinite(s_all)))
            else:
                failed[name] = 1.0

        # optimize-for-largest vs joint, per workload
        big = sep[largest]
        comparison = {}
        if len(big.top_genomes) and len(joint.top_genomes):
            big_best = big.top_genomes[0]
            joint_best = joint.top_genomes[0]
            s_big = per_workload_scores(big_best, ws)
            s_joint = per_workload_scores(joint_best, ws)
            for w in ws.names:
                if np.isfinite(s_big[w]) and np.isfinite(s_joint[w]):
                    comparison[w] = 1.0 - s_joint[w] / s_big[w]  # + = joint better
                else:
                    comparison[w] = None if np.isfinite(s_joint[w]) else float("nan")
        entry = {
            "seed": seed,
            "joint_top10": [float(s) for s in joint.top_scores],
            "separate_failed_frac": failed,
            "joint_vs_largest_improvement": comparison,
            "joint_wall_s": t_joint,
        }
        results["seeds"].append(entry)
        if verbose:
            print(f"[fig2 seed {seed}] joint best {joint.top_scores[0]:.3g} "
                  f"({t_joint:.1f}s); failed%: "
                  f"{ {k: f'{v:.0%}' for k, v in failed.items()} }")
            if comparison:
                print(f"          joint-vs-vgg16-optimized improvement: "
                      f"{ {k: (f'{v:.0%}' if v is not None and np.isfinite(v) else 'fail') for k, v in comparison.items()} }")
    return results


if __name__ == "__main__":
    out = run()
    with open("experiments/fig2_joint_vs_separate.json", "w") as f:
        json.dump(out, f, indent=1)
