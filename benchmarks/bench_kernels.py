"""Kernel parity + micro-bench: Pallas (interpret) vs jnp reference.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-times are NOT TPU numbers — parity (max |err|) is the deliverable
here; TPU timing comes from the roofline analysis of the compiled cells.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def bench_imc_eval(verbose=True):
    from repro.core import space
    from repro.imc.cost import evaluate_designs
    from repro.kernels.imc_eval.ops import evaluate_designs_kernel
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    g = space.random_genomes(jax.random.PRNGKey(0), 512)
    d = space.decode(g)
    r_ref = evaluate_designs(d, ws)
    # one pallas_call for the whole W-workload set (3-D grid, see kernel.py)
    r_pal = evaluate_designs_kernel(d, ws, backend="pallas", interpret=True)
    err = float(jnp.max(jnp.abs(r_pal.energy_pj - r_ref.energy_pj)
                        / (jnp.abs(r_ref.energy_pj) + 1e-9)))
    if verbose:
        print(f"[kern] imc_eval  pallas-vs-ref rel err {err:.2e} "
              f"(1 launch, {ws.n} workloads)")
    return {"kernel": "imc_eval", "rel_err": err, "pallas_calls": 1}


def bench_flash(verbose=True):
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.flash_attention.ref import attention_reference

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 256, 8, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    v = jax.random.normal(key, (2, 256, 2, 64))
    o_p = fa.flash_attention(q, k, v, causal=True)
    o_r = attention_reference(q, k, v, causal=True)
    err = float(jnp.abs(o_p - o_r).max())
    if verbose:
        print(f"[kern] flash_attention  pallas-vs-ref max err {err:.2e}")
    return {"kernel": "flash_attention", "max_err": err}


def bench_ssd(verbose=True):
    from repro.kernels.ssd_scan import ops, ref

    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 256, 4, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    y_p, h_p = ops.ssd_chunked(x, dt, A, Bm, Cm)
    y_r, h_r = ref.ssd_chunked(x, dt, A, Bm, Cm)
    err = float(jnp.abs(y_p - y_r).max())
    if verbose:
        print(f"[kern] ssd_scan  pallas-vs-ref max err {err:.2e}")
    return {"kernel": "ssd_scan", "max_err": err}


def run(verbose: bool = True) -> list:
    return [bench_imc_eval(verbose), bench_flash(verbose), bench_ssd(verbose)]


if __name__ == "__main__":
    from benchmarks.run import exp_dir

    res = run()
    with open(exp_dir() / "kernels.json", "w") as f:
        json.dump(res, f, indent=1)
