"""Tracked search-throughput benchmark — the repo's perf trajectory.

End-to-end DSE throughput of the batched one-jit search stack at the
paper's operating point (P=40, G=10, 4-CNN workload set):

  * multi-seed joint search (``joint_search_batched``): cold (first call,
    includes trace+compile) and warm (cached program) wall time,
  * all-seeds x all-workloads separate search in one program,
  * designs-evaluated/sec for both, vs the paper's ~36 s/design.

``benchmarks/run.py`` writes the result to
``experiments/search_throughput.json`` so future PRs can diff the
trajectory.  The paper's 4 h for the same P x G search is the 1x line.

``--mesh [SEARCHxPOP]`` re-runs the same workload on a 2-D (search,
population) device mesh (``launch.mesh.make_search_mesh``) and records the
sharded row under the ``"sharded"`` key of the same json — on a CPU host
it forces 8 fake XLA devices first, so the row proves the fleet layout
end-to-end even without real hardware.  ``--backend table`` re-runs
through the factorized grid-table cost model (``imc.tables``; eval
independent of workload depth) and records the row under ``"table"``.
See benchmarks/README.md.
"""
from __future__ import annotations

import sys
import time

# NOTE: importing jax alone does not initialize the XLA backend, but the
# repro modules build device arrays at import — keep them inside run() so
# ``main()`` can still inject xla_force_host_platform_device_count first.
import jax
import jax.numpy as jnp

PAPER_S_PER_DESIGN = 36.0
POP, GENS = 40, 10


def _block(results) -> None:
    # pipelined (transfer-thin) results carry ga=None — their top arrays
    # are host numpy already, so blocking on them is the right no-op
    jax.block_until_ready(
        [r.ga.scores if r.ga is not None else r.top_scores for r in results]
    )


def run(quick: bool = False, verbose: bool = True, mesh=None,
        backend: str = "jnp") -> dict:
    from repro.core.search import batched_search, joint_search_batched
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    # sharded rows use a seed count divisible by every 8-device search-axis
    # layout so the batch axis actually shards (ragged dims replicate)
    seeds = (4 if quick else 8) if mesh is not None else (2 if quick else 5)
    warm_reps = 2 if quick else 3  # warm = best-of-N (steady state, not noise)
    per_search = POP * (GENS + 1)
    out = {
        "pop": POP, "gens": GENS, "seeds": seeds, "backend": backend,
        "warm_reps": warm_reps,
        "paper_s_per_design": PAPER_S_PER_DESIGN,
    }
    if mesh is not None:
        from repro.launch.mesh import describe

        out["mesh"] = describe(mesh)
        out["devices"] = int(jax.device_count())

    def keys(base):
        return jnp.stack([jax.random.PRNGKey(base + s) for s in range(seeds)])

    t0 = time.time()
    _block(joint_search_batched(keys(0), ws, pop_size=POP, generations=GENS,
                                mesh=mesh, backend=backend))
    cold = time.time() - t0
    warm = float("inf")
    for rep in range(warm_reps):
        t0 = time.time()
        _block(joint_search_batched(keys(1000 * (rep + 1)), ws, pop_size=POP,
                                    generations=GENS, mesh=mesh,
                                    backend=backend))
        warm = min(warm, time.time() - t0)
    n = seeds * per_search
    out["joint"] = {
        "searches": seeds,
        "cold_s": cold,  # includes trace + XLA compile
        "warm_s": warm,  # cached program: the steady-state number
        "designs_per_s": n / warm,
        "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
    }
    if verbose:
        print(f"[search-thru] joint x{seeds}: cold {cold:.2f}s, warm {warm:.2f}s "
              f"-> {n/warm:.0f} designs/s ({n/warm*PAPER_S_PER_DESIGN:.0f}x paper)")

    W = ws.n
    sep_feats = jnp.tile(ws.feats[:, None], (seeds, 1, 1, 1))
    sep_mask = jnp.tile(ws.mask[:, None], (seeds, 1, 1))

    def sep_keys(base):
        return jnp.concatenate(
            [jax.random.split(jax.random.PRNGKey(base + s), W) for s in range(seeds)]
        )

    t0 = time.time()
    _block(batched_search(sep_keys(0), sep_feats, sep_mask,
                          pop_size=POP, generations=GENS, mesh=mesh,
                          backend=backend))
    cold = time.time() - t0
    warm = float("inf")
    for rep in range(warm_reps):
        t0 = time.time()
        _block(batched_search(sep_keys(1000 * (rep + 1)), sep_feats, sep_mask,
                              pop_size=POP, generations=GENS, mesh=mesh,
                              backend=backend))
        warm = min(warm, time.time() - t0)
    n = seeds * W * per_search
    out["separate"] = {
        "searches": seeds * W,
        "cold_s": cold,
        "warm_s": warm,
        "designs_per_s": n / warm,
        "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
    }
    if verbose:
        print(f"[search-thru] separate x{seeds*W}: cold {cold:.2f}s, warm {warm:.2f}s "
              f"-> {n/warm:.0f} designs/s ({n/warm*PAPER_S_PER_DESIGN:.0f}x paper)")
    return out


def run_fused(quick: bool = False, verbose: bool = True,
              densities=(1, 2, 3)) -> dict:
    """The fast-path row: fused generation step + direct table seeding on
    the separate-search config (B single-workload GAs, table backend) —
    the configuration the >=1M designs/s acceptance number is measured on
    — swept over grid densities to characterize table memory vs gather
    cost (``configure_grid``; density d inserts d-1 points per grid
    interval, so the joint design space grows ~d^9).

    The first density in ``densities`` (the baseline grid) provides the
    row's top-level ``designs_per_s`` that ``tools/ci.sh bench-smoke``
    gates against the unfused ``table`` row."""
    import numpy as np

    from repro.core import space
    from repro.core.engine import SearchEngine
    from repro.core.search import batched_search
    from repro.imc.tables import grid_table_shape, table_bytes
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    W = ws.n
    seeds = 10 if quick else 40
    B = seeds * W
    warm_reps = 2 if quick else 4
    per_search = POP * (GENS + 1)
    n = B * per_search

    keys = np.concatenate([
        np.asarray(jax.random.split(jax.random.PRNGKey(100 + s), W))
        for s in range(seeds)
    ])
    feats = np.tile(np.asarray(ws.feats)[:, None], (seeds, 1, 1, 1))
    mask = np.tile(np.asarray(ws.mask)[:, None], (seeds, 1, 1))
    names = [(w,) for w in PAPER_WORKLOADS] * seeds

    out = {
        "pop": POP, "gens": GENS, "searches": B, "backend": "table",
        "config": "separate", "fused": True, "direct_seed": True,
        "warm_reps": warm_reps, "paper_s_per_design": PAPER_S_PER_DESIGN,
        "densities": [],
    }
    base_density = space.GRID_DENSITY
    try:
        for d in densities:
            space.configure_grid(d)
            eng = SearchEngine(max_slots=B, fused=True, direct_seed=True)

            def go():
                return batched_search(keys, feats, mask, names=names,
                                      pop_size=POP, generations=GENS,
                                      backend="table", engine=eng)

            t0 = time.time()
            _block(go())
            cold = time.time() - t0
            warm = float("inf")
            for _ in range(warm_reps):
                t0 = time.time()
                _block(go())
                warm = min(warm, time.time() - t0)
            cells = 1
            for f in space.FIELDS:
                cells *= len(space.SPACE[f])
            row = {
                "density": int(d),
                "space_cells": cells,
                "table_shape": grid_table_shape(),
                "table_kb_per_workload": table_bytes(ws.tables()) / W / 1024.0,
                "cold_s": cold,
                "warm_s": warm,
                "designs_per_s": n / warm,
                "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
            }
            out["densities"].append(row)
            if verbose:
                print(f"[search-thru] fused x{B} density={d} "
                      f"({cells:.3g} cells, "
                      f"{row['table_kb_per_workload']:.1f} KB/workload): "
                      f"cold {cold:.2f}s, warm {warm*1e3:.1f}ms -> "
                      f"{n/warm/1e6:.3f}M designs/s")
    finally:
        space.configure_grid(base_density)
    # the gated steady-state number: the baseline grid's warm throughput
    out.update({k: out["densities"][0][k]
                for k in ("cold_s", "warm_s", "designs_per_s",
                          "speedup_vs_paper")})
    return out


def run_pipelined(quick: bool = False, verbose: bool = True) -> dict:
    """The transfer-thin row: the SAME configuration as the ``fused`` row's
    baseline-grid entry (B = seeds x W separate searches, table backend,
    fused generation step + direct table seeding) executed through a
    ``pipelined=True`` engine — the GA program computes its top-k-unique
    epilogue on device and only (B, top_k, n) genomes, (B, top_k) scores
    and (B, G+1) convergence cross the wire instead of the full (B, G+1,
    P, n) history.

    Records warm designs/s plus host-transfer bytes per launch for BOTH
    the thin and the history-syncing engine (``transfer_reduction_x`` is
    their ratio).  ``tools/check_fused_gate.py`` gates
    ``designs_per_s >= fused row`` and ``transfer_reduction_x >= 10``."""
    import numpy as np

    from repro.core.engine import SearchEngine
    from repro.core.search import batched_search
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    W = ws.n
    seeds = 10 if quick else 40
    B = seeds * W
    warm_reps = 2 if quick else 4
    per_search = POP * (GENS + 1)
    n = B * per_search

    keys = np.concatenate([
        np.asarray(jax.random.split(jax.random.PRNGKey(100 + s), W))
        for s in range(seeds)
    ])
    feats = np.tile(np.asarray(ws.feats)[:, None], (seeds, 1, 1, 1))
    mask = np.tile(np.asarray(ws.mask)[:, None], (seeds, 1, 1))
    names = [(w,) for w in PAPER_WORKLOADS] * seeds

    thin = SearchEngine(max_slots=B, fused=True, direct_seed=True,
                        pipelined=True)
    hist = SearchEngine(max_slots=B, fused=True, direct_seed=True)

    def go(eng):
        return batched_search(keys, feats, mask, names=names,
                              pop_size=POP, generations=GENS,
                              backend="table", engine=eng)

    t0 = time.time()
    _block(go(thin))
    cold = time.time() - t0
    warm = float("inf")
    for _ in range(warm_reps):
        t0 = time.time()
        _block(go(thin))
        warm = min(warm, time.time() - t0)

    # transfer accounting: one dedicated warm run per engine (the history
    # engine's program is also warmed first so its number is steady-state)
    thin.reset_transfer_stats()
    _block(go(thin))
    thin_bpl = thin.transfer_bytes / max(1, thin.launches)
    _block(go(hist))
    hist.reset_transfer_stats()
    _block(go(hist))
    hist_bpl = hist.transfer_bytes / max(1, hist.launches)

    out = {
        "pop": POP, "gens": GENS, "searches": B, "backend": "table",
        "config": "separate", "fused": True, "direct_seed": True,
        "pipelined": True, "warm_reps": warm_reps,
        "paper_s_per_design": PAPER_S_PER_DESIGN,
        "cold_s": cold,
        "warm_s": warm,
        "designs_per_s": n / warm,
        "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
        "launches": int(thin.launches),
        "transfer_bytes_per_launch": thin_bpl,
        "history_transfer_bytes_per_launch": hist_bpl,
        "transfer_reduction_x": hist_bpl / max(1.0, thin_bpl),
    }
    if verbose:
        print(f"[search-thru] pipelined x{B}: cold {cold:.2f}s, "
              f"warm {warm*1e3:.1f}ms -> {n/warm/1e6:.3f}M designs/s; "
              f"{thin_bpl:.0f} B/launch vs {hist_bpl:.0f} B/launch history "
              f"({out['transfer_reduction_x']:.1f}x thinner)")
    return out


def run_pareto(quick: bool = False, verbose: bool = True,
               pareto_k: int = 10) -> dict:
    """The multi-objective row: NSGA-II Pareto-front search
    (``objective="pareto"``) on the fast-path configuration (B = seeds x W
    separate searches, table backend, fused survival, direct seeding,
    transfer-thin pipelined engine).  Each search returns its ``pareto_k``
    best front members with per-member (E, L, A) vectors instead of one
    scalar optimum — this row tracks what the front search costs relative
    to the scalar ``pipelined`` row on the same B and operating point."""
    import numpy as np

    from repro.core.engine import SearchEngine
    from repro.core.search import batched_search
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    W = ws.n
    seeds = 10 if quick else 40
    B = seeds * W
    warm_reps = 2 if quick else 4
    per_search = POP * (GENS + 1)
    n = B * per_search

    keys = np.concatenate([
        np.asarray(jax.random.split(jax.random.PRNGKey(100 + s), W))
        for s in range(seeds)
    ])
    feats = np.tile(np.asarray(ws.feats)[:, None], (seeds, 1, 1, 1))
    mask = np.tile(np.asarray(ws.mask)[:, None], (seeds, 1, 1))
    names = [(w,) for w in PAPER_WORKLOADS] * seeds

    eng = SearchEngine(max_slots=B, fused=True, direct_seed=True,
                       pipelined=True)

    def go():
        return batched_search(keys, feats, mask, names=names,
                              pop_size=POP, generations=GENS,
                              backend="table", objective="pareto",
                              pareto_k=pareto_k, engine=eng)

    t0 = time.time()
    res = go()
    _block(res)
    cold = time.time() - t0
    warm = float("inf")
    for _ in range(warm_reps):
        t0 = time.time()
        res = go()
        _block(res)
        warm = min(warm, time.time() - t0)

    front_sizes = [len(r.top_scores) for r in res]
    out = {
        "pop": POP, "gens": GENS, "searches": B, "backend": "table",
        "config": "separate", "objective": "pareto",
        "pareto_k": int(pareto_k), "fused": True, "direct_seed": True,
        "pipelined": True, "warm_reps": warm_reps,
        "paper_s_per_design": PAPER_S_PER_DESIGN,
        "cold_s": cold,
        "warm_s": warm,
        "designs_per_s": n / warm,
        "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
        "mean_front_size": float(np.mean(front_sizes)),
        "min_front_size": int(min(front_sizes)),
    }
    if verbose:
        print(f"[search-thru] pareto x{B} (k={pareto_k}): cold {cold:.2f}s, "
              f"warm {warm*1e3:.1f}ms -> {n/warm/1e6:.3f}M designs/s; "
              f"front size mean {out['mean_front_size']:.1f} "
              f"min {out['min_front_size']}")
    return out


def main(argv=None) -> int:
    import argparse

    from benchmarks.run import prepare_search_mesh, write_search_throughput

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer seeds")
    ap.add_argument(
        "--mesh", nargs="?", const="auto", default=None, metavar="SEARCHxPOP",
        help="shard over a (search, population) mesh (e.g. 2x4; default: all "
             "devices on search) and record the row under 'sharded'",
    )
    ap.add_argument(
        "--backend", default="jnp", choices=["jnp", "pallas", "table"],
        help="cost-model backend; 'table' records its row under 'table' "
             "(the factorized-eval trajectory)",
    )
    ap.add_argument(
        "--fused", action="store_true",
        help="run the fast-path config (fused gen step + direct table "
             "seeding, separate-search B, table backend) over a grid-"
             "density sweep and record the row under 'fused'",
    )
    ap.add_argument(
        "--grid-density", default="1,2,3", metavar="D[,D...]",
        help="comma-separated grid densities for the --fused sweep "
             "(the first is the baseline the CI gate reads)",
    )
    ap.add_argument(
        "--pipelined", action="store_true",
        help="run the fast-path config through a transfer-thin pipelined "
             "engine (on-device top-k epilogue) and record the row under "
             "'pipelined' (warm designs/s + host-transfer bytes/launch)",
    )
    ap.add_argument(
        "--pareto", action="store_true",
        help="run the fast-path config under objective='pareto' (NSGA-II "
             "front search, thin pipelined engine) and record the row "
             "under 'pareto' (warm designs/s + front-size stats)",
    )
    ap.add_argument("--pareto-k", type=int, default=10,
                    help="front members per search for --pareto")
    args = ap.parse_args(argv)

    if args.pareto:
        if args.mesh or args.backend != "jnp" or args.fused or args.pipelined:
            ap.error("--pareto is its own configuration; "
                     "drop --mesh/--backend/--fused/--pipelined")
        res = run_pareto(quick=args.quick, pareto_k=args.pareto_k)
        write_search_throughput(res, row="pareto")
        return 0

    if args.pipelined:
        if args.mesh or args.backend != "jnp" or args.fused:
            ap.error("--pipelined is its own configuration; "
                     "drop --mesh/--backend/--fused")
        res = run_pipelined(quick=args.quick)
        write_search_throughput(res, row="pipelined")
        return 0

    if args.fused:
        if args.mesh or args.backend != "jnp":
            ap.error("--fused is its own configuration; drop --mesh/--backend")
        densities = tuple(int(v) for v in args.grid_density.split(","))
        res = run_fused(quick=args.quick, densities=densities)
        write_search_throughput(res, row="fused")
        return 0

    # each json row tracks ONE configuration: top-level = dense jnp
    # unsharded, 'sharded' = dense jnp on the mesh, 'table' = table backend
    # unsharded — refuse combinations that would overwrite a row with
    # numbers from a different configuration
    if args.mesh and args.backend != "jnp":
        ap.error("--mesh records the dense-jnp 'sharded' row; "
                 "combine it with --backend jnp only")
    mesh = prepare_search_mesh(args.mesh) if args.mesh else None
    res = run(quick=args.quick, mesh=mesh, backend=args.backend)
    if args.backend == "pallas":
        print("[search-thru] pallas run not recorded (no tracked row; "
              "interpret-mode timing off-TPU is not meaningful)")
        return 0
    row = "sharded" if mesh is not None else (
        "table" if args.backend == "table" else None
    )
    write_search_throughput(res, row=row)
    return 0


if __name__ == "__main__":
    sys.exit(main())
