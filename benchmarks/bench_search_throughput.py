"""Tracked search-throughput benchmark — the repo's perf trajectory.

End-to-end DSE throughput of the batched one-jit search stack at the
paper's operating point (P=40, G=10, 4-CNN workload set):

  * multi-seed joint search (``joint_search_batched``): cold (first call,
    includes trace+compile) and warm (cached program) wall time,
  * all-seeds x all-workloads separate search in one program,
  * designs-evaluated/sec for both, vs the paper's ~36 s/design.

``benchmarks/run.py`` writes the result to
``experiments/search_throughput.json`` so future PRs can diff the
trajectory.  The paper's 4 h for the same P x G search is the 1x line.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.search import batched_search, joint_search_batched
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

PAPER_S_PER_DESIGN = 36.0
POP, GENS = 40, 10


def _block(results) -> None:
    jax.block_until_ready([r.ga.scores for r in results])


def run(quick: bool = False, verbose: bool = True) -> dict:
    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    seeds = 2 if quick else 5
    per_search = POP * (GENS + 1)
    out = {
        "pop": POP, "gens": GENS, "seeds": seeds,
        "paper_s_per_design": PAPER_S_PER_DESIGN,
    }

    def keys(base):
        return jnp.stack([jax.random.PRNGKey(base + s) for s in range(seeds)])

    t0 = time.time()
    _block(joint_search_batched(keys(0), ws, pop_size=POP, generations=GENS))
    cold = time.time() - t0
    t0 = time.time()
    _block(joint_search_batched(keys(1000), ws, pop_size=POP, generations=GENS))
    warm = time.time() - t0
    n = seeds * per_search
    out["joint"] = {
        "searches": seeds,
        "cold_s": cold,  # includes trace + XLA compile
        "warm_s": warm,  # cached program: the steady-state number
        "designs_per_s": n / warm,
        "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
    }
    if verbose:
        print(f"[search-thru] joint x{seeds}: cold {cold:.2f}s, warm {warm:.2f}s "
              f"-> {n/warm:.0f} designs/s ({n/warm*PAPER_S_PER_DESIGN:.0f}x paper)")

    W = ws.n
    sep_feats = jnp.tile(ws.feats[:, None], (seeds, 1, 1, 1))
    sep_mask = jnp.tile(ws.mask[:, None], (seeds, 1, 1))

    def sep_keys(base):
        return jnp.concatenate(
            [jax.random.split(jax.random.PRNGKey(base + s), W) for s in range(seeds)]
        )

    t0 = time.time()
    _block(batched_search(sep_keys(0), sep_feats, sep_mask,
                          pop_size=POP, generations=GENS))
    cold = time.time() - t0
    t0 = time.time()
    _block(batched_search(sep_keys(1000), sep_feats, sep_mask,
                          pop_size=POP, generations=GENS))
    warm = time.time() - t0
    n = seeds * W * per_search
    out["separate"] = {
        "searches": seeds * W,
        "cold_s": cold,
        "warm_s": warm,
        "designs_per_s": n / warm,
        "speedup_vs_paper": (n / warm) * PAPER_S_PER_DESIGN,
    }
    if verbose:
        print(f"[search-thru] separate x{seeds*W}: cold {cold:.2f}s, warm {warm:.2f}s "
              f"-> {n/warm:.0f} designs/s ({n/warm*PAPER_S_PER_DESIGN:.0f}x paper)")
    return out


if __name__ == "__main__":
    from benchmarks.run import exp_dir

    res = run()
    with open(exp_dir() / "search_throughput.json", "w") as f:
        json.dump(res, f, indent=1)
