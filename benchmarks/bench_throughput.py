"""Search-throughput benchmark (paper Sec. IV: ~4 h for P=40 x G=10 on 64
CPU cores == ~36 s per design evaluated).

Measures:
  * vectorized evaluator throughput (designs/s) at several population
    sizes — the dense jnp path AND the factorized table path
    (``imc.tables``; the Pallas imc_eval kernel runs interpret-mode on
    CPU, compiled-TPU numbers are the target),
  * full GA generation throughput (eval + select + SBX + mutate, jitted).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space
from repro.core.ga import run_ga
from repro.core.objectives import make_objective
from repro.core.search import make_eval_fn, seed_population
from repro.imc.cost import evaluate_designs
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

PAPER_S_PER_DESIGN = 36.0


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / n


def run(verbose: bool = True) -> dict:
    from repro.imc.tables import evaluate_genomes_tables

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    obj = make_objective("ela", 150.0)
    out = {"paper_s_per_design": PAPER_S_PER_DESIGN, "eval": [], "ga": []}

    @jax.jit
    def eval_pop(genomes):
        return obj(evaluate_designs(space.decode(genomes), ws))

    tables = ws.tables()

    @jax.jit
    def eval_pop_table(genomes):
        return obj(evaluate_genomes_tables(genomes, tables))

    for backend, fn in (("jnp", eval_pop), ("table", eval_pop_table)):
        for pop in (40, 1024, 16384):
            g = space.random_genomes(jax.random.PRNGKey(0), pop)
            dt = _time(fn, g)
            rate = pop / dt
            out["eval"].append({"backend": backend, "pop": pop, "s": dt,
                                "designs_per_s": rate,
                                "speedup_vs_paper": rate * PAPER_S_PER_DESIGN})
            if verbose:
                print(f"[thru] eval[{backend:5s}] pop={pop:6d}: "
                      f"{rate:9.0f} designs/s "
                      f"({rate * PAPER_S_PER_DESIGN:.0f}x paper)")

    eval_fn = make_eval_fn(ws, "ela", 150.0)
    init = seed_population(jax.random.PRNGKey(1), ws, 40)
    def ga_run():
        # run_ga donates its init buffer -> hand it a fresh copy per call
        return run_ga(jax.random.PRNGKey(2), eval_fn, pop_size=40,
                      generations=10, init_genomes=jnp.array(init)).best_score
    dt = _time(ga_run, n=2)
    n_designs = 40 * 11
    out["ga"].append({"pop": 40, "gens": 10, "s": dt,
                      "designs_per_s": n_designs / dt})
    if verbose:
        print(f"[thru] full GA (P=40, G=10): {dt:.2f}s total "
              f"(paper: ~14,400s) -> {14400/dt:.0f}x end-to-end")
    return out


if __name__ == "__main__":
    from benchmarks.run import exp_dir

    res = run()
    with open(exp_dir() / "throughput.json", "w") as f:
        json.dump(res, f, indent=1)
