"""Benchmark entry point — one bench per paper table/figure + kernels.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Writes machine-readable results under experiments/ and prints a summary.
``experiments/search_throughput.json`` is the repo's tracked perf
trajectory (designs-evaluated/sec + end-to-end search wall time).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

EXP = Path(__file__).resolve().parents[1] / "experiments"


def exp_dir() -> Path:
    """The experiments/ output dir, created on demand.  Shared by every
    bench's ``__main__`` block so they can be run directly."""
    EXP.mkdir(exist_ok=True)
    return EXP


def prepare_search_mesh(spec: str):
    """``--mesh`` argument (``'auto'`` or ``'SEARCHxPOP'``) -> 2-D search
    mesh, shared by the bench entry points.  CPU-only hosts expose one
    device, so this first fakes 8 XLA host devices — it must therefore run
    before anything initializes a jax backend (the benches keep their
    repro imports lazy for exactly this reason)."""
    import os

    if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()
    from repro.launch.mesh import make_search_mesh

    if spec == "auto":
        return make_search_mesh()
    s, p = (int(v) for v in spec.lower().split("x"))
    return make_search_mesh(s, p)


# named rows kept alongside the top-level (dense, unsharded) trajectory
EXTRA_ROWS = ("sharded", "table", "service", "cache", "fused", "pipelined",
              "pareto")


def write_search_throughput(res: dict, *, row: str = None) -> Path:
    """Write ``experiments/search_throughput.json``.  ``row=None`` replaces
    the top-level (dense jnp, unsharded) trajectory; ``row="sharded"`` /
    ``row="table"`` updates that named row in place — every entry point
    (benchmarks.run, bench_search_throughput --mesh / --backend) keeps the
    other rows intact."""
    path = exp_dir() / "search_throughput.json"
    prior = json.loads(path.read_text()) if path.exists() else {}
    if row is None:
        out = res
        for r in EXTRA_ROWS:
            if r in prior:
                out[r] = prior[r]
    else:
        assert row in EXTRA_ROWS, row
        out = prior
        out[row] = res
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="1 seed instead of 5")
    args = ap.parse_args(argv)
    exp_dir()

    from benchmarks import (
        bench_dse_service,
        bench_generalization,
        bench_joint_vs_separate,
        bench_kernels,
        bench_search_throughput,
        bench_throughput,
    )

    t0 = time.time()
    print("== kernels (parity) ==")
    kern = bench_kernels.run()
    with open(EXP / "kernels.json", "w") as f:
        json.dump(kern, f, indent=1)

    print("\n== throughput (paper Sec. IV: 36 s/design) ==")
    thru = bench_throughput.run()
    with open(EXP / "throughput.json", "w") as f:
        json.dump(thru, f, indent=1)

    print("\n== search throughput (batched one-jit stack; tracked trajectory) ==")
    sthru = bench_search_throughput.run(quick=args.quick)
    write_search_throughput(sthru)

    print("\n== search throughput (factorized table backend) ==")
    sthru_t = bench_search_throughput.run(quick=args.quick, backend="table")
    write_search_throughput(sthru_t, row="table")

    print("\n== search throughput (fused gen step + direct seed, grid sweep) ==")
    sthru_f = bench_search_throughput.run_fused(
        quick=args.quick, densities=(1, 2) if args.quick else (1, 2, 3))
    write_search_throughput(sthru_f, row="fused")

    print("\n== search throughput (pipelined transfer-thin engine) ==")
    sthru_p = bench_search_throughput.run_pipelined(quick=args.quick)
    write_search_throughput(sthru_p, row="pipelined")

    print("\n== DSE service (continuous batching of mixed requests) ==")
    svc = bench_dse_service.run(quick=args.quick)
    write_search_throughput(svc, row="service")

    print("\n== Fig. 2: joint vs separate ==")
    fig2 = bench_joint_vs_separate.run(seeds=1 if args.quick else 5)
    with open(EXP / "fig2_joint_vs_separate.json", "w") as f:
        json.dump(fig2, f, indent=1)

    print("\n== Fig. 3: generalization loss across objectives ==")
    fig3 = bench_generalization.run()
    with open(EXP / "fig3_generalization.json", "w") as f:
        json.dump(fig3, f, indent=1)

    print(f"\nall benches done in {time.time()-t0:.0f}s; results in {EXP}/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
