"""Beyond-paper: joint IMC hardware search for an LLM SERVING MIX.

The paper optimizes one chip for four CNNs.  Here the workload set is a
mix of assigned LM architectures in decode mode (token-at-a-time serving)
— exported as IMC layer tables directly from the live model configs — and
the joint search finds one IMC chip that serves all of them.

    PYTHONPATH=src python examples/lm_hw_cosearch.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.search import (
    joint_search,
    rescore_designs,
    seed_population,
    separate_search,
)
from repro.workloads.lm import lm_workload
from repro.workloads.pack import pack_workloads

ARCHS = ["llama3.2-1b", "qwen2-vl-2b", "mamba2-780m"]


def main():
    named = [(a, lm_workload(get_config(a), mode="decode")) for a in ARCHS]
    ws = pack_workloads(named)
    print(f"LM serving mix: {ws.names} "
          f"({[len(l) for _, l in named]} IMC layers each)")

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    # LLM decode workloads are weight-capacity bound: billions of RRAM
    # cells, so (a) only the top corner of the search space fits at all —
    # seed with deep oversampling; (b) the area budget is a multi-chiplet
    # SYSTEM budget (~12,000 mm^2 — e.g. 16 reticle-limited chiplets), not
    # the paper's single-chip 150 mm^2: a 1B-param model at 2 bits/cell
    # needs ~100 mm^2 of RRAM cells alone, before ADCs and routers.
    init = seed_population(key, ws, 40, oversample=1024, max_rounds=32)
    res = joint_search(key, ws, area_constr=12_000.0, pop_size=40,
                       generations=10, init_genomes=init)
    print(f"\njoint LM-serving chip ({time.time()-t0:.1f}s), "
          f"score {res.top_scores[0]:.3g}:")
    for k, v in res.top_designs[0].items():
        print(f"   {k:14s} = {v}")

    sep = separate_search(
        jax.random.PRNGKey(1), ws, area_constr=12_000.0, pop_size=40,
        generations=10, share_init=init,
    )
    print("\nper-model chips re-scored on the full mix:")
    for name, r in sep.items():
        if not len(r.top_genomes):
            print(f"   {name:14s}: no feasible designs")
            continue
        s_all, _ = rescore_designs(r.top_genomes, ws, area_constr=12_000.0)
        failed = np.mean(~np.isfinite(s_all))
        best = np.nanmin(np.where(np.isfinite(s_all), s_all, np.nan))
        print(f"   {name:14s}: {failed:4.0%} fail on the mix; "
              f"best surviving score {best:.3g} "
              f"(joint: {res.top_scores[0]:.3g})")


if __name__ == "__main__":
    main()
