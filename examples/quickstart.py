"""Quickstart: the paper in 60 seconds.

Runs the joint hardware-workload search over the paper's four CNN
workloads, prints the best generalized IMC design, and contrasts it with
a separate per-workload search (most of whose winners FAIL on the other
workloads — the paper's headline phenomenon).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core.search import joint_search, rescore_designs, separate_search
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads


def main():
    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    print(f"workloads: {ws.names}")

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    res = joint_search(key, ws, pop_size=40, generations=10)
    dt = time.time() - t0
    print(f"\njoint search: {40 * 11} designs evaluated in {dt:.1f}s "
          f"(paper: ~4h on 64 CPU cores)")
    print(f"best generalized design (score {res.top_scores[0]:.3g}):")
    for k, v in res.top_designs[0].items():
        print(f"   {k:14s} = {v}")

    sep = separate_search(jax.random.PRNGKey(1), ws, pop_size=40, generations=10)
    print("\nseparate searches, re-scored on ALL workloads:")
    for name, r in sep.items():
        s_all, _ = rescore_designs(r.top_genomes, ws)
        failed = np.mean(~np.isfinite(s_all)) if len(s_all) else 1.0
        print(f"   optimized for {name:12s}: {failed:4.0%} of top designs "
              f"fail on the full workload set")


if __name__ == "__main__":
    main()
