"""Serving demo: continuous batching on a reduced mixtral (MoE + SWA).

Submits a burst of requests with different prompt/output lengths; the
engine prefills into free slots and decodes all live slots per step.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("mixtral-8x7b").reduced()
    key = jax.random.PRNGKey(0)
    params = transformer.init(cfg, key)
    eng = Engine(cfg, params, slots=4, max_len=128)

    rng = np.random.default_rng(0)
    for rid in range(10):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=int(rng.integers(8, 24))))

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s on CPU)")
    for r in sorted(done, key=lambda r: r.rid)[:5]:
        ttft = (r.t_first - r.t_submit) * 1e3
        print(f"  req {r.rid}: prompt {len(r.prompt):3d} -> {len(r.out):3d} new "
              f"(TTFT {ttft:.0f}ms) {r.out[:8]}...")


if __name__ == "__main__":
    main()
