"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic pipeline, with checkpoint/auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the reduced-scale version of ``repro.launch.train`` (same code
path); on a pod the same launcher runs the full configs over the
production mesh.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_demo")
    args = ap.parse_args()
    # ~100M params: width 512, 12 layers of the llama3.2 family
    return train_main([
        "--arch", "llama3.2-1b",
        "--d-model", "512",
        "--layers", "12",
        "--seq", "512",
        "--batch", "8",
        "--steps", str(args.steps),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    sys.exit(main())
