"""Post-optimization HLO analysis: collective traffic, op census.

``compiled.cost_analysis()`` reports FLOPs and bytes accessed but NOT
collective traffic, so we parse the optimized HLO text and sum operand
sizes of every communication op:

    all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute
    (+ their -start async forms; -done forms carry no new payload)

Sizes are per-device payload bytes (the HLO module is the single-device
SPMD program; an operand shape is the per-device shard).  We also record
per-collective-kind byte totals and an op census (how many fusions,
convolutions/dots, etc.) used by the perf iteration loop to spot redundant
gathers and layout churn.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0,
    "opaque": 0,
}

# bf16[128,4096]{1,0:T(8,128)(2,1)}  /  f32[]  /  (bf16[2,4], f32[8])
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*\)|\S+)\s+([\w\-]+)\(")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: Dict[str, int]
    counts: Dict[str, int]

    def summary(self) -> str:
        parts = [
            f"{k}: {self.counts.get(k, 0)}x {self.by_kind.get(k, 0) / 1e6:.1f}MB"
            for k in COLLECTIVE_KINDS
            if self.counts.get(k, 0)
        ]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum per-device payload (result-shape bytes) of every collective.

    We use the *result* shape: for all-reduce it equals the operand; for
    all-gather it is the gathered (larger) buffer — the bytes that actually
    traverse links per device in a ring implementation; for reduce-scatter
    the operand is larger, so we take max(result, heuristic) by parsing the
    operand list too would need full parsing — result-shape is the standard
    proxy and is what we report consistently across cells.
    """
    by_kind: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_str, kind, is_start = m.group(1), m.group(2), m.group(3)
        if is_start and kind == "all-reduce":
            # all-reduce-start result repeats the shape; count once
            pass
        b = shape_bytes(shape_str)
        if kind == "all-reduce" and is_start:
            b //= 2  # start returns (operand, result) tuple: same payload twice
        by_kind[kind] += b
        counts[kind] += 1
    return CollectiveStats(
        total_bytes=sum(by_kind.values()),
        by_kind=dict(by_kind),
        counts=dict(counts),
    )


def op_census(hlo_text: str) -> Counter:
    c: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m:
            c[m.group(1)] += 1
    return c


def largest_collectives(hlo_text: str, k: int = 8) -> List[Tuple[str, int]]:
    """The k biggest individual collective ops (kind, bytes) — hillclimb aid."""
    out: List[Tuple[str, int]] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        out.append((m.group(2), shape_bytes(m.group(1))))
    out.sort(key=lambda t: -t[1])
    return out[:k]
