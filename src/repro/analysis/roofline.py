"""Roofline model for the TPU v5e-class target.

Three terms per (arch x shape x mesh) cell, all in seconds:

    compute    = HLO_FLOPs_per_device  / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device  / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device / (links x link_bandwidth)

``compiled.cost_analysis()`` reports the per-device SPMD module, so its
flops/bytes are already per-chip — no further division by chip count.
Collective bytes come from the optimized HLO text (``analysis.hlo``).

The useful-compute ratio compares the analytic model FLOPs
(6·N_active·D for training, 2·N_active·tokens for inference) against the
compiled total — catching remat recompute and sharding-induced redundancy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import CollectiveStats

# ---- hardware constants (TPU v5e-class target) ------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 2                # usable links on a 2D-torus axis-pair (conservative)
HBM_GB = 16.0                # v5e HBM capacity


@dataclasses.dataclass
class Roofline:
    cell: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_global: float      # analytic 6ND / 2ND
    useful_ratio: float            # model_flops / (hlo_flops x chips)
    peak_fraction: float           # t_compute / max(all terms) -> roofline frac
    mem_per_device_gb: float = 0.0
    collectives: Optional[Dict[str, int]] = None

    def table_row(self) -> str:
        return (
            f"| {self.cell} | {self.mesh} | {self.t_compute*1e3:.2f} | "
            f"{self.t_memory*1e3:.2f} | {self.t_collective*1e3:.2f} | "
            f"{self.bottleneck} | {self.useful_ratio:.2f} | "
            f"{self.peak_fraction:.2%} |"
        )


def roofline_terms(
    *,
    cell: str,
    mesh_name: str,
    chips: int,
    hlo_flops: float,
    hlo_bytes: float,
    coll: CollectiveStats,
    model_flops_global: float,
    mem_per_device: float = 0.0,
) -> Roofline:
    t_c = hlo_flops / PEAK_FLOPS
    t_m = hlo_bytes / HBM_BW
    t_x = coll.total_bytes / (ICI_LINKS * ICI_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    worst = max(terms.values())
    useful = model_flops_global / max(hlo_flops * chips, 1.0)
    return Roofline(
        cell=cell,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=hlo_flops,
        bytes_per_device=hlo_bytes,
        collective_bytes=coll.total_bytes,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        peak_fraction=t_c / worst if worst > 0 else 0.0,
        mem_per_device_gb=mem_per_device / 1e9,
        collectives=dict(coll.by_kind),
    )


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs per step for the cell (global, not per-chip).

    train:    6 * N_active * tokens   (fwd 2ND + bwd 4ND)
    prefill:  2 * N_active * tokens
    decode:   2 * N_active * batch    (one token per sequence)
    plus attention-score FLOPs where attention exists (often dominant at 32k).
    """
    n_act = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult = B * S, 6.0
    elif shape.kind == "prefill":
        tokens, mult = B * S, 2.0
    else:
        tokens, mult = B * 1, 2.0
    base = mult * n_act * tokens

    # attention score+value FLOPs: 2 * 2 * H * Dh * Sq * Skv_eff per layer
    n_attn = sum(1 for m, _ in cfg.layer_plan() if m == "attn") * cfg.n_blocks
    if cfg.is_encdec:
        n_attn += cfg.encoder_layers + cfg.n_layers  # enc self + dec cross
    if n_attn and cfg.n_heads:
        H, Dh = cfg.n_heads, cfg.head_dim_
        if shape.kind == "train" or shape.kind == "prefill":
            skv = min(S, cfg.sliding_window) if cfg.sliding_window else S
            # causal halves the average effective kv length
            att = 4.0 * H * Dh * S * (skv / 2 if not cfg.sliding_window else skv) * B
            att *= 3.0 if shape.kind == "train" else 1.0
        else:
            skv = min(S, cfg.sliding_window) if cfg.sliding_window else S
            att = 4.0 * H * Dh * 1 * skv * B
        base += att * n_attn
    return base
