"""Sharded, atomic, mesh-agnostic checkpointing.

Layout (one directory per step):

    <dir>/step_000123/
        MANIFEST.json       # tree structure, shapes, dtypes, step, wall time
        arr_<idx>.npy       # one file per leaf (gathered to host)
        _COMMITTED          # written LAST — incomplete saves are ignored

Fault-tolerance contract:
  * ``save`` is atomic: writes into ``step_x.tmp`` then os.rename after the
    commit marker; a crash mid-save never corrupts the latest checkpoint.
  * ``restore`` loads the newest COMMITTED step <= requested.
  * ``restore_resharded`` re-lays the arrays onto a DIFFERENT mesh
    (elastic restart: e.g. a 16x16 checkpoint restored onto 8x16 after
    losing a pod row) by placing each host array with jax.device_put
    against the new sharding tree.
  * leaves are gathered via jax.device_get — on a real multi-host pod this
    becomes a per-host shard dump (the manifest format is already
    per-leaf, so switching to tensorstore/OCDBT is a storage-layer swap).

Checkpoints store the *logical* tree (params / opt state / data state /
step); nothing about the mesh is baked in.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MARKER = "_COMMITTED"


def _tree_paths(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | Path, step: int, tree: PyTree, *, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _tree_paths(tree)
    manifest = {
        "step": int(step),
        "time": time.time(),
        "treedef": str(treedef),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append(
            {"idx": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(tmp / "MANIFEST.json", "w") as f:
        json.dump(manifest, f)
    (tmp / _MARKER).touch()
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention: keep the newest `keep` committed checkpoints
    steps = sorted(committed_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}", ignore_errors=True)
    return final


def committed_steps(ckpt_dir: str | Path):
    ckpt_dir = Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and not p.name.endswith(".tmp") and (p / _MARKER).exists():
            out.append(int(p.name[5:]))
    return out


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return max(steps) if steps else None


def _load_leaves(path: Path):
    with open(path / "MANIFEST.json") as f:
        manifest = json.load(f)
    return [
        np.load(path / f"arr_{e['idx']}.npy") for e in manifest["leaves"]
    ], manifest


def restore(ckpt_dir: str | Path, template: PyTree, step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Host-side restore into the template's tree structure."""
    ckpt_dir = Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    arrs, manifest = _load_leaves(ckpt_dir / f"step_{step:09d}")
    _, treedef = _tree_paths(template)
    return jax.tree.unflatten(treedef, arrs), step


def clear(ckpt_dir: str | Path) -> None:
    """Remove a checkpoint directory tree entirely (a finished search
    deleting its own saved state); a missing directory is a no-op."""
    shutil.rmtree(Path(ckpt_dir), ignore_errors=True)


def scan(root: str | Path) -> list:
    """Names of child directories under ``root`` holding at least one
    COMMITTED step — the content keys a keyed store (checkpoint roots,
    the DSE result cache's disk tier) can currently serve.  Uncommitted
    (crashed mid-save) children are invisible, exactly like
    ``restore``'s view of a single directory."""
    root = Path(root)
    if not root.exists():
        return []
    return sorted(
        p.name for p in root.iterdir()
        if p.is_dir() and latest_step(p) is not None
    )


def restore_resharded(
    ckpt_dir: str | Path,
    template: PyTree,
    sharding_tree: PyTree,
    step: Optional[int] = None,
) -> Tuple[PyTree, int]:
    """Restore and place each leaf under the given (possibly different-mesh)
    sharding — the elastic-restart path."""
    host_tree, step = restore(ckpt_dir, template, step)
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), host_tree, sharding_tree
    )
    return placed, step
