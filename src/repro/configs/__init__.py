from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeSpec,
    get_config,
    list_configs,
)
