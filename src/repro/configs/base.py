"""Model configuration system.

Every assigned architecture is expressed as a ``ModelConfig``.  The same config
object drives
  * the JAX model implementation (``repro.models``),
  * the IMC workload export (``repro.workloads.lm``), and
  * the dry-run / roofline launchers (``repro.launch``).

A config describes a *family* via a layer plan: a repeating period of
(mixer, ffn) sub-layer kinds.  Dense transformers have period 1 =
[("attn", "mlp")]; Jamba has period 8 with one attention layer and MoE on odd
layers; Mamba-2 is [("mamba", "none")] (the SSD block contains its own gating
MLP-equivalent), etc.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

MIXER_KINDS = ("attn", "mamba")
FFN_KINDS = ("mlp", "moe", "none")


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned shape cells (identical across LM archs).
TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention / embedding details -------------------------------------
    mlp_act: str = "silu"  # "silu" (SwiGLU) | "gelu" (GeGLU)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_type: str = "rope"  # "rope" | "mrope" | "none"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0  # 0 = full attention
    logit_softcap: float = 0.0
    scale_embeds: bool = False  # gemma: multiply embeddings by sqrt(d_model)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    topk: int = 0
    moe_every: int = 1  # MoE ffn on layers with (i % moe_every == moe_every-1)
    capacity_factor: float = 1.25
    moe_d_ff: int = 0  # expert hidden size; 0 -> d_ff

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_every: int = 0  # hybrid: one attn layer per `attn_every` (jamba: 8);
    attn_offset: int = 4  # ... placed at this index within the period
    # 0 -> pure family default (all-attn for transformers, all-mamba for ssm)

    # --- encoder-decoder -----------------------------------------------------
    encoder_layers: int = 0  # >0 -> enc-dec (whisper)

    # --- VLM -----------------------------------------------------------------
    vision_tokens: int = 0  # stubbed patch-embedding prefix length (train/prefill)

    # --- source provenance ---------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def moe_d_ff_(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k is sub-quadratic / bounded-memory.

        SSM state is O(1); hybrids attend in only 1/attn_every layers (and we
        seq-shard their cache); sliding-window attention has a bounded cache.
        Pure full-attention archs skip ``long_500k`` (recorded in DESIGN.md).
        """
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True
        if self.sliding_window > 0:
            return True
        return False

    def supported_shapes(self) -> List[ShapeSpec]:
        out = []
        for s in ALL_SHAPES:
            if s.name == "long_500k" and not self.supports_long_context:
                continue
            out.append(s)
        return out

    def shape_skips(self) -> List[Tuple[str, str]]:
        """(shape, reason) pairs for cells that are intentionally not run."""
        skips = []
        if not self.supports_long_context:
            skips.append(
                (
                    "long_500k",
                    "pure full-attention arch: O(S) KV cache at 524k infeasible; "
                    "needs sub-quadratic attention (see DESIGN.md §4)",
                )
            )
        return skips

    # ---------------------------------------------------------------- layer plan
    def layer_plan(self) -> List[Tuple[str, str]]:
        """The repeating (mixer, ffn) period; len divides n_layers."""
        if self.family == "ssm":
            return [("mamba", "none")]
        if self.family == "hybrid":
            assert self.attn_every > 0
            plan = []
            for i in range(self.attn_every):
                mixer = "attn" if i == self.attn_offset % self.attn_every else "mamba"
                ffn = (
                    "moe"
                    if (self.n_experts and i % self.moe_every == self.moe_every - 1)
                    else "mlp"
                )
                plan.append((mixer, ffn))
            return plan
        # dense / moe / encdec / vlm transformers
        if self.n_experts and self.moe_every == 1:
            return [("attn", "moe")]
        if self.n_experts:
            return [
                ("attn", "moe" if i % self.moe_every == self.moe_every - 1 else "mlp")
                for i in range(self.moe_every)
            ]
        return [("attn", "mlp")]

    @property
    def period(self) -> int:
        return len(self.layer_plan())

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={self.period}"
        )
        return self.n_layers // self.period

    # ---------------------------------------------------------------- param count
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.head_dim_
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        mlp = 3 * d * self.d_ff
        moe = self.n_experts * 3 * d * self.moe_d_ff_ + d * self.n_experts
        di, ns = self.d_inner, self.ssm_state
        mamba = (
            d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_heads)  # in_proj
            + self.ssm_conv * (di + 2 * self.ssm_groups * ns)  # conv
            + 3 * self.ssm_heads  # A, D, dt_bias
            + di * d  # out_proj
        )
        per_layer = {"attn": attn, "mamba": mamba, "mlp": mlp, "moe": moe, "none": 0}
        for mixer, ffn in self.layer_plan():
            n += (per_layer[mixer] + per_layer[ffn] + 2 * d) * self.n_blocks
        if self.is_encdec:
            # encoder self-attn+mlp plus decoder cross-attn
            n += self.encoder_layers * (attn + mlp + 2 * d)
            n += self.n_layers * (attn + d)  # cross-attn per decoder layer
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        full_moe = self.n_experts * 3 * self.d_model * self.moe_d_ff_
        act_moe = self.topk * 3 * self.d_model * self.moe_d_ff_
        n_moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe") * self.n_blocks
        return self.param_count() - n_moe_layers * (full_moe - act_moe)

    # ---------------------------------------------------------------- reduction
    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=self.period * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            topk=min(self.topk, 2) if self.topk else 0,
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            encoder_layers=2 if self.encoder_layers else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # importing the modules triggers register()
    from repro.configs import (  # noqa: F401
        yi_9b,
        gemma_7b,
        qwen2_72b,
        llama32_1b,
        mamba2_780m,
        qwen2_vl_2b,
        whisper_medium,
        jamba_52b,
        mixtral_8x7b,
        qwen3_moe_235b,
    )
