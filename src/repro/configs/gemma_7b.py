"""Gemma-7B — dense, GeGLU, head_dim=256 (MHA: kv=16). [arXiv:2403.08295; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="gelu",           # GeGLU
    tie_embeddings=True,      # gemma ties the LM head
    scale_embeds=True,        # gemma scales embeddings by sqrt(d_model)
    rope_theta=10_000.0,
    source="arXiv:2403.08295; hf:google/gemma-7b",
))
