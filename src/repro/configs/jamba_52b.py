"""Jamba-v0.1 (52B) — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] — period-8 blocks: attention at in-block index 4,
Mamba elsewhere; MoE FFN on odd in-block indices (every 2nd layer).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    topk=2,
    moe_every=2,
    ssm_state=16,           # jamba uses mamba-1 state 16; SSD block reuses it
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=8,
    attn_offset=4,
    rope_type="none",       # jamba uses no positional encoding
    source="arXiv:2403.19887; hf:ai21labs/Jamba-v0.1",
))
