"""Qwen2-VL-2B backbone — M-RoPE, GQA kv=2; vision frontend stubbed.

[arXiv:2409.12191; hf] — ``input_specs()`` provides precomputed patch
embeddings as the image prefix; M-RoPE position ids cover (t, h, w).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_type="mrope",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    vision_tokens=1024,   # stubbed 32x32-patch image prefix
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B",
))
