"""Qwen3-MoE-235B-A22B — 128 experts top-8, fine-grained experts.

[hf:Qwen/Qwen3-235B-A22B family; config per assignment] — d_ff listed is the
per-expert hidden size (fine-grained experts, moe_d_ff = 1536).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    topk=8,
    moe_d_ff=1536,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-235B-A22B",
))
