"""Whisper-medium — encoder-decoder; conv frontend stubbed to frame embeddings.

[arXiv:2212.04356] — 24 encoder + 24 decoder layers, d_model=1024, MHA.
The assigned stress shapes (prefill_32k / decode_32k) exceed Whisper's native
1500-frame / 448-token positions; we exercise the *backbone* at those shapes
as specified (frontend is a stub providing precomputed frame embeddings).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,            # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    mlp_act="gelu",
    rope_type="none",       # whisper: sinusoid (enc) + learned (dec) positions
    tie_embeddings=True,
    source="arXiv:2212.04356; hf:openai/whisper-medium",
))
