"""Yi-9B — llama-architecture dense transformer with GQA. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_act="silu",
    rope_theta=10_000.0,
    source="arXiv:2403.04652; hf:01-ai/Yi-9B",
))
