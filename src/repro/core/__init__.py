"""The paper's contribution: joint hardware-workload DSE for IMC chips.

* ``space``      — the ~1.9e7-config hardware search space + genome codec
* ``ga``         — SBX + polynomial-mutation GA as one XLA program
* ``objectives`` — f(E_w, L_w, A) s.t. A <= A_constr families
* ``search``     — joint / separate drivers, seeding, cross-rescoring
* ``distributed``— population evaluation sharded over the mesh
"""
from repro.core import space  # noqa: F401
from repro.core.ga import GAResult, run_ga  # noqa: F401
from repro.core.objectives import OBJECTIVES, make_objective  # noqa: F401
from repro.core.search import (  # noqa: F401
    SearchResult,
    joint_search,
    rescore_designs,
    run_search,
    seed_population,
    separate_search,
)
