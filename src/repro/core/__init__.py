"""The paper's contribution: joint hardware-workload DSE for IMC chips.

* ``space``      — the ~1.9e7-config hardware search space + genome codec
* ``ga``         — SBX + polynomial-mutation GA as one XLA program
* ``objectives`` — f(E_w, L_w, A) s.t. A <= A_constr families
* ``engine``     — SearchRequest -> plan -> execute DSE engine (the
                   implementation behind every search driver)
* ``search``     — joint / separate driver wrappers, cross-rescoring
* ``distributed``— population evaluation sharded over the mesh
"""
from repro.core import space  # noqa: F401
from repro.core.engine import (  # noqa: F401
    POLICIES,
    EDFPolicy,
    PriorityPolicy,
    RequestMeta,
    SchedulingPolicy,
    SearchEngine,
    SearchRequest,
    get_policy,
    plan_batch,
)
from repro.core.ga import GAResult, run_ga, run_ga_batched  # noqa: F401
from repro.core.objectives import (  # noqa: F401
    OBJECTIVES,
    OBJECTIVE_WEIGHTS,
    make_objective,
    make_weighted_objective,
)
from repro.core.search import (  # noqa: F401
    SearchResult,
    batched_search,
    joint_search,
    joint_search_batched,
    rescore_designs,
    run_search,
    seed_population,
    seed_population_batched,
    separate_search,
)
