"""Pod-scale design-space exploration: searches AND populations on the mesh.

The paper calls out "runtime efficiency limitations and slow optimization
speed" as an open challenge (4 h for P=40 x G=10 on 64 CPU cores, ~36 s per
design, simulator-bound).  Here the evaluator is a tensor program, so the
whole batched search stack lays out over a 2-D ``(search, population)``
mesh (``launch.mesh.make_search_mesh``):

  * the leading batch axis of ``core.ga.run_ga_batched`` (independent GAs:
    seeds, workload sets, objective weights) shards over the ``search``
    mesh axis — a fleet runs hundreds of independent searches per launch;
  * each GA's population axis shards over the ``pod``/``data`` axes — a pod
    evaluates hundreds of thousands of designs per second; the GA's
    select/survive step needs only the (P,) score vector.

Two kinds of entry points:

  * ``sharded_eval_fn`` / ``sharded_batched_eval_fn`` — drop-in evaluation
    callbacks whose population (and batch) axes carry explicit
    ``with_sharding_constraint`` annotations; used by the dry-run launcher
    (launch/dryrun.py --paper) and standalone rescoring.
  * ``sharded_run_ga_batched`` / ``sharded_batched_search`` /
    ``sharded_separate_search`` / ``sharded_seed_population_batched`` —
    the batched drivers with their inputs committed to ``NamedSharding``
    placements (``place_batched``): batch axis pinned to ``search``,
    population axis to ``pod``/``data``.  The eval callbacks already take
    workload tensors as traced ``ctx`` arguments, so this is placement +
    GSPMD propagation — the cached one-jit GA programs are reused, not
    retraced, and per-element results are bit-identical to the unsharded
    path (asserted in tests/test_search_sharded.py on a fake 8-device
    host).

Meshes without a ``search`` (or without a ``data``/``pod``) axis degrade to
replication along the missing dimension, so every helper also accepts the
historical single-GA meshes.  The DSE engine (``core.engine``) places its
slot-packed launches through the same ``place_batched`` path —
``sharded_search_engine`` / ``serve.dse.DSEService(mesh=...)`` put the
whole request->plan->execute service on the mesh.  Remaining open item:
real-TPU timings (ROADMAP.md) — this container runs Pallas in interpret
mode.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import space
from repro.core.ga import GAResult, run_ga_batched
from repro.core.objectives import make_objective
from repro.imc.cost import evaluate_designs_arrays
from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet

SEARCH_AXIS = "search"
POP_AXES = ("pod", "data")


# ------------------------------------------------------------- axis helpers
def pop_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the population dimension shards over (may be empty)."""
    return tuple(a for a in POP_AXES if a in mesh.axis_names)


def search_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the search batch dimension shards over (may be empty)."""
    return tuple(a for a in (SEARCH_AXIS,) if a in mesh.axis_names)


def batch_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """``(search_axes, pop_axes)`` — disjoint axis groups for the 2-D
    (search, population) layout.  Invariants (checked in test_properties):
    the groups never overlap and only name axes present on the mesh."""
    return search_axes(mesh), pop_axes(mesh)


def batch_spec(mesh: Mesh, ndim: int, pop_dim: Optional[int] = None) -> P:
    """PartitionSpec for a batched array: dim 0 over ``search``, optional
    ``pop_dim`` over ``pod``/``data``, everything else replicated.  Missing
    mesh axes degrade to ``None`` (replicated), never an empty ``P(())``."""
    s_ax, p_ax = batch_axes(mesh)
    parts = [s_ax or None] + [None] * (ndim - 1)
    if pop_dim is not None and p_ax and 0 < pop_dim < ndim:
        parts[pop_dim] = p_ax
    return P(*parts)


def shape_spec(
    mesh: Mesh, shape: Sequence[int], pop_dim: Optional[int] = None
) -> P:
    """``batch_spec`` refined against a concrete shape: any dimension whose
    size is not divisible by its mesh-axis-group product degrades to
    replication (odd populations, B not a multiple of the search axis),
    because ``device_put``/``with_sharding_constraint`` reject uneven
    shards.  Scores are bit-identical either way — this only trades
    parallelism on the ragged dimension."""
    spec = batch_spec(mesh, len(shape), pop_dim)
    parts = []
    for dim, part in enumerate(spec):
        if part is None:
            parts.append(None)
            continue
        names = part if isinstance(part, tuple) else (part,)
        group = int(np.prod([mesh.shape[a] for a in names]))
        parts.append(part if shape[dim] % group == 0 else None)
    return P(*parts)


def place_batched(mesh: Mesh, x, *, pop_dim: Optional[int] = None):
    """Commit ``x`` to its 2-D layout placement."""
    x = jnp.asarray(x)
    return jax.device_put(x, NamedSharding(mesh, shape_spec(mesh, x.shape, pop_dim)))


# ------------------------------------------------------------ eval callbacks
def sharded_eval_fn(
    mesh: Mesh,
    ws: WorkloadSet,
    objective: str,
    area_constr: float,
    tech: TechParams = TECH,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """eval_fn with the population axis sharded over every data-ish mesh
    axis.  On a mesh with no ``pod``/``data`` axis the constraint degrades
    to full replication instead of an empty-tuple spec."""
    axes = pop_axes(mesh)
    group = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    obj = make_objective(objective, area_constr)
    feats, mask = ws.feats, ws.mask

    @jax.jit
    def eval_fn(genomes: jnp.ndarray) -> jnp.ndarray:
        # replicate instead of shard when the population is ragged (shapes
        # are static under trace, so this costs nothing at run time)
        shard = bool(axes) and genomes.shape[0] % group == 0
        pop_sharding = NamedSharding(mesh, P(axes, None) if shard else P())
        out_sharding = NamedSharding(mesh, P(axes) if shard else P())
        genomes = jax.lax.with_sharding_constraint(genomes, pop_sharding)
        scores = obj(evaluate_designs_arrays(space.decode(genomes), feats, mask, tech))
        return jax.lax.with_sharding_constraint(scores, out_sharding)

    return eval_fn


def sharded_batched_eval_fn(
    mesh: Mesh,
    objective: Optional[str],
    area_constr: float,
    tech: TechParams = TECH,
    *,
    backend: str = "jnp",
) -> Callable[[jnp.ndarray, Any], jnp.ndarray]:
    """Batched ``eval_fn(genomes (B, P, n), ctx) -> scores (B, P)`` with the
    2-D (search, population) layout annotated via sharding constraints.

    ``ctx`` is ``(feats (B, W, L, 6), mask (B, W, L))`` — or, for
    ``backend="table"``, ``(tables,)`` with ``tables`` an
    ``imc.tables.WorkloadTables`` pytree whose every leaf carries the
    leading B axis (tables are just more batched leaves: ``place_batched``
    pins them to the ``search`` mesh axis like feats/mask).  With
    ``objective=None`` a trailing ``weights (B, 3)`` leaf selects the
    exponent-weighted objective.  Reuses the cached ``core.search`` eval
    callbacks, so the same compiled cost model backs sharded and unsharded
    paths.  Used by the fleet dry-run (launch/dryrun.py --search-mesh
    [--backend table]) and standalone batched rescoring.
    """
    from repro.core.engine import _ctx_eval  # deferred: engine places via us

    base = _ctx_eval(objective, float(area_constr), tech, backend)

    @jax.jit
    def eval_fn(genomes: jnp.ndarray, ctx) -> jnp.ndarray:
        g_sharding = NamedSharding(mesh, shape_spec(mesh, genomes.shape, pop_dim=1))
        genomes = jax.lax.with_sharding_constraint(genomes, g_sharding)
        scores = jax.vmap(base)(genomes, ctx)
        s_sharding = NamedSharding(mesh, shape_spec(mesh, scores.shape, pop_dim=1))
        return jax.lax.with_sharding_constraint(scores, s_sharding)

    return eval_fn


# ------------------------------------------------------------ batched drivers
def sharded_run_ga_batched(
    mesh: Mesh,
    keys: jnp.ndarray,
    eval_fn: Callable,
    *,
    init_genomes: jnp.ndarray,
    ctx: Any = None,
    **kw,
) -> GAResult:
    """``core.ga.run_ga_batched`` with its inputs committed to the 2-D
    layout: keys/ctx batch-sharded over ``search``, init populations over
    (``search``, ``data``).  GSPMD propagates the layout through the cached
    GA program; results match the unsharded call bit-for-bit."""
    keys = place_batched(mesh, keys)
    # copy before placing: the GA donates its init, and device_put is a
    # no-op (same buffer) when the caller already committed this layout
    init_genomes = place_batched(mesh, jnp.array(init_genomes), pop_dim=1)
    if ctx is not None:
        ctx = jax.tree_util.tree_map(lambda a: place_batched(mesh, a), ctx)
    return run_ga_batched(keys, eval_fn, init_genomes=init_genomes, ctx=ctx, **kw)


def sharded_batched_search(mesh: Mesh, keys, feats, mask, **kw):
    """``core.search.batched_search`` on a (search, population) mesh."""
    from repro.core import search

    return search.batched_search(keys, feats, mask, mesh=mesh, **kw)


def sharded_separate_search(mesh: Mesh, key, ws: WorkloadSet, **kw):
    """``core.search.separate_search`` with the W per-workload GAs sharded
    over the ``search`` axis (one mesh slice per workload)."""
    from repro.core import search

    return search.separate_search(key, ws, mesh=mesh, **kw)


def sharded_seed_population_batched(mesh: Mesh, keys, feats, mask, pop_size, **kw):
    """``core.search.seed_population_batched`` on a (search, population) mesh."""
    from repro.core import search

    return search.seed_population_batched(keys, feats, mask, pop_size, mesh=mesh, **kw)


def sharded_search_engine(mesh: Mesh, **kw) -> "SearchEngine":
    """A ``core.engine.SearchEngine`` whose every plan launch commits its
    slot-packed inputs to this (search, population) mesh — the DSE-service
    stack (``serve.dse.DSEService(mesh=...)``) on a pod.  Scores stay
    bit-identical to the meshless engine (tests/test_engine.py)."""
    from repro.core.engine import SearchEngine

    return SearchEngine(mesh=mesh, **kw)
