"""Pod-scale design-space exploration: population eval sharded over the mesh.

The paper calls out "runtime efficiency limitations and slow optimization
speed" as an open challenge (4 h for P=40 x G=10 on 64 CPU cores, ~36 s per
design, simulator-bound).  Here the evaluator is a tensor program, so the
population axis simply shards over the mesh ``data`` axis: a pod evaluates
hundreds of thousands of designs per second; the GA's select/survive step
needs only the (P,) score vector (all-gathered — bytes, not tensors).

``sharded_eval_fn`` returns a drop-in ``eval_fn`` for ``core.ga.run_ga``
whose population batch is annotated with a ``data``-axis sharding; GSPMD
partitions the whole eval.  Used by the multi-pod DSE dry-run
(launch/dryrun.py --paper) and the throughput benchmark.

Interaction with the batched one-jit search stack (``core.search``): the
vmapped ``run_ga_batched`` adds a leading batch axis (workloads / seeds)
*on top of* the population axis.  Sharding the population axis per GA
composes with that today; sharding the BATCH axis itself over pods (one
pod per seed, W pods for W separate searches) is the remaining open item
tracked in ROADMAP.md.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import space
from repro.core.objectives import make_objective
from repro.imc.cost import evaluate_designs_arrays
from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet


def sharded_eval_fn(
    mesh: Mesh,
    ws: WorkloadSet,
    objective: str,
    area_constr: float,
    tech: TechParams = TECH,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """eval_fn with the population axis sharded over every data-ish mesh axis."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    pop_sharding = NamedSharding(mesh, P(axes, None))
    out_sharding = NamedSharding(mesh, P(axes))
    obj = make_objective(objective, area_constr)
    feats, mask = ws.feats, ws.mask

    @jax.jit
    def eval_fn(genomes: jnp.ndarray) -> jnp.ndarray:
        genomes = jax.lax.with_sharding_constraint(genomes, pop_sharding)
        scores = obj(evaluate_designs_arrays(space.decode(genomes), feats, mask, tech))
        return jax.lax.with_sharding_constraint(scores, out_sharding)

    return eval_fn
