"""DSE engine: search request -> batch plan -> one cached XLA program.

The service layer of the search stack (the ROADMAP's DSE-service north
star).  Every driver in ``core.search`` is a thin wrapper over three
pieces defined here:

  * ``SearchRequest``   — one search: workload set + objective (kind or
    exponent weights) + area + seed + backend + GA params.  Requests are
    heterogeneous: any mix of workload subsets, objectives, seeds and
    backends can be submitted together.
  * ``plan_batch``      — groups compatible requests by *traced-shape
    signature* (pop, generations, backend, tech — plus the raw (W, L)
    shape for dense backends; the ``table`` backend is layer-free, so any
    workload shapes pack together) and slot-packs each group into chunks
    of at most ``max_slots``, padding the last ragged chunk with repeated
    slots so every chunk of a group traces to the SAME program.
  * ``SearchEngine``    — executes a plan as one vmapped, donated,
    cached GA jit (``core.ga.run_ga_batched``), reusing the factorized
    table ctx (``imc.tables``) and the 2-D (search, population) mesh
    placement from ``core.distributed``.

Heterogeneity inside one program:

  * **Objectives** enter as a traced per-slot kind index + area scalar
    (``objectives.make_indexed_objective``): every branch computes exactly
    the expression of the static ``make_objective`` path, so packed scores
    are bit-identical to per-request ``run_search``.  Custom exponent
    weights use the weighted objective (its own signature group).
  * **Workload sets** under ``backend="table"`` are padded along W with
    all-zero table rows: a zero-demand workload fits everywhere and
    contributes 0 to the ``max``-reduction, which is exactly neutral.
    The seeding program sees mask-padded (W, L) feats; every quantity it
    consumes (crossbar demand, fits) is integer-valued, so padded layers
    are exactly neutral there too.
  * **Seeds** are just data (stacked PRNG keys).

Parity is asserted bit-identical against per-request ``run_search`` in
tests/test_engine.py, including under the fake-8-device mesh.  256 mixed
requests drain through 2 compiled programs (one seeding jit + one GA jit
entry); the acceptance test bounds it at 4.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import lru_cache, partial
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space
from repro.core.ga import (
    GAResult,
    GAState,
    GAThin,
    ParetoThin,
    ga_epilogue_batched,
    init_ga_state_batched,
    run_ga_batched,
    run_ga_batched_segment,
    run_ga_batched_thin,
    run_pareto_batched,
)
from repro.core.objectives import (
    OBJECTIVE_INDEX,
    OBJECTIVE_WEIGHTS,
    PARETO,
    make_indexed_objective,
    make_objective,
    make_pareto_objective,
    make_weighted_objective,
    pareto_scalar,
)
from repro.imc.cost import evaluate_designs_arrays
from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet

BACKENDS = ("jnp", "pallas", "table")

# reserved objective name selecting the traced-kind-index objective; the
# engine uses it so one program covers every OBJECTIVES kind and area
INDEXED = "__indexed__"


@dataclasses.dataclass
class SearchResult:
    workload_names: Tuple[str, ...]
    objective: str
    ga: Optional[GAResult]  # None for empty partials (never launched) and
    # for pipelined (transfer-thin) results, whose history never reaches host
    top_designs: List[Dict[str, float]]  # decoded, deduped, best-first
    top_scores: np.ndarray
    top_genomes: np.ndarray
    convergence: np.ndarray  # best-so-far score per generation
    valid: bool = True  # False: no finite-scoring design in the history
    partial: bool = False  # True: search stopped before its full budget
    generations: int = -1  # generations actually applied (-1 = full budget)
    # objective="pareto" only: per-member (max_W E, max_W L, A) vectors,
    # (kept, 3) float32 aligned with top_genomes/top_scores; None for the
    # scalar objective families
    objective_vectors: Optional[np.ndarray] = None


class EngineFault(RuntimeError):
    """A launch failed permanently (retries exhausted, or no retry path).

    ``partials`` — when the failing plan had already advanced some
    segments — carries one anytime ``SearchResult`` (``partial=True``,
    finalized from the accumulated history) per plan request, aligned
    with ``plan.requests`` (``None`` where nothing was evaluated yet), so
    a service can resolve the affected rids with their best-so-far."""

    def __init__(self, msg: str, *, partials: Optional[List[Optional[SearchResult]]] = None,
                 generations_done: int = 0):
        super().__init__(msg)
        self.partials = partials
        self.generations_done = int(generations_done)


class NonFiniteScoreError(EngineFault):
    """The per-segment score guard tripped: a launch produced NaN scores.

    (+inf is the NORMAL encoding for an infeasible design, so the guard
    is NaN-only; an all-infeasible history is flagged on the result as
    ``valid=False`` by ``_finalize`` instead.)"""


# --------------------------------------------------------- eval callbacks
@lru_cache(maxsize=None)
def _ctx_eval(
    objective: Optional[str], area_constr: float, tech: TechParams, backend: str
) -> Callable:
    """Cached ``eval_fn(genomes, ctx)`` with ``ctx = (feats (W, L, 6),
    mask (W, L))`` — or, for ``backend="table"``, ``ctx = (tables,)`` with
    ``tables`` an ``imc.tables.WorkloadTables`` pytree (``_eval_ctx`` builds
    the right one).  ``objective`` selects the scoring tail: a kind string
    (static), ``None`` (trailing traced ``weights (3,)`` leaf, exponent-
    weighted), ``PARETO`` (trailing traced ``area`` leaf; the fn returns
    (P, 3) objective VECTORS for NSGA-II survival), or ``INDEXED``
    (trailing traced ``(kind_index, area)`` leaves — the engine's
    mixed-objective path, bit-identical per branch to the static kinds).  The cache (plus workload tensors/tables being
    traced, not closed over) is what keeps the GA jit from retracing
    across seeds, workload sets and objectives."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if objective == INDEXED:
        obj = make_indexed_objective()
    elif objective == PARETO:
        obj = make_pareto_objective()
    elif objective is None:
        obj = make_weighted_objective(area_constr)
    else:
        obj = make_objective(objective, area_constr)

    if backend == "table":
        from repro.imc.tables import evaluate_genomes_tables

        def ev(genomes, ctx):
            return evaluate_genomes_tables(genomes, ctx[0], tech)

    elif backend == "pallas":
        from repro.kernels.imc_eval.ops import evaluate_designs_kernel_arrays

        def ev(genomes, ctx):
            return evaluate_designs_kernel_arrays(
                space.decode(genomes), ctx[0], ctx[1], tech
            )

    else:

        def ev(genomes, ctx):
            return evaluate_designs_arrays(space.decode(genomes), ctx[0], ctx[1], tech)

    def eval_fn(genomes: jnp.ndarray, ctx) -> jnp.ndarray:
        r = ev(genomes, ctx)
        if objective == INDEXED:
            return obj(r, ctx[-2], ctx[-1])
        if objective == PARETO or objective is None:
            # one trailing traced leaf: the (3,) weights (weighted) or the
            # () area constraint (pareto vector objective)
            return obj(r, ctx[-1])
        return obj(r)

    if backend == "table" and objective == INDEXED:
        # advertise the whole-generation Pallas kernel
        # (repro.kernels.ga_gen_step): the kernel understands exactly this
        # eval shape — factorized tables + traced (kind, area) tail — and
        # reads the TechParams it must bake in from this marker.
        eval_fn.gen_kernel_tech = tech

    return eval_fn


def _eval_ctx(
    feats: jnp.ndarray,
    mask: jnp.ndarray,
    tech: TechParams,
    backend: str,
    *,
    batched: bool = False,
) -> Tuple:
    """The workload half of an eval ``ctx`` for ``backend``: the raw
    ``(feats, mask)`` tensors, or — for the table backend — the factorized
    ``(tables,)`` statistics, reduced over the layer axis here, ONCE, so
    the per-generation evaluation never sees L again."""
    if backend != "table":
        return (feats, mask)
    from repro.imc.tables import build_tables_arrays, build_tables_batched

    build = build_tables_batched if batched else build_tables_arrays
    return (build(feats, mask, tech),)


def make_eval_fn(
    ws: WorkloadSet,
    objective: str,
    area_constr: float,
    tech: TechParams = TECH,
    *,
    backend: str = "jnp",
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """backend: "jnp" (portable), "pallas" (the imc_eval TPU kernel;
    interpret-mode off-TPU — numerically identical, see tests) or "table"
    (factorized per-workload grid tables: O(W) gathers per design, no
    layer axis — allclose to "jnp", see tests/test_tables.py)."""
    fn = _ctx_eval(objective, float(area_constr), tech, backend)
    ctx = (ws.tables(tech),) if backend == "table" else (ws.feats, ws.mask)

    def eval_fn(genomes: jnp.ndarray) -> jnp.ndarray:
        return fn(genomes, ctx)

    return eval_fn


def _workload_weights(feats: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Crossbar-demand proxy per workload (total weight count K * N * groups);
    the single definition of "largest" shared by sequential and batched
    seeding so their largest-workload picks can never diverge."""
    return (feats[..., 1] * feats[..., 2] * feats[..., 5] * mask).sum(-1)


def largest_workload_index(ws: WorkloadSet) -> int:
    """Largest = most crossbar demand at a reference design (most weights)."""
    return int(jnp.argmax(_workload_weights(ws.feats, ws.mask)))


# ----------------------------------------------------------------- seeding
def _seed_rounds(key, feats, mask, pop_size, oversample, max_rounds, tech):
    """Jit-traceable rejection sampler against ONE workload (feats (L, 6)).

    Each round draws ``pop_size * oversample`` candidates, keeps those that
    fit and are V/f-valid, and scatters them into the next free pool slots;
    a ``lax.while_loop`` repeats until the pool is full or ``max_rounds``
    is hit — the host only syncs once, on the final (pool, count)."""
    n_cand = pop_size * oversample

    def cond(st):
        _, _, count, rnd = st
        return (count < pop_size) & (rnd < max_rounds)

    def body(st):
        key, pool, count, rnd = st
        key, k = jax.random.split(key)
        cand = space.random_genomes(k, n_cand)
        r = evaluate_designs_arrays(space.decode(cand), feats[None], mask[None], tech)
        ok = r.fits[:, 0] & r.valid
        pos = count + jnp.cumsum(ok) - 1
        idx = jnp.where(ok & (pos < pop_size), pos, pop_size)  # OOB -> dropped
        pool = pool.at[idx].set(cand, mode="drop")
        count = jnp.minimum(count + ok.sum(), pop_size)
        return key, pool, count, rnd + jnp.int32(1)

    pool0 = jnp.zeros((pop_size, space.N_GENES), jnp.float32)
    st = (key, pool0, jnp.int32(0), jnp.int32(0))
    _, pool, count, _ = jax.lax.while_loop(cond, body, st)
    return pool, count


_SEED_STATICS = ("pop_size", "oversample", "max_rounds", "tech")


@partial(jax.jit, static_argnames=_SEED_STATICS)
def _seed_jit(key, feats, mask, *, pop_size, oversample, max_rounds, tech):
    return _seed_rounds(key, feats, mask, pop_size, oversample, max_rounds, tech)


@partial(jax.jit, static_argnames=_SEED_STATICS)
def _seed_batched_jit(keys, feats, mask, *, pop_size, oversample, max_rounds, tech):
    """keys (B, 2), feats (B, W, L, 6), mask (B, W, L).  Each element's
    largest workload is picked as a TRACED argmax+gather inside the
    program — no host-side device sync before the seeding launch."""

    def one(k, ft, mk):
        li = jnp.argmax(_workload_weights(ft, mk))
        return _seed_rounds(k, ft[li], mk[li], pop_size, oversample, max_rounds, tech)

    return jax.vmap(one)(keys, feats, mask)


def _valid_vt_mask(tech: TechParams) -> np.ndarray:
    """(V, Tc) boolean mask of ``imc.cost.design_valid`` over the
    (v_op, t_cycle_ns) grid — the only two axes validity depends on.
    Host numpy mirror of the jnp formula (identical f32 arithmetic)."""
    v = np.asarray(space.SPACE["v_op"], np.float32)[:, None]
    t = np.asarray(space.SPACE["t_cycle_ns"], np.float32)[None, :]
    k = np.float32(
        (tech.v_nominal - tech.v_th) ** tech.alpha_power / tech.v_nominal
    )
    t_min = k * v / (v - np.float32(tech.v_th)) ** np.float32(tech.alpha_power)
    return t >= t_min


# the six jointly-constrained fields of the direct seeder: the demand
# table's axes first, then the capacity axes — their mixed-radix order
# defines the 6-D cell index the CDF is over
_CAP_FIELDS = (
    "rows", "cols", "bits_cell", "c_per_tile", "t_per_router", "g_per_chip"
)


def _seed_cells_cdf(demand_l: np.ndarray) -> np.ndarray:
    """Host-side feasible-cell CDF of ONE workload's demand table.

    Feasibility factorizes exactly like the rejection test the direct
    seeder replaces: ``demand[rows, cols, bits] <= c_per_tile *
    t_per_router * g_per_chip`` over the 6-D grid (``glb_mb`` and the
    validity pair are handled separately).  Returns the inclusive int32
    prefix-sum over the flat (R, C, Bc, Cpt, Tpr, Gpc) cell order —
    cheap numpy on ~1e4..1e6 cells, computed once per (workload set,
    tech, grid) and cached; the jitted sampler only searchsorts it."""
    cpt = np.asarray(space.SPACE["c_per_tile"], np.float32)
    tpr = np.asarray(space.SPACE["t_per_router"], np.float32)
    gpc = np.asarray(space.SPACE["g_per_chip"], np.float32)
    cap = cpt[:, None, None] * tpr[None, :, None] * gpc[None, None, :]
    feas = demand_l[:, :, :, None, None, None] <= cap[None, None, None]
    return np.cumsum(feas.reshape(-1).astype(np.int64)).astype(np.int32)


def _seed_direct(key, cdf6, pop_size, tech):
    """Direct inverse-CDF sampler over the feasible cells of the largest
    workload — the table-backend replacement for the rejection rounds.

    ``cdf6`` is the precomputed joint-cell CDF (``_seed_cells_cdf``); the
    (v_op, t_cycle) validity mask contributes a second, trace-time CDF,
    and two uniform selectors pick cells by ``searchsorted``.  Each gene
    is then placed uniformly INSIDE its cell with a [1e-3, 1-1e-3]
    margin, so the f32 round-trip ``floor(genome * n)`` in
    ``space.decode_indices`` can never cross a cell boundary (round-trip
    error ~1e-6 against a 1e-3 margin).  Every sampled design fits the
    largest workload and is V/f-valid by construction — the paper's
    seeding rule with zero rejected draws and no data-dependent
    while-loop."""
    sizes = {f: len(space.SPACE[f]) for f in space.FIELDS}
    total6 = cdf6[-1]
    vt = _valid_vt_mask(tech)  # (V, Tc), trace-time constant
    cdf2 = jnp.asarray(np.cumsum(vt.reshape(-1).astype(np.int64)), jnp.int32)
    total2 = cdf2[-1]

    u = jax.random.uniform(key, (pop_size, space.N_GENES + 2))
    # clamp the selector below the count: f32 rounding of u*total on very
    # dense grids (total > 2^24) could otherwise land exactly on total
    k6 = jnp.minimum((u[:, -2] * total6).astype(jnp.int32), total6 - 1)
    k2 = jnp.minimum((u[:, -1] * total2).astype(jnp.int32), total2 - 1)
    sel6 = jnp.searchsorted(cdf6, k6, side="right")
    sel2 = jnp.searchsorted(cdf2, k2, side="right")
    idx = {}
    rem = sel6
    for f in reversed(_CAP_FIELDS):
        idx[f] = rem % sizes[f]
        rem = rem // sizes[f]
    idx["t_cycle_ns"] = sel2 % sizes["t_cycle_ns"]
    idx["v_op"] = sel2 // sizes["t_cycle_ns"]

    genes = []
    for j, f in enumerate(space.FIELDS):
        frac = jnp.clip(u[:, j], 1e-3, 1.0 - 1e-3)
        if f == "glb_mb":  # unconstrained axis: any cell
            genes.append(
                (jnp.floor(u[:, j] * sizes[f]) + frac) / sizes[f]
            )
        else:
            genes.append((idx[f].astype(jnp.float32) + frac) / sizes[f])
    pool = jnp.stack(genes, axis=1)
    # count mirrors the rejection seeder's contract: full unless the
    # largest workload fits NOWHERE in the space
    count = jnp.where(total6 > 0, jnp.int32(pop_size), jnp.int32(0))
    return pool, count


@partial(jax.jit, static_argnames=("pop_size", "tech"))
def _seed_direct_batched_jit(keys, cdf6, *, pop_size, tech):
    """keys (B, 2), cdf6 (B, n_cells) stacked per-slot feasible-cell CDFs
    (largest workload each, precomputed host-side and cached) feeding the
    direct cell sampler."""

    def one(k, cdf):
        return _seed_direct(k, cdf, pop_size, tech)

    return jax.vmap(one)(keys, cdf6)


def seed_population(
    key: jax.Array,
    ws: WorkloadSet,
    pop_size: int,
    *,
    tech: TechParams = TECH,
    oversample: int = 64,
    max_rounds: int = 8,
) -> jnp.ndarray:
    """Random init; designs failing the largest workload (or V/f-invalid)
    are discarded (paper Sec. III-C).  One jitted while-loop program."""
    wi = largest_workload_index(ws)
    pool, count = _seed_jit(
        key, ws.feats[wi], ws.mask[wi],
        pop_size=int(pop_size), oversample=int(oversample),
        max_rounds=int(max_rounds), tech=tech,
    )
    if int(count) < pop_size:
        raise RuntimeError(
            f"could not seed {pop_size} valid designs ({int(count)} found); "
            "largest workload may not fit anywhere in the search space"
        )
    return pool


def seed_population_batched(
    keys: jnp.ndarray,
    feats: jnp.ndarray,
    mask: jnp.ndarray,
    pop_size: int,
    *,
    tech: TechParams = TECH,
    oversample: int = 64,
    max_rounds: int = 8,
    mesh=None,
) -> jnp.ndarray:
    """Per-batch-element seeding: keys (B, 2), feats (B, W, L, 6), mask
    (B, W, L) -> pools (B, pop_size, n).  Each element rejects against its
    own largest workload — selected by a traced argmax INSIDE the jit, so
    nothing blocks on device between the call and the seeding launch — all
    under one vmapped while-loop.  With ``mesh`` (a
    ``launch.mesh.make_search_mesh`` layout) the batch axis is committed
    to the ``search`` mesh axis before the launch, so each mesh slice seeds
    its own searches."""
    if mesh is not None:
        from repro.core.distributed import place_batched

        keys = place_batched(mesh, keys)
        feats = place_batched(mesh, feats)
        mask = place_batched(mesh, mask)
    pools, counts = _seed_batched_jit(
        keys, feats, mask,
        pop_size=int(pop_size), oversample=int(oversample),
        max_rounds=int(max_rounds), tech=tech,
    )
    counts = np.asarray(counts)
    if counts.min() < pop_size:
        bad = int(np.argmin(counts))
        raise RuntimeError(
            f"could not seed {pop_size} valid designs for batch element {bad} "
            f"({int(counts[bad])} found)"
        )
    return pools


# ------------------------------------------------------------- result prep
def _top_unique(
    genomes: np.ndarray, scores: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Best-k designs, unique in *decoded grid index* space.

    Fully vectorized host-side numpy (``np.unique`` over score-sorted grid
    indices instead of a Python loop over all G*P designs, and a host
    decode instead of per-call jnp dispatches): sorting by score first
    means each unique design's first occurrence is its best-scoring one,
    and non-finite scores (inf/nan) sort to the end, so dropping them
    equals the old truncate-at-first-non-finite rule."""
    idx = space.decode_indices_np(genomes)
    # mixed-radix encode to ONE int64 per design: 1-D np.unique is far
    # cheaper than the row-wise axis=0 variant, and the encoding is
    # injective (SPACE_SIZE < 2^63 at any realistic grid density), so the
    # unique classes — and therefore the kept designs — are identical
    sizes = space.GRID_SIZES.astype(np.int64)
    strides = np.concatenate(
        [np.cumprod(sizes[::-1])[::-1][1:], np.ones(1, np.int64)]
    )
    codes = idx.astype(np.int64) @ strides
    order = np.argsort(scores, kind="stable")
    _, first = np.unique(codes[order], return_index=True)
    first.sort()  # positions within `order`, ascending = best-first
    keep = order[first]
    keep = keep[np.isfinite(scores[keep])][:k]
    return genomes[keep], scores[keep]


def _finalize_batch(
    ga_np: GAResult, requests: Sequence["SearchRequest"],
) -> List[SearchResult]:
    """Vectorized ``_finalize`` over the real slots of one launch.

    The per-slot loop was the warm drain's host bottleneck at large B
    (160 separate argsorts, decodes and unique calls); here the decode,
    the mixed-radix design codes, the stable score argsort and the
    convergence scan run ONCE over (S, (G+1)*P) arrays, leaving only the
    tiny per-slot unique/top-k selection in Python.  Slot-for-slot
    bit-identical to ``_finalize`` on the same history (same stable
    argsort, same unique-class first occurrences, same finite filter) —
    the engine-vs-``run_search`` parity tests cover both paths."""
    S = len(requests)
    G1, P, n = ga_np.genomes.shape[1:]
    flat_g = ga_np.genomes[:S].reshape(S, G1 * P, n)
    flat_s = ga_np.scores[:S].reshape(S, G1 * P)
    idx = space.decode_indices_np(
        flat_g.reshape(-1, n)).reshape(S, G1 * P, n)
    sizes = space.GRID_SIZES.astype(np.int64)
    strides = np.concatenate(
        [np.cumprod(sizes[::-1])[::-1][1:], np.ones(1, np.int64)]
    )
    codes = idx.astype(np.int64) @ strides  # (S, G1*P)
    order = np.argsort(flat_s, axis=1, kind="stable")
    conv = np.minimum.accumulate(ga_np.scores[:S].min(axis=2), axis=1)
    finite = np.isfinite(flat_s)
    out = []
    for i, r in enumerate(requests):
        o = order[i]
        _, first = np.unique(codes[i][o], return_index=True)
        first.sort()
        keep = o[first]
        keep = keep[finite[i][keep]][: r.top_k]
        top_g, top_s = flat_g[i][keep], flat_s[i][keep]
        out.append(SearchResult(
            workload_names=tuple(r.ws.names),
            objective=_objective_label(r),
            ga=GAResult(*(f[i] for f in ga_np)),
            top_designs=space.design_dicts_from_indices(idx[i][keep]),
            top_scores=top_s,
            top_genomes=top_g,
            convergence=conv[i],
            valid=bool(len(top_s)),
            partial=False,
            generations=int(G1) - 1,
        ))
    return out


def _finalize_batch_thin(
    thin_np: GAThin, requests: Sequence["SearchRequest"],
    *, partial: bool = False,
) -> List[SearchResult]:
    """``_finalize_batch`` over the thin epilogue outputs instead of the
    full history: the device already selected each slot's top-k-unique
    designs (``ga._thin_epilogue``, K = the plan's max ``top_k``) and the
    convergence curve, so all that is left is slicing each request's own
    ``top_k`` prefix off the padded arrays and decoding the few kept
    genomes.  The selection is prefix-stable (ordered by score rank), so
    a request asking for fewer than K designs gets exactly the designs
    the history path would have kept — bit-identical fields, except
    ``ga`` is ``None``: the history never crossed the wire."""
    out = []
    for i, r in enumerate(requests):
        kept = int(min(int(thin_np.n_kept[i]), r.top_k))
        top_g = thin_np.top_genomes[i][:kept]
        top_s = thin_np.top_scores[i][:kept]
        conv = thin_np.convergence[i]
        out.append(SearchResult(
            workload_names=tuple(r.ws.names),
            objective=_objective_label(r),
            ga=None,
            top_designs=space.design_dicts_from_indices(
                space.decode_indices_np(top_g)),
            top_scores=top_s,
            top_genomes=top_g,
            convergence=conv,
            valid=bool(kept),
            partial=bool(partial),
            generations=int(conv.shape[-1]) - 1,
        ))
    return out


def _finalize_batch_pareto(
    thin_np: ParetoThin, requests: Sequence["SearchRequest"],
    *, history: Optional[tuple] = None,
) -> List[SearchResult]:
    """Host finalize of a Pareto plan: the device epilogue already picked
    each slot's crowded-order front members (K = the plan's max
    ``pareto_k``, cell-deduped exactly like the scalar thin epilogue), so
    this slices each request's own ``pareto_k`` prefix, decodes the kept
    genomes, and attaches the per-member (E, L, A) vectors.  ``history``
    is the optional synced ``(genomes_hist, objs_hist)`` pair from a
    sequential engine; its scalar-proxy scores (``pareto_scalar`` — the
    E*L*A bits of the ``ela`` objective) make the attached ``ga`` usable
    by every history consumer (rescoring, partial snapshots, caching)."""
    out = []
    gh_np = sh_np = None
    if history is not None:
        gh_np, oh_np = history
        # host numpy multiply in (E, L, A) order: same f32 products, same
        # association as the in-jit pareto_scalar — bit-identical
        sh_np = np.asarray(oh_np[..., 0] * oh_np[..., 1] * oh_np[..., 2])
    for i, r in enumerate(requests):
        kept = int(min(int(thin_np.n_kept[i]), int(r.pareto_k)))
        top_g = thin_np.top_genomes[i][:kept]
        top_v = thin_np.top_vectors[i][:kept]
        top_s = thin_np.top_scores[i][:kept]
        conv = thin_np.convergence[i]
        ga = None
        if gh_np is not None:
            ga = SearchEngine._history_result(gh_np[i], sh_np[i])
        out.append(SearchResult(
            workload_names=tuple(r.ws.names),
            objective=PARETO,
            ga=ga,
            top_designs=space.design_dicts_from_indices(
                space.decode_indices_np(top_g)),
            top_scores=top_s,
            top_genomes=top_g,
            convergence=conv,
            valid=bool(kept),
            partial=False,
            generations=int(conv.shape[-1]) - 1,
            objective_vectors=top_v,
        ))
    return out


def _finalize(
    ga: GAResult, names: Sequence[str], objective: str, top_k: int,
    *, partial: bool = False,
) -> SearchResult:
    G1, P, n = ga.genomes.shape
    flat_g = np.asarray(ga.genomes).reshape(-1, n)
    flat_s = np.asarray(ga.scores).reshape(-1)
    top_g, top_s = _top_unique(flat_g, flat_s, top_k)
    top_designs = space.design_dicts_from_indices(space.decode_indices_np(top_g))
    conv = np.minimum.accumulate(np.asarray(ga.scores).min(axis=1))
    # finite-score guard: _top_unique drops every non-finite (inf/nan)
    # score, so an empty top list means the whole history scored
    # infeasible or poisoned — flag it instead of silently returning
    return SearchResult(
        workload_names=tuple(names),
        objective=objective,
        ga=ga,
        top_designs=top_designs,
        top_scores=top_s,
        top_genomes=top_g,
        convergence=conv,
        valid=bool(len(top_s)),
        partial=bool(partial),
        generations=int(G1) - 1,
    )


def empty_partial_result(req: "SearchRequest") -> SearchResult:
    """The anytime result of a request that never got a good launch: no
    designs, ``valid=False``, ``partial=True``.  What a service resolves
    a quarantined or deadline-swept request with when no checkpointed
    best exists."""
    n = space.N_GENES
    return SearchResult(
        workload_names=tuple(req.ws.names),
        objective=_objective_label(req),
        ga=None,
        top_designs=[],
        top_scores=np.zeros((0,), np.float32),
        top_genomes=np.zeros((0, n), np.float32),
        convergence=np.zeros((0,), np.float32),
        valid=False,
        partial=True,
        generations=0,
    )


def _objective_label(req: "SearchRequest") -> str:
    """Truthful ``SearchResult.objective`` label: the kind string, or the
    kind a custom weight vector reproduces, or ``weighted(...)``."""
    if req.obj_weights is None:
        return req.objective
    inv = {v: k for k, v in OBJECTIVE_WEIGHTS.items()}
    w = tuple(float(v) for v in req.obj_weights)
    return inv.get(w, f"weighted{w}")


# ------------------------------------------------------- request -> plan
@dataclasses.dataclass(frozen=True, eq=False)
class SearchRequest:
    """One DSE query: everything ``run_search`` takes, as data.

    ``key`` overrides ``seed`` when given (drivers pass explicit PRNG
    keys; service clients usually just pick an integer seed).
    ``obj_weights`` switches the request to the exponent-weighted
    objective; otherwise ``objective`` must be one of
    ``objectives.OBJECTIVES``.

    ``priority`` and ``deadline_s`` are *scheduling metadata*, consumed
    only by ``plan_batch``'s policy layer (and the service front ends):
    priority 0 is the most urgent (larger = less urgent) and
    ``deadline_s`` is seconds-from-submit (the service converts it to an
    absolute clock deadline at ingest).  Neither enters ``signature()``
    — scheduling can never change which compiled program a request hits."""

    ws: WorkloadSet
    objective: str = "ela"
    obj_weights: Optional[Tuple[float, ...]] = None
    area_constr: float = 150.0
    seed: int = 0
    key: Optional[jax.Array] = None
    backend: str = "jnp"
    pop_size: int = 40
    generations: int = 10
    top_k: int = 10
    # objective="pareto" only: how many front members the result returns
    # (crowded order; large enough k covers the whole first front).  Not
    # part of signature() — like top_k it never changes the compiled
    # program — but request_key/plan_key hash it, so cached fronts of
    # different widths can never collide.
    pareto_k: int = 10
    tech: TechParams = TECH
    init_genomes: Optional[Any] = None  # (pop_size, n); never consumed
    priority: int = 0  # 0 = most urgent; scheduling-only, not traced
    deadline_s: Optional[float] = None  # seconds from submit; scheduling-only

    def prng_key(self) -> jax.Array:
        return self.key if self.key is not None else jax.random.PRNGKey(self.seed)

    def signature(self) -> tuple:
        """Traced-shape signature: requests with equal signatures run in
        ONE compiled program.  The ``table`` backend reduced the layer
        axis away, so its signature carries no workload shape at all —
        any mix of workload sets packs together; dense backends group by
        their exact (W, L).  ``top_k`` and ``init_genomes`` are host-side
        / data-only and deliberately absent."""
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {self.backend!r}")
        if self.objective == PARETO:
            if self.obj_weights is not None:
                raise ValueError("objective='pareto' is incompatible with obj_weights")
            if int(self.pareto_k) < 1:
                raise ValueError(f"pareto_k must be >= 1, got {self.pareto_k!r}")
            obj = ("pareto",)
        elif self.obj_weights is not None:
            obj = ("weighted", float(self.area_constr))
        elif self.objective not in OBJECTIVE_INDEX:
            raise ValueError(
                f"objective must be one of {tuple(OBJECTIVE_INDEX)} or "
                f"{PARETO!r} (or pass obj_weights), got {self.objective!r}"
            )
        else:
            obj = ("indexed",)
        shape = (
            () if self.backend == "table"
            else (int(self.ws.feats.shape[0]), int(self.ws.feats.shape[1]))
        )
        return (self.backend, int(self.pop_size), int(self.generations),
                self.tech, shape, obj)


@dataclasses.dataclass
class BatchPlan:
    """One XLA launch: ``len(requests)`` real searches slot-packed into
    ``slots`` program rows (trailing pad rows repeat the first request and
    are dropped on the host).  ``pad_w``/``pad_l`` are the group-wide
    padded workload-tensor shape, shared by every chunk of the group so
    they all hit the same compiled program."""

    signature: tuple
    requests: List[SearchRequest]
    indices: List[int]  # positions in the submitted request list
    slots: int
    pad_w: int
    pad_l: int


def plan_key(plan: BatchPlan) -> str:
    """Content hash of everything that determines a plan's GA trajectory
    (workload fingerprints, objective, area, tech constants, PRNG keys,
    GA params, slot shape).  Stable across processes — the checkpoint
    directory name, so a killed drain's restart finds its own saved
    state.  ``tech`` MUST be in the hash: it parameterizes the whole
    cost model, so two otherwise-identical plans under different
    ``TechParams`` follow different GA trajectories — omitting it lets a
    resume silently restore a foreign tech's state (regression-pinned in
    tests/test_result_cache.py)."""
    h = hashlib.sha256()
    for r in plan.requests:
        h.update(r.ws.fingerprint().encode())
        h.update(repr((
            r.objective, r.obj_weights, float(r.area_constr), r.backend,
            int(r.pop_size), int(r.generations), int(r.top_k),
            int(r.pareto_k), r.tech,
        )).encode())
        h.update(np.asarray(r.prng_key()).tobytes())
    h.update(repr((int(plan.slots), int(plan.pad_w), int(plan.pad_l))).encode())
    # the grid is a trace-time constant of every program in the plan: a
    # densified space follows a different trajectory from the same requests
    h.update(space.grid_token().encode())
    return h.hexdigest()[:24]


# ------------------------------------------------------ scheduling policy
@dataclasses.dataclass(frozen=True)
class RequestMeta:
    """Scheduling facts the policies key on, per queued request.

    ``seq`` is the submit order (the FIFO key and the universal
    tiebreak), ``wait_s`` how long the request has been queued (feeds
    priority aging), ``deadline_s`` the ABSOLUTE deadline on the
    scheduler's clock (``None`` = none).  ``plan_batch`` synthesizes
    defaults (seq = list position, wait 0, ``SearchRequest.deadline_s``
    read as absolute-from-0) when the caller has no queue state, so
    driver-path plans stay pure functions of the request list."""

    seq: int
    priority: int = 0
    wait_s: float = 0.0
    deadline_s: Optional[float] = None


class SchedulingPolicy:
    """Maps a queued request to a sortable urgency key (lower = sooner).

    The planner stable-sorts the queue by ``key`` before grouping, so a
    policy controls both which requests share a chunk and which chunk
    launches first — while chunking itself (fixed ``slots`` per
    signature group, padded tail) is untouched: policies can never
    change which compiled program a request hits, only when it runs."""

    name = "fifo"

    def key(self, req: SearchRequest, meta: RequestMeta) -> tuple:
        return (meta.seq,)


class PriorityPolicy(SchedulingPolicy):
    """Strict priority (0 = most urgent) with optional aging: a request
    waiting ``aging_s`` seconds gains one priority level, so any finite
    priority eventually reaches 0 and launches — the starvation-freedom
    knob the scheduler sim pins.  ``aging_s=None`` disables aging
    (pure strict priority; can starve under a hot higher-priority
    stream)."""

    name = "priority"

    def __init__(self, aging_s: Optional[float] = 30.0):
        if aging_s is not None and aging_s <= 0:
            raise ValueError(f"aging_s must be positive or None, got {aging_s}")
        self.aging_s = aging_s

    def key(self, req: SearchRequest, meta: RequestMeta) -> tuple:
        p = float(meta.priority)
        if self.aging_s is not None:
            p -= meta.wait_s / self.aging_s
        return (p, meta.seq)


class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first: absolute deadline, then submit order;
    deadline-less requests run after every deadlined one."""

    name = "edf"

    def key(self, req: SearchRequest, meta: RequestMeta) -> tuple:
        d = float("inf") if meta.deadline_s is None else float(meta.deadline_s)
        return (d, meta.seq)


POLICIES = {"fifo": SchedulingPolicy, "priority": PriorityPolicy, "edf": EDFPolicy}


def get_policy(policy) -> SchedulingPolicy:
    """Accepts a policy name or an already-built ``SchedulingPolicy``."""
    if isinstance(policy, SchedulingPolicy):
        return policy
    cls = POLICIES.get(policy)
    if cls is None:
        raise ValueError(f"policy must be one of {tuple(POLICIES)} or a "
                         f"SchedulingPolicy, got {policy!r}")
    return cls()


def plan_batch(
    requests: Sequence[SearchRequest],
    *,
    max_slots: int = 64,
    policy="fifo",
    meta: Optional[Sequence[RequestMeta]] = None,
    slot_hints: Optional[Dict[tuple, int]] = None,
) -> List[BatchPlan]:
    """Group heterogeneous requests by signature and slot-pack each group,
    ordered by the scheduling policy.

    Packing: a group of ``total`` requests runs in chunks of
    ``slots = min(total, max_slots)`` — a single exact-size launch when it
    fits (no pad waste on the hot driver paths), fixed ``max_slots``-row
    chunks when it doesn't (the last chunk padded), so a 256-request drain
    is 4 launches of ONE compiled program.  ``slot_hints`` (signature ->
    previously-used slot count) rounds a smaller group UP to a known-warm
    program size instead of compiling an exact-size one — the service's
    fixed-slot steady state; hints never shrink a chunk below its natural
    size.

    Policy (fifo / priority / edf, or any ``SchedulingPolicy``): the
    queue is stable-sorted by urgency key before grouping, members of a
    chunk are key-ordered, and the emitted plan list is key-ordered by
    each plan's most urgent member — so ``plans[0]`` is always the launch
    the policy wants next.  One fairness caveat is inherent to
    slot-packing: a less urgent request that shares a signature with an
    urgent one may ride along in its chunk (free slots cost nothing),
    so cross-GROUP order is policy order, within-chunk admission is
    policy order + free capacity.  ``meta`` (per-request queue facts:
    submit order, wait, absolute deadline) comes from the service; bare
    calls synthesize it from the request fields."""
    pol = get_policy(policy)
    if meta is None:
        meta = [
            RequestMeta(seq=i, priority=int(r.priority), wait_s=0.0,
                        deadline_s=r.deadline_s)
            for i, r in enumerate(requests)
        ]
    keys = [pol.key(r, m) for r, m in zip(requests, meta)]
    order = sorted(range(len(requests)), key=keys.__getitem__)
    groups: Dict[tuple, List[int]] = {}
    for i in order:
        groups.setdefault(requests[i].signature(), []).append(i)
    plans: List[BatchPlan] = []
    for sig, idxs in groups.items():
        reqs = [requests[i] for i in idxs]
        pad_w = max(int(r.ws.feats.shape[0]) for r in reqs)
        pad_l = max(int(r.ws.feats.shape[1]) for r in reqs)
        slots = min(len(idxs), int(max_slots))
        hint = (slot_hints or {}).get(sig)
        if hint is not None and slots < hint <= int(max_slots):
            slots = hint  # round up to the warm program size, never down
        for lo in range(0, len(idxs), slots):
            plans.append(BatchPlan(
                signature=sig,
                requests=reqs[lo:lo + slots],
                indices=idxs[lo:lo + slots],
                slots=slots,
                pad_w=pad_w,
                pad_l=pad_l,
            ))
    # most urgent plan first: group members are key-sorted, so a plan's
    # urgency is its first member's key
    plans.sort(key=lambda p: keys[p.indices[0]])
    return plans


# ----------------------------------------------------------------- engine
@dataclasses.dataclass
class _LaunchPrep:
    """Everything ``execute`` computes before the GA launch, shared by the
    single-shot and segmented paths so both trace identical operands."""

    packed: List[SearchRequest]
    place: Callable
    k_ga: Any
    init: Any
    ctx: tuple
    eval_fn: Callable
    # deferred seed-feasibility check (pipelined dispatch only): syncing
    # the seeder's counts would serialize back-to-back dispatches, so the
    # check moves to harvest time.  None when already verified eagerly.
    seed_check: Optional[Callable] = None


@dataclasses.dataclass
class PendingLaunch:
    """A dispatched-but-not-harvested plan: the handle ``dispatch``
    returns and ``harvest`` consumes.  Exactly one of the payload fields
    is set — ``thin`` (un-synced device ``GAThin``, pipelined single-shot
    and segmented finals), ``ga`` (un-synced device ``GAResult``,
    sequential single-shot), or ``results`` (already-finalized host
    results, sequential segmented — that path syncs per segment anyway).
    Holding the device arrays here WITHOUT ``np.asarray`` is what lets
    chunk i's host finalize overlap chunk i+1's device compute."""

    plan: BatchPlan
    thin: Optional[GAThin] = None
    ga: Optional[GAResult] = None
    results: Optional[List[SearchResult]] = None
    # pareto plans: (genomes_hist, objs_hist, ParetoThin) un-synced device
    # arrays; the history pair is (None, None) when pipelined (thin-only)
    pareto: Optional[tuple] = None
    seed_check: Optional[Callable] = None


class SearchEngine:
    """Executes batch plans as cached one-jit GA programs.

    Stateless apart from caches: the compiled programs live in the global
    jit caches (keyed by the plan signature's static half + traced
    shapes), and padded table slices are cached per
    ``(WorkloadSet.fingerprint(), tech, pad_w)`` — re-packed identical
    workload sets hit both.  ``mesh`` (``launch.mesh.make_search_mesh``)
    lays every launch out over the 2-D (search, population) device mesh
    via ``core.distributed.place_batched``; scores are bit-identical with
    or without it.

    Robustness knobs (all off by default — the single-shot path is
    byte-for-byte the original engine):

      * ``segment_gens``    — run each plan as ceil(G / k) segment
        launches of k generations through ``core.ga.run_ga_segment``
        (bit-identical to the single launch), with a NaN score guard
        after every segment.
      * ``segment_retries`` — how many times a failed/NaN segment is
        re-launched from the last good ``GAState`` before the plan gives
        up with an ``EngineFault`` carrying anytime partial results.
      * ``checkpoint_dir``  — persist the ``GAState`` + history every
        ``checkpoint_every`` segments under ``checkpoint_dir/<plan_key>``
        (atomic ``checkpoint.store``); a re-executed identical plan
        resumes from the newest committed step, and a completed plan
        clears its own directory.
      * ``result_cache``    — a ``serve.cache.ResultCache`` (or anything
        with its ``get(req)/put(req, res)`` shape): every completed
        request persists its finalized ``SearchResult`` keyed on its OWN
        content (``serve.cache.request_key`` — independent of
        chunk-mates and slot shape, unlike ``plan_key``), and ``run()``
        resolves cached requests without planning them — zero GA
        launches on a full hit.
      * ``pipelined``       — the transfer-thin fast path: the top-k
        selection and convergence curve are computed ON DEVICE by the
        thin epilogue fused onto the GA program, so a launch syncs
        (S, K, n) genomes + (S, K) scores + (S, G+1) convergence instead
        of the full (S, G+1, P, n) history, and ``execute`` splits into
        ``dispatch``/``harvest`` so ``run()`` (and a pipelined service
        drain) overlaps chunk i's host finalize with chunk i+1's device
        compute.  Result fields are bit-identical to the sequential path
        (tests/test_pipelined.py) EXCEPT ``SearchResult.ga`` is ``None``.
        Thin FULL results are still result-cacheable — ``ResultCache``
        round-trips ``ga=None`` entries (only ``partial=True`` results
        are refused), so ``pipelined=True`` + ``result_cache`` resolves
        a resubmitted drain with zero GA launches; fault partials /
        checkpoints stay full-history and bit-identical either way.

    ``transfer_bytes`` / ``launches`` count device->host bytes and plan
    launches since construction (or ``reset_transfer_stats()``) — the
    benches record bytes/launch from them.
    """

    def __init__(self, *, mesh=None, max_slots: int = 64,
                 segment_gens: Optional[int] = None, segment_retries: int = 1,
                 checkpoint_dir: Optional[str] = None, checkpoint_every: int = 1,
                 result_cache=None, fused: Optional[bool] = None,
                 direct_seed: bool = False, pipelined: bool = False):
        self.mesh = mesh
        self.max_slots = int(max_slots)
        # fused: the GA survival-epilogue knob (None = ga.default_fused();
        # both settings are bit-identical — see core.ga._make_gen_step)
        self.fused = fused
        # direct_seed: table-backend-only inverse-CDF seeding (no rejection
        # rounds).  Same validity guarantees, DIFFERENT seed pools than the
        # rejection sampler, so it is opt-in: the default keeps every
        # backend on the shared rejection program (table-vs-dense
        # trajectory closeness in tests/test_tables.py depends on that).
        self.direct_seed = bool(direct_seed)
        # pipelined: thin on-device epilogue + overlapped dispatch/harvest
        # (bit-identical results with ga=None — see the class docstring)
        self.pipelined = bool(pipelined)
        # device->host transfer telemetry, read by the benches/service
        self.transfer_bytes = 0
        self.launches = 0
        self.segment_gens = None if segment_gens is None else int(segment_gens)
        self.segment_retries = int(segment_retries)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.result_cache = result_cache
        self._padded_tables: Dict[tuple, tuple] = {}
        # slot-packed device tensors keyed on the packed content
        # (per-slot workload fingerprints + padded shape): a warm drain
        # over the same workload mix — every driver's steady state —
        # skips the host packing and transfer entirely
        self._packed_workloads: Dict[tuple, tuple] = {}
        self._stacked_tables: Dict[tuple, Any] = {}
        # direct-seeder feasible-cell CDFs: per-request host arrays and the
        # per-plan device stack (both content-keyed; see _request_seed_cdf)
        self._seed_cdfs: Dict[tuple, np.ndarray] = {}
        self._stacked_seed_cdfs: Dict[tuple, Any] = {}

    # ------------------------------------------------------------ planning
    def run(
        self, requests: Sequence[SearchRequest], *, mesh=None
    ) -> List[SearchResult]:
        """Plan + execute; results align with ``requests`` order.  With a
        ``result_cache``, cached requests resolve without entering a plan
        (their chunk-mates pack without them) and completed ones persist
        their entries — a repeated request list is zero launches."""
        out: List[Optional[SearchResult]] = [None] * len(requests)
        todo = list(range(len(requests)))
        if self.result_cache is not None:
            todo = []
            for i, r in enumerate(requests):
                hit = self.result_cache.get(r)
                if hit is not None:
                    out[i] = hit
                else:
                    todo.append(i)
        plans = plan_batch([requests[i] for i in todo],
                           max_slots=self.max_slots)
        if self.pipelined:
            # dispatch every chunk back-to-back (JAX async dispatch: the
            # launches queue without a host sync), then harvest in order —
            # chunk i's host finalize overlaps chunk i+1's device compute
            pending = [self.dispatch(p, mesh=mesh) for p in plans]
            for plan, pend in zip(plans, pending):
                for i, res in zip(plan.indices, self.harvest(pend)):
                    out[todo[i]] = res
        else:
            for plan in plans:
                for i, res in zip(plan.indices, self.execute(plan, mesh=mesh)):
                    out[todo[i]] = res
        return out  # type: ignore[return-value]

    def reset_transfer_stats(self) -> None:
        self.transfer_bytes = 0
        self.launches = 0

    def _sync(self, x) -> np.ndarray:
        """The engine's ONE device->host sync point: every harvest-side
        ``np.asarray`` goes through here so ``transfer_bytes`` stays an
        exact count of what crossed the wire."""
        a = np.asarray(x)
        self.transfer_bytes += a.nbytes
        return a

    # ----------------------------------------------------------- execution
    def _padded_request_tables(self, req: SearchRequest, pad_w: int):
        """Host-side table leaves of one request, zero-padded along W to
        the plan width.  Zero rows are exactly neutral: zero demand fits
        everywhere and the objective's max-reduction ignores zeros, so the
        padded slots cannot perturb real scores (tests/test_engine.py
        asserts bit-identity).  Keyed on the set's content fingerprint so
        re-packed identical sets reuse the same padded slices."""
        key = (req.ws.fingerprint(), req.tech, pad_w, space.grid_token())
        hit = self._padded_tables.get(key)
        if hit is None:
            leaves = [np.asarray(leaf) for leaf in req.ws.tables(req.tech)]
            extra = pad_w - leaves[0].shape[0]
            if extra:
                leaves = [
                    np.pad(leaf, [(0, extra)] + [(0, 0)] * (leaf.ndim - 1))
                    for leaf in leaves
                ]
            hit = self._padded_tables[key] = tuple(leaves)
        return hit

    def execute(self, plan: BatchPlan, *, mesh=None,
                on_progress: Optional[Callable[[int, SearchResult], None]] = None,
                ) -> List[SearchResult]:
        """One slot-packed XLA launch (or, with ``segment_gens``, a chain
        of guarded segment launches — same bits); returns results for the
        plan's REAL requests (pad slots dropped), in plan order.

        ``on_progress(i, partial)`` — called after every guarded segment
        with the plan-local request index and a monotone best-so-far
        snapshot (``SearchResult`` with ``partial=True``, finalized from
        the history accumulated so far).  Only the segmented path has
        mid-search boundaries to report from; the single-shot path never
        calls it.  Completed requests persist into ``result_cache``."""
        return self.harvest(self.dispatch(plan, mesh=mesh,
                                          on_progress=on_progress))

    def dispatch(self, plan: BatchPlan, *, mesh=None,
                 on_progress: Optional[Callable[[int, SearchResult], None]] = None,
                 ) -> PendingLaunch:
        """Launch a plan WITHOUT syncing its outputs to host: the GA (and,
        when ``pipelined``, the thin epilogue) is enqueued and the device
        arrays ride back inside a ``PendingLaunch`` for a later
        ``harvest``.  Dispatching several plans back-to-back queues their
        programs on the device, so the harvests' host work overlaps the
        remaining device compute.  The segmented path runs its guarded
        segment chain here (it is a synchronous loop by construction) but
        still defers its final sync/finalize to ``harvest``."""
        mesh = self.mesh if mesh is None else mesh
        r0 = plan.requests[0]
        if r0.objective == PARETO and r0.obj_weights is None:
            # Pareto plans always run single-shot: NSGA-II survival carries
            # an (objs, sel) state the segmented GAState does not model, so
            # segment_gens/checkpointing do not apply to this family.  Both
            # engine modes run the SAME fused device epilogue — front
            # selection is bit-identical across sequential/pipelined by
            # construction; sequential additionally keeps the history.
            prep = self._prepare(plan, mesh, defer_seed=self.pipelined)
            self.launches += 1
            kw = dict(pop_size=r0.pop_size, generations=r0.generations,
                      init_genomes=prep.init, ctx=prep.ctx, fused=self.fused,
                      top_k=max(int(r.pareto_k) for r in plan.requests))
            if self.pipelined:
                thin = run_pareto_batched(prep.k_ga, prep.eval_fn, **kw)
                return PendingLaunch(plan=plan, pareto=(None, None, thin),
                                     seed_check=prep.seed_check)
            gh, oh, thin = run_pareto_batched(prep.k_ga, prep.eval_fn,
                                              history=True, **kw)
            return PendingLaunch(plan=plan, pareto=(gh, oh, thin),
                                 seed_check=prep.seed_check)
        k = self.segment_gens
        if k is not None and 0 < k < int(r0.generations):
            return self._dispatch_segmented(plan, mesh, k,
                                            on_progress=on_progress)
        prep = self._prepare(plan, mesh, defer_seed=self.pipelined)
        self.launches += 1
        if self.pipelined:
            thin = run_ga_batched_thin(
                prep.k_ga, prep.eval_fn,
                pop_size=r0.pop_size, generations=r0.generations,
                init_genomes=prep.init, ctx=prep.ctx, fused=self.fused,
                top_k=max(int(r.top_k) for r in plan.requests),
            )
            return PendingLaunch(plan=plan, thin=thin,
                                 seed_check=prep.seed_check)
        ga = run_ga_batched(
            prep.k_ga, prep.eval_fn,
            pop_size=r0.pop_size, generations=r0.generations,
            init_genomes=prep.init, ctx=prep.ctx, fused=self.fused,
        )
        return PendingLaunch(plan=plan, ga=ga, seed_check=prep.seed_check)

    def harvest(self, pending: PendingLaunch) -> List[SearchResult]:
        """Sync a dispatched plan's (small) outputs, finalize, and persist
        completed results into the cache — the host half of ``execute``."""
        if pending.seed_check is not None:
            pending.seed_check()
        if pending.results is not None:
            results = pending.results
        elif pending.pareto is not None:
            gh, oh, thin = pending.pareto
            thin_np = ParetoThin(*(self._sync(f) for f in thin))
            history = None
            if gh is not None:
                history = (self._sync(gh), self._sync(oh))
            results = _finalize_batch_pareto(thin_np, pending.plan.requests,
                                             history=history)
        elif pending.thin is not None:
            thin_np = GAThin(*(self._sync(f) for f in pending.thin))
            results = _finalize_batch_thin(thin_np, pending.plan.requests)
        else:
            # one device->host transfer per field, then pure-numpy prep
            ga_np = GAResult(*(self._sync(f) for f in pending.ga))
            results = _finalize_batch(ga_np, pending.plan.requests)
        self._cache_completed(pending.plan, results)
        return results

    def _cache_completed(self, plan: BatchPlan,
                         results: Sequence[SearchResult]) -> None:
        """Persist each finished request's result under its own content
        key — per-request, so a future submission hits regardless of
        which chunk-mates it packed with this time."""
        if self.result_cache is not None:
            for r, res in zip(plan.requests, results):
                self.result_cache.put(r, res)

    def _prepare(self, plan: BatchPlan, mesh,
                 defer_seed: bool = False) -> _LaunchPrep:
        """Pack, place and seed a plan up to (but not including) the GA
        launch.  Shared verbatim by both execution paths.  With
        ``defer_seed`` the seeder's feasibility counts are NOT synced
        here — the returned ``seed_check`` raises at harvest time instead
        — so back-to-back pipelined dispatches never block on device."""
        reqs = plan.requests
        r0 = reqs[0]
        backend, tech = r0.backend, r0.tech
        S, W, L = plan.slots, plan.pad_w, plan.pad_l
        packed = list(reqs) + [r0] * (S - len(reqs))

        if mesh is None:
            place = lambda x, **_: x  # noqa: E731 — identity placement
        else:
            from repro.core.distributed import place_batched

            place = partial(place_batched, mesh)

        # slot-packed workload tensors, (W, L)-padded with masked slots;
        # cached on content so warm drains skip the host pack + transfer
        fps = tuple(r.ws.fingerprint() for r in packed)
        hit = self._packed_workloads.get((fps, W, L))
        if hit is None:
            feats = np.zeros((S, W, L, 6), np.float32)
            mask = np.zeros((S, W, L), bool)
            for i, r in enumerate(packed):
                w, l = r.ws.feats.shape[:2]
                feats[i, :w, :l] = np.asarray(r.ws.feats)
                mask[i, :w, :l] = np.asarray(r.ws.mask)
            hit = (jnp.asarray(feats), jnp.asarray(mask))
            self._packed_workloads[(fps, W, L)] = hit
        feats, mask = place(hit[0]), place(hit[1])

        # host-side stack (prng keys are tiny numpy/jnp arrays): ONE
        # device transfer instead of a stack of S device-resident scalars
        keys = place(jnp.asarray(np.stack([np.asarray(r.prng_key())
                                           for r in packed])))
        ks = jax.vmap(lambda k: jax.random.split(k))(keys)  # (S, 2, 2)
        # re-commit the derived keys: vmap outputs lose the committed
        # layout, and an uncommitted jit operand lets GSPMD re-layout the
        # whole program (bit-parity with the meshless run requires the
        # exact input placements the sharded drivers always used)
        k_seed, k_ga = place(ks[:, 0]), place(ks[:, 1])

        # workload ctx: factorized tables (stacked per request — the SAME
        # arrays run_search would trace, so parity is exact) or raw tensors.
        # Built BEFORE seeding: the direct table seeder samples straight
        # from the stacked demand table.
        tables = None
        if backend == "table":
            from repro.imc.tables import WorkloadTables

            gt = space.grid_token()
            tables = self._stacked_tables.get((fps, W, tech, gt))
            if tables is None:
                per_req = [self._padded_request_tables(r, W) for r in packed]
                tables = WorkloadTables(*(
                    jnp.asarray(np.stack([t[f] for t in per_req]))
                    for f in range(len(per_req[0]))
                ))
                self._stacked_tables[(fps, W, tech, gt)] = tables
            tables = jax.tree_util.tree_map(place, tables)
            ctx: tuple = (tables,)
        else:
            ctx = (feats, mask)

        init, seed_check = self._init_populations(
            packed, k_seed, feats, mask, place, tables=tables,
            defer=defer_seed)

        # objective tail: pareto's traced area, traced exponent weights,
        # or traced (kind, area)
        if r0.objective == PARETO and r0.obj_weights is None:
            areas = jnp.asarray([r.area_constr for r in packed], jnp.float32)
            ctx = ctx + (place(areas),)
            eval_fn = _ctx_eval(PARETO, 0.0, tech, backend)
        elif r0.obj_weights is not None:
            w = jnp.asarray([r.obj_weights for r in packed], jnp.float32)
            ctx = ctx + (place(w),)
            eval_fn = _ctx_eval(None, float(r0.area_constr), tech, backend)
        else:
            codes = jnp.asarray(
                [OBJECTIVE_INDEX[r.objective] for r in packed], jnp.int32
            )
            areas = jnp.asarray([r.area_constr for r in packed], jnp.float32)
            ctx = ctx + (place(codes), place(areas))
            eval_fn = _ctx_eval(INDEXED, 0.0, tech, backend)

        return _LaunchPrep(packed=packed, place=place, k_ga=k_ga,
                           init=init, ctx=ctx, eval_fn=eval_fn,
                           seed_check=seed_check)

    # ------------------------------------------------- segmented execution
    def _place_state(self, state: GAState, place) -> GAState:
        """Commit a (possibly host-restored) batched state to the mesh
        layout the GA programs expect (identity when meshless)."""
        return GAState(
            genomes=place(jnp.asarray(state.genomes), pop_dim=1),
            scores=place(jnp.asarray(state.scores), pop_dim=1),
            key=place(jnp.asarray(state.key)),
            gen=place(jnp.asarray(state.gen)),
        )

    def _ckpt_dir(self, plan: BatchPlan) -> Optional[Path]:
        if self.checkpoint_dir is None:
            return None
        return Path(self.checkpoint_dir) / plan_key(plan)

    def _partial_results(
        self, plan: BatchPlan, gh: Optional[np.ndarray], sh: Optional[np.ndarray],
    ) -> List[Optional[SearchResult]]:
        """Anytime results from the accumulated (S, g+1, P, n) history —
        ``None`` per request when nothing was ever evaluated."""
        if gh is None:
            return [None] * len(plan.requests)
        out = []
        for i, r in enumerate(plan.requests):
            ga_i = self._history_result(gh[i], sh[i])
            out.append(_finalize(ga_i, r.ws.names, _objective_label(r),
                                 r.top_k, partial=True))
        return out

    @staticmethod
    def _history_result(gh_i: np.ndarray, sh_i: np.ndarray) -> GAResult:
        """A host-side ``GAResult`` over one slot's (g+1, P, ·) history;
        ``np.argmin`` picks the first minimum exactly like the in-jit
        ``jnp.argmin`` of the single-shot program."""
        n = gh_i.shape[-1]
        flat_s = sh_i.reshape(-1)
        b = int(np.argmin(flat_s)) if flat_s.size else 0
        return GAResult(
            genomes=gh_i, scores=sh_i,
            best_genome=gh_i.reshape(-1, n)[b] if flat_s.size else np.zeros(n),
            best_score=flat_s[b] if flat_s.size else np.float32(np.inf),
        )

    def _dispatch_segmented(
        self, plan: BatchPlan, mesh, seg: int,
        on_progress: Optional[Callable[[int, SearchResult], None]] = None,
    ) -> PendingLaunch:
        """Advance the plan ``seg`` generations per launch with a NaN
        score guard, retry-from-last-good-state, and optional on-disk
        checkpoints.  The chained segments are bit-identical to the
        single launch (tests/test_ga_segments.py).  After every good
        segment, ``on_progress`` (if given) receives each request's
        best-so-far snapshot — finalized from the same accumulated
        history the fault/deadline partials use, so the streamed best is
        monotone non-increasing and exactly the history minimum.

        The generation counter is derived HOST-side: 0 for a fresh init,
        or the restored checkpoint's (host numpy) ``state.gen`` — the
        warm loop never syncs the device counter.

        ``pipelined`` keeps the accumulated history ON DEVICE: the guard
        blocks on a 1-byte NaN scalar instead of the full per-segment
        history, ``on_progress`` snapshots flow through the thin epilogue
        (``ga_epilogue_batched``), and the final epilogue is dispatched
        un-synced for ``harvest``.  Checkpoints and fault partials still
        sync the FULL history at their (cold) boundaries, so both stay
        bit-identical to the sequential path."""
        from repro.checkpoint import store

        reqs = plan.requests
        r0 = reqs[0]
        G = int(r0.generations)
        K = max(int(r.top_k) for r in reqs)
        thin = self.pipelined
        ck_dir = self._ckpt_dir(plan)

        state: Optional[GAState] = None
        done = 0
        # accumulated history, (S, done+1, P, n) / (S, done+1, P):
        # host numpy (sequential) or device arrays (pipelined)
        gh = sh = None
        if ck_dir is not None and store.latest_step(ck_dir) is not None:
            template = {"state": GAState(0, 0, 0, 0), "gh": 0, "sh": 0}
            tree, _ = store.restore(ck_dir, template)
            state = GAState(*tree["state"])
            # restored fields are host arrays — this int() never blocks
            done = int(np.asarray(state.gen).reshape(-1)[0])
            gh, sh = np.asarray(tree["gh"]), np.asarray(tree["sh"])
            if thin:
                gh, sh = jnp.asarray(gh), jnp.asarray(sh)

        def host_hist():
            if gh is None:
                return None, None
            if thin:
                return self._sync(gh), self._sync(sh)
            return gh, sh

        try:
            prep = self._prepare(plan, mesh)
            self.launches += 1
            if state is None:
                state = init_ga_state_batched(
                    prep.k_ga, prep.eval_fn, prep.init, ctx=prep.ctx
                )
                if thin:
                    if bool(jnp.isnan(state.scores).any()):
                        raise NonFiniteScoreError(
                            "NaN scores in the seed evaluation"
                        )
                    gh = state.genomes[:, None]
                    sh = state.scores[:, None]
                else:
                    s0 = self._sync(state.scores)
                    if np.isnan(s0).any():
                        raise NonFiniteScoreError(
                            "NaN scores in the seed evaluation"
                        )
                    gh = self._sync(state.genomes)[:, None]
                    sh = s0[:, None]
        except EngineFault:
            raise
        except Exception as e:
            raise EngineFault(
                f"segmented launch setup failed: {e}",
                partials=self._partial_results(plan, *host_hist()),
            ) from e

        seg_idx = 0
        while done < G:
            k_gens = min(seg, G - done)
            state = self._place_state(state, prep.place)
            attempt = 0
            while True:
                try:
                    new_state, (hg, hs) = run_ga_batched_segment(
                        state, prep.eval_fn, ctx=prep.ctx,
                        generations=k_gens, total_generations=G,
                        fused=self.fused,
                    )
                    if thin:
                        # guard on ONE reduced byte; the history stays put
                        if bool(jnp.isnan(hs).any()):
                            raise NonFiniteScoreError(
                                f"NaN scores in segment at generation {done}"
                            )
                    else:
                        hs_np = self._sync(hs)  # (S, k, P)
                        if np.isnan(hs_np).any():
                            raise NonFiniteScoreError(
                                f"NaN scores in segment at generation {done}"
                            )
                        hg_np = self._sync(hg)
                    break
                except Exception as e:
                    attempt += 1
                    if attempt > self.segment_retries:
                        raise EngineFault(
                            f"segment at generation {done} failed after "
                            f"{attempt} attempts: {e}",
                            partials=self._partial_results(plan, *host_hist()),
                            generations_done=done,
                        ) from e
                    # retry re-launches from the SAME (undonated) state
            if thin:
                gh = jnp.concatenate([gh, hg], axis=1)
                sh = jnp.concatenate([sh, hs], axis=1)
            else:
                gh = np.concatenate([gh, hg_np], axis=1)
                sh = np.concatenate([sh, hs_np], axis=1)
            state = new_state
            done += k_gens
            seg_idx += 1
            if (ck_dir is not None and done < G
                    and seg_idx % self.checkpoint_every == 0):
                host_state = GAState(*(self._sync(f) for f in state))
                hg_ck, hs_ck = host_hist()
                store.save(ck_dir, done,
                           {"state": host_state, "gh": hg_ck, "sh": hs_ck})
            if on_progress is not None and done < G:
                # mid-search anytime stream: best-so-far per request,
                # finalized over the history up to this boundary (the
                # final segment's snapshot IS the returned result)
                if thin:
                    snap = GAThin(*(self._sync(f) for f in
                                    ga_epilogue_batched(gh, sh, top_k=K)))
                    for i, res in enumerate(
                            _finalize_batch_thin(snap, reqs, partial=True)):
                        on_progress(i, res)
                else:
                    for i, r in enumerate(reqs):
                        on_progress(i, _finalize(
                            self._history_result(gh[i], sh[i]),
                            r.ws.names, _objective_label(r), r.top_k,
                            partial=True,
                        ))

        if ck_dir is not None:
            store.clear(ck_dir)
        if thin:
            # final epilogue rides back un-synced; harvest does the rest
            return PendingLaunch(
                plan=plan, thin=ga_epilogue_batched(gh, sh, top_k=K))
        results = [
            _finalize(
                self._history_result(gh[i], sh[i]),
                r.ws.names, _objective_label(r), r.top_k,
            )
            for i, r in enumerate(reqs)
        ]
        return PendingLaunch(plan=plan, results=results)

    def _request_seed_cdf(self, req: SearchRequest) -> np.ndarray:
        """One request's feasible-cell CDF for the direct seeder (host
        numpy, largest workload — the same crossbar-demand ``argmax`` rule
        as ``largest_workload_index``, mirrored in numpy).  Content-keyed
        like the padded tables: the 12ms-class 6-D mask + prefix-sum runs
        once per (workload set, tech, grid) and never on the warm path."""
        key = (req.ws.fingerprint(), req.tech, space.grid_token())
        hit = self._seed_cdfs.get(key)
        if hit is None:
            feats = np.asarray(req.ws.feats, np.float32)
            mask = np.asarray(req.ws.mask, bool)
            w = (feats[..., 1] * feats[..., 2] * feats[..., 5] * mask).sum(-1)
            demand = np.asarray(req.ws.tables(req.tech).demand)
            hit = self._seed_cdfs[key] = _seed_cells_cdf(
                demand[int(np.argmax(w))]
            )
        return hit

    def _stacked_seed_cdf(self, packed, tech):
        """(S, n_cells) device stack of the per-slot seed CDFs, cached on
        the packed fingerprints — a warm drain reuses the device array."""
        fps = tuple(r.ws.fingerprint() for r in packed)
        key = (fps, tech, space.grid_token())
        hit = self._stacked_seed_cdfs.get(key)
        if hit is None:
            hit = jnp.asarray(
                np.stack([self._request_seed_cdf(r) for r in packed])
            )
            self._stacked_seed_cdfs[key] = hit
        return hit

    def _init_populations(self, packed, k_seed, feats, mask, place,
                          tables=None, defer=False):
        """Initial populations for every slot: provided ``init_genomes``
        are copied in (the GA donates its input; callers keep theirs),
        missing ones run the batched largest-workload rejection seeder —
        one program either way, and seed failures only raise for slots
        that actually needed seeding.  With ``direct_seed`` and stacked
        tables at hand, the rejection rounds are replaced by the direct
        feasible-cell sampler (``_seed_direct``).

        Returns ``(init, check)``: ``check`` is ``None`` when feasibility
        was verified here, or (with ``defer``, all-seeded slots only) a
        callable that syncs the counts and raises the identical
        ``RuntimeError`` later — the pipelined dispatch path's way of
        keeping the seeder's count array off the critical host path."""
        r0 = packed[0]
        P = int(r0.pop_size)
        needs = [r.init_genomes is None for r in packed]
        if not any(needs):
            init = jnp.stack([jnp.asarray(r.init_genomes) for r in packed])
            return place(init, pop_dim=1), None
        if self.direct_seed and tables is not None:
            cdf6 = place(self._stacked_seed_cdf(packed, r0.tech))
            pools, counts = _seed_direct_batched_jit(
                k_seed, cdf6, pop_size=P, tech=r0.tech,
            )
        else:
            pools, counts = _seed_batched_jit(
                k_seed, feats, mask,
                pop_size=P, oversample=64, max_rounds=8, tech=r0.tech,
            )

        def check(counts=counts):
            c = self._sync(counts)
            for i, (r, need) in enumerate(zip(packed, needs)):
                if need and c[i] < P:
                    raise RuntimeError(
                        f"could not seed {P} valid designs for request {i} "
                        f"(workloads {r.ws.names}; {int(c[i])} found)"
                    )

        if all(needs):
            if defer:
                return place(pools, pop_dim=1), check
            check()
            return place(pools, pop_dim=1), None
        check()  # the override merge below syncs the pools anyway
        pools = np.array(pools)  # writable host copy for the overrides
        for i, r in enumerate(packed):
            if r.init_genomes is not None:
                pools[i] = np.asarray(r.init_genomes)
        return place(jnp.asarray(pools), pop_dim=1), None


_DEFAULT_ENGINE: Optional[SearchEngine] = None


def default_engine() -> SearchEngine:
    """Shared engine behind the ``core.search`` driver wrappers."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = SearchEngine()
    return _DEFAULT_ENGINE
