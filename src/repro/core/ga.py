"""Genetic algorithm (paper Sec. III-C) as a single XLA program.

pymoo-equivalent operators [33][34]:
  * binary-tournament parent selection,
  * simulated binary crossover  (p_c = 0.95, eta = 3  — the paper's values,
    "prioritizing exploration"),
  * polynomial mutation         (p_m = 1/n_genes, eta = 3),
  * (mu + lambda) elitist survival,
with the whole G-generation loop under ``lax.scan`` and the population
evaluated by the vectorized IMC cost model.  Population history (every
sampled design + score, per generation) is returned, matching the paper's
"best set selected from the stored population history".

One jit covers the entire experiment, not just one generation:

  * ``run_ga``          — eval -> select -> SBX -> mutate -> survive for all
    G generations under a single cached, donated ``jax.jit``.  Workload
    tensors enter as the traced ``ctx`` argument, so searching a different
    workload set of the same shape reuses the compiled program — no
    per-seed / per-workload retraces.
  * ``run_ga_batched``  — the same program ``vmap``-ed over a leading batch
    axis (workloads for ``separate_search``, seeds for the multi-seed
    benchmark drivers): B independent GAs in ONE XLA launch.

The evaluation callback is a parameter, so the same GA drives joint
(multi-workload) and separate (single-workload) searches, and the
population axis can be sharded over the mesh (``repro.core.distributed``).

Anytime / segmented execution: the scan carry is also exposed as a
first-class ``GAState`` (population, scores, master rng key, generation
counter), with ``init_ga_state`` / ``run_ga_segment`` (and their batched
twins) advancing k generations per launch through one cached jit.  The
segment derives its per-generation keys by splitting the SAME master key
into the run's full ``total_generations`` keys (a static count) and
dynamic-slicing out its window, so N segments of k generations are
bit-identical to one ``run_ga`` of N*k — the parity is asserted in
tests/test_ga_segments.py and as a hypothesis property.
"""
from __future__ import annotations

import os
import warnings
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import space
from repro.core.objectives import pareto_scalar

SBX_PROB = 0.95
SBX_ETA = 3.0
MUT_ETA = 3.0


def _env_flag(name: str, default: bool) -> bool:
    v = os.environ.get(name)
    if v is None:
        return default
    return v.strip().lower() not in ("", "0", "false", "off", "no")


def default_fused() -> bool:
    """Default for the ``fused`` GA knob: collapse the survival epilogue
    (total-order keying + argsort + score gather) into ONE combined
    ``lax.sort`` pass per generation.  On by default; set
    ``REPRO_GA_FUSED=0`` to fall back to the two-pass epilogue.  Both
    paths are bit-identical (the combined sort carries the scores through
    the exact permutation ``_survivor_indices`` computes) — the flag only
    trades program shape, never trajectories."""
    return _env_flag("REPRO_GA_FUSED", True)


def gen_kernel_enabled() -> bool:
    """Opt-in for the Pallas whole-generation kernel
    (``repro.kernels.ga_gen_step``).  Read at TRACE time: set
    ``REPRO_GA_KERNEL=1`` before the first GA launch of the process (a
    cached jit compiled with the flag off will not retrace).  Off by
    default — the lax fused path is faster on CPU hosts; the kernel
    targets TPU runs and is parity-pinned in interpret mode."""
    return _env_flag("REPRO_GA_KERNEL", False)


class GAResult(NamedTuple):
    genomes: jnp.ndarray  # (G+1, P, n) every generation incl. initial
    scores: jnp.ndarray  # (G+1, P)
    best_genome: jnp.ndarray  # (n,)
    best_score: jnp.ndarray  # ()


class GAState(NamedTuple):
    """The GA scan carry as a resumable value.  ``key`` is the MASTER run
    key (never advanced — segments index into ``split(key, total)`` by
    ``gen``), ``gen`` the number of generations already applied.  Batched
    variants carry a leading (B,) axis on every field."""

    genomes: jnp.ndarray  # (P, n) current population
    scores: jnp.ndarray  # (P,)
    key: jax.Array  # master PRNG key of the whole run
    gen: jnp.ndarray  # () int32, generations completed so far


class GAThin(NamedTuple):
    """The transfer-thin GA result: what the pipelined engine syncs to
    host instead of the full (G+1, P, n) history.  ``top_genomes`` /
    ``top_scores`` hold the best ``min(top_k, (G+1)*P)`` UNIQUE designs
    (uniqueness in decoded-grid-cell space, exactly like the host
    ``engine._top_unique``) best-first; slots past ``n_kept`` are padding
    (genome 0, score +inf).  ``convergence`` is the monotone best-so-far
    curve over generations.  Batched variants carry a leading (B,) axis."""

    top_genomes: jnp.ndarray  # (K, n) best-first unique designs
    top_scores: jnp.ndarray  # (K,)
    n_kept: jnp.ndarray  # () int32, valid entries in top_*
    convergence: jnp.ndarray  # (G+1,) running best score


class ParetoThin(NamedTuple):
    """The transfer-thin Pareto-front result: ``GAThin``'s twin for
    ``objective="pareto"`` requests.  ``top_genomes`` / ``top_vectors`` /
    ``top_scores`` hold the ``min(top_k, unique feasible cells)`` best
    front members in crowded order — ascending non-domination rank,
    descending crowding within a rank, flat history index as the final
    tie-break — deduped by decoded grid cell exactly like ``GAThin``.
    ``top_vectors`` carries each member's (max_W E, max_W L, A) triple;
    ``top_scores`` its scalar E*L*A proxy (bit-identical to the ``ela``
    objective on feasible rows).  ``convergence`` is the running best of
    that proxy.  Slots past ``n_kept`` are padding (genome 0, vector and
    score +inf).  Batched variants carry a leading (B,) axis."""

    top_genomes: jnp.ndarray  # (K, n) front members, crowded order
    top_vectors: jnp.ndarray  # (K, M) per-member (E, L, A)
    top_scores: jnp.ndarray  # (K,) scalar E*L*A proxy
    n_kept: jnp.ndarray  # () int32, valid entries in top_*
    convergence: jnp.ndarray  # (G+1,) running best scalar proxy


class _IgnoreCtx:
    """Adapt a ctx-less ``eval_fn(genomes)`` to the internal
    ``eval_fn(genomes, ctx)`` convention.  Hash/eq delegate to the wrapped
    callable so the cached jits below are NOT retraced when the same
    evaluation function is reused across calls."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable):
        self.fn = fn

    def __call__(self, genomes, ctx):
        return self.fn(genomes)

    def __hash__(self):
        return hash(self.fn)

    def __eq__(self, other):
        return isinstance(other, _IgnoreCtx) and self.fn == other.fn


def _survivor_indices(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Indices of the k lowest scores, best-first, replacing the float
    ``jnp.argsort(alls)[:P]`` of the survival step (the GA's post-PR-3
    hot spot: survival touches 2P candidates per generation, all through
    a stability-tracking float comparator).

    Implementation: one ``lax.sort`` over an integer key pair — the
    float32 score mapped to its total-order int32 (negatives: descending
    magnitude; both zero signs collapse to 0, matching comparison sorts),
    tie-broken by the candidate index.  Semantics are EXACTLY stable
    ascending argsort, asserted adversarially (duplicates, +inf
    infeasibles, mixed zero signs) in tests/test_search_batched.py.

    Why not ``lax.top_k`` on the negated scores: top_k breaks ties by
    index in a single shard, but a GSPMD-sharded population merges
    per-shard top-k lists and the cross-shard tie order (every infeasible
    candidate scores exactly +inf, so ties are the norm) diverges from
    the unsharded program — which would break the stack's bit-identical
    sharded-parity guarantee (tests/test_search_sharded.py).  A
    collision-free int64 composite would fix that but int64 is
    unavailable without global x64.  The unique integer key pair keeps
    the sort shard-stable, branchless, and stability-free instead."""
    n = scores.shape[-1]
    bits = jax.lax.bitcast_convert_type(scores.astype(jnp.float32), jnp.int32)
    order = jnp.where(
        bits < 0,
        -(bits & jnp.int32(0x7FFFFFFF)),  # negative floats: -magnitude
        bits,
    )
    iota = jax.lax.iota(jnp.int32, n)
    _, idx = jax.lax.sort((order, iota), num_keys=2, is_stable=False)
    return idx[:k]


def _fold_bits(x: jnp.ndarray) -> jnp.ndarray:
    """float32 -> total-order int32: the sign-folded sort key of
    ``_survivor_indices`` as a reusable helper (negative floats map to
    -magnitude, both zero signs collapse to 0, +inf stays below the
    0x7FFFFFFF sentinel).  Ascending int order == ascending float order
    for every non-NaN value."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return jnp.where(bits < 0, -(bits & jnp.int32(0x7FFFFFFF)), bits)


# --------------------------------------------- NSGA-II building blocks
def _dominance_rank(objs: jnp.ndarray) -> jnp.ndarray:
    """(N, M) objective vectors -> (N,) int32 non-domination rank
    (0 = the Pareto front), minimization on every component.

    Brute-force O(N^2) dominance mask + iterative front peeling — the
    survival step only ever ranks 2P candidates and the epilogue
    (G+1)*P, both small enough that the dense mask beats any clever
    sort-based front construction on this stack, and the same loop IS
    the reference algorithm the numpy oracle in tests/test_pareto.py
    replays verbatim.  Rows with a NaN component compare False both
    ways, so they neither dominate nor are dominated (callers mask
    non-finite rows out of any selection); all-+inf infeasible rows tie
    with each other and are dominated by every feasible design."""
    N = objs.shape[0]
    le = (objs[:, None, :] <= objs[None, :, :]).all(axis=-1)
    lt = (objs[:, None, :] < objs[None, :, :]).any(axis=-1)
    dom = le & lt  # dom[i, j]: i strictly dominates j

    def cond(state):
        return (state[0] < 0).any()

    def body(state):
        rank, r = state
        unassigned = rank < jnp.int32(0)
        blocked = (dom & unassigned[:, None]).any(axis=0)
        front = unassigned & ~blocked
        return jnp.where(front, r, rank), r + jnp.int32(1)

    rank, _ = jax.lax.while_loop(
        cond, body, (jnp.full((N,), -1, jnp.int32), jnp.int32(0)))
    return rank


def _crowding(objs: jnp.ndarray) -> jnp.ndarray:
    """(N, M) -> (N,) float32 crowding distance, computed as one
    ``lax.sort`` pass per objective over the sign-folded total-order
    int32 bits (``_fold_bits``).

    Distances are measured in folded-bit space rather than raw float
    space: the fold is strictly monotone, every +/-inf objective maps to
    a finite int32, and the neighbour/span arithmetic (cast to float32)
    therefore never produces the inf-inf NaNs the raw values would —
    which is what keeps the adversarial all-+inf-infeasible case exact.
    Each per-objective pass sorts ``(key, iota)`` (a unique total order,
    shard-stable like ``_survivor_indices``), gives the two boundary
    designs +inf distance, interior designs their normalized
    neighbour-gap, and scatter-adds through the permutation (unique
    indices, so the scatter is deterministic)."""
    N, M = objs.shape
    iota = jax.lax.iota(jnp.int32, N)
    total = jnp.zeros((N,), jnp.float32)
    for m in range(M):
        key = _fold_bits(objs[:, m])
        skey, perm = jax.lax.sort((key, iota), num_keys=2, is_stable=False)
        kf = skey.astype(jnp.float32)
        span = kf[-1] - kf[0]
        prev = jnp.concatenate([kf[:1], kf[:-1]])
        nxt = jnp.concatenate([kf[1:], kf[-1:]])
        d = jnp.where(span > 0, (nxt - prev) / span, jnp.float32(0.0))
        d = d.at[0].set(jnp.float32(jnp.inf))
        d = d.at[N - 1].set(jnp.float32(jnp.inf))
        total = total.at[perm].add(d)
    return total


def _crowded_order_keys(objs: jnp.ndarray):
    """The (rank, -crowding) survival sort keys as an int32 pair.
    Crowding is non-negative and never NaN, so its raw float32 bit
    pattern is monotone and negating it sorts descending — ascending
    ``(rank, ckey, index)`` is exactly NSGA-II's crowded comparison."""
    rank = _dominance_rank(objs)
    crowd = _crowding(objs)
    ckey = -jax.lax.bitcast_convert_type(crowd, jnp.int32)
    return rank, ckey


def _crowded_positions(objs: jnp.ndarray) -> jnp.ndarray:
    """(P, M) -> (P,) float32 crowded-comparison position (0 = best) of
    each design WITHOUT reordering the population — the tournament
    selection key for the initial generation (survival emits later
    populations already in crowded order, so their key is just iota)."""
    P = objs.shape[0]
    rank, ckey = _crowded_order_keys(objs)
    iota = jax.lax.iota(jnp.int32, P)
    _, _, perm = jax.lax.sort((rank, ckey, iota), num_keys=3, is_stable=False)
    pos = jnp.zeros((P,), jnp.int32).at[perm].set(iota)
    return pos.astype(jnp.float32)


def _tournament(key, scores: jnp.ndarray, n: int) -> jnp.ndarray:
    """Binary tournament: n winners (indices)."""
    P = scores.shape[0]
    idx = jax.random.randint(key, (n, 2), 0, P)
    a, b = idx[:, 0], idx[:, 1]
    return jnp.where(scores[a] <= scores[b], a, b)


def _pow_recip_eta1(x: jnp.ndarray, eta: float) -> jnp.ndarray:
    """``x ** (1 / (eta + 1))``.  The paper's eta = 3 turns the
    transcendental pow — the measured hot spot of SBX/mutation on CPU —
    into two sqrts (exponent 1/4)."""
    if eta == 3.0:
        return jnp.sqrt(jnp.sqrt(x))
    return x ** (1.0 / (eta + 1.0))


def _pow_eta1(x: jnp.ndarray, eta: float) -> jnp.ndarray:
    """``x ** (eta + 1)``; eta = 3 strength-reduces to two multiplies."""
    if eta == 3.0:
        x2 = x * x
        return x2 * x2
    return x ** (eta + 1.0)


def _sbx(key, p1: jnp.ndarray, p2: jnp.ndarray, eta: float, prob: float):
    """Simulated binary crossover on [0,1] genes (Deb & Agrawal)."""
    ku, kc, kg = jax.random.split(key, 3)
    u = jax.random.uniform(ku, p1.shape)
    beta = jnp.where(
        u <= 0.5,
        _pow_recip_eta1(2.0 * u, eta),
        _pow_recip_eta1(1.0 / (2.0 * (1.0 - u)), eta),
    )
    c1 = 0.5 * ((1 + beta) * p1 + (1 - beta) * p2)
    c2 = 0.5 * ((1 - beta) * p1 + (1 + beta) * p2)
    # per-pair: apply crossover with prob; per-gene: 50% exchange (pymoo)
    do_pair = jax.random.uniform(kc, (p1.shape[0], 1)) < prob
    do_gene = jax.random.uniform(kg, p1.shape) < 0.5
    use = do_pair & do_gene
    c1 = jnp.where(use, c1, p1)
    c2 = jnp.where(use, c2, p2)
    return jnp.clip(c1, 0.0, 1.0 - 1e-7), jnp.clip(c2, 0.0, 1.0 - 1e-7)


def _poly_mutation(key, x: jnp.ndarray, eta: float, prob: float):
    """Polynomial mutation (Deb), genes in [0,1]."""
    ku, kp = jax.random.split(key)
    u = jax.random.uniform(ku, x.shape)
    lo = x  # delta to bounds (range = 1)
    hi = 1.0 - x
    d1 = _pow_recip_eta1(2 * u + (1 - 2 * u) * _pow_eta1(1 - lo, eta), eta) - 1
    d2 = 1 - _pow_recip_eta1(2 * (1 - u) + (2 * u - 1) * _pow_eta1(1 - hi, eta), eta)
    delta = jnp.where(u <= 0.5, d1, d2)
    do = jax.random.uniform(kp, x.shape) < prob
    return jnp.clip(jnp.where(do, x + delta, x), 0.0, 1.0 - 1e-7)


def _make_gen_step(eval_fn, ctx, pop_size, n_genes, sbx_prob, sbx_eta, mut_eta,
                   fused=True, pareto=False):
    """The per-generation scan body, shared verbatim by the single-shot
    ``_ga_core`` and the segmented ``_segment_core`` so both paths compile
    the exact same generation program (the bit-parity guarantee).

    ``pareto=True`` swaps ONLY the fitness plumbing around the shared
    variation body (tournament -> SBX -> mutation, identical slicing of
    the same uniform block): the carry becomes ``(pop, objs (P, M),
    sel)`` where ``sel`` is the crowded-comparison position each
    tournament compares instead of a scalar score, ``eval_fn`` returns
    (P, M) objective vectors, and survival replaces the (mu + lambda)
    scalar sort with NSGA-II (rank, crowding) selection over the same
    combined-``lax.sort`` machinery — ``fused`` carries the objective
    columns through the sort, unfused gathers them by the sorted index;
    both apply the identical permutation.  The Pallas whole-generation
    kernel only understands scalar scores, so the kernel hook is gated
    off under ``pareto``.

    All per-generation randomness comes from ONE uniform block sliced at
    static offsets — the many small threefry launches of the original
    select/SBX/mutate splits carried fixed dispatch overheads that
    dominated the generation on CPU.  ``fused`` only switches the survival
    epilogue: ``True`` sorts ``(okey, iota, scores)`` in one combined
    ``lax.sort`` pass (the scores ride the key permutation, saving the
    separate score gather and its HBM round-trip); ``False`` keeps the
    two-pass ``_survivor_indices`` + gather.  The sort keys are a unique
    total order, so both epilogues apply the identical permutation —
    fused vs unfused is pinned bit-identical in tests/test_fused_gen.py.

    When ``REPRO_GA_KERNEL`` is set and the eval fn advertises table-gather
    support (``gen_kernel_tech``), the whole generation instead lowers to
    the Pallas kernel in ``repro.kernels.ga_gen_step`` (same bits, one
    kernel launch per generation)."""
    P = pop_size
    n = n_genes
    mut_prob = 1.0 / n
    # odd P: select one extra pair and truncate the children back to P, so
    # no parent slot is silently dropped and history shapes stay (G+1, P).
    n_pairs = (P + 1) // 2
    n_contest = 2 * n_pairs
    # slice offsets into the single per-generation uniform block
    o_t = 2 * n_contest          # tournament contestants (uniform -> int)
    o_u = o_t + n_pairs * n      # SBX spread factor u
    o_p = o_u + n_pairs          # SBX per-pair gate
    o_g = o_p + n_pairs * n      # SBX per-gene gate
    o_mu = o_g + P * n           # mutation u
    o_md = o_mu + P * n          # mutation per-gene gate
    tot = o_md

    if fused and not pareto and gen_kernel_enabled() \
            and getattr(eval_fn, "gen_kernel_tech", None) is not None:
        from repro.kernels.ga_gen_step import make_kernel_gen_step

        kgen = make_kernel_gen_step(
            eval_fn, ctx, pop_size=P, n_genes=n,
            sbx_prob=sbx_prob, sbx_eta=sbx_eta, mut_eta=mut_eta,
        )
        if kgen is not None:
            return kgen

    def gen(carry, k):
        if pareto:
            pop, objs, sel = carry
            scores = sel  # crowded-comparison position, lower = better
        else:
            pop, scores = carry
        u = jax.random.uniform(k, (tot,))
        # binary tournament: 2*n_pairs contests of 2 contestants each
        ti = (u[:o_t] * P).astype(jnp.int32)
        ca, cb = ti[:n_contest], ti[n_contest:]
        parents = jnp.where(scores[ca] <= scores[cb], ca, cb)
        p1 = pop[parents[:n_pairs]]
        p2 = pop[parents[n_pairs:]]
        # SBX from the pre-drawn uniforms
        ub = u[o_t:o_u].reshape(n_pairs, n)
        beta = jnp.where(
            ub <= 0.5,
            _pow_recip_eta1(2.0 * ub, sbx_eta),
            _pow_recip_eta1(1.0 / (2.0 * (1.0 - ub)), sbx_eta),
        )
        c1 = 0.5 * ((1 + beta) * p1 + (1 - beta) * p2)
        c2 = 0.5 * ((1 - beta) * p1 + (1 + beta) * p2)
        do_pair = u[o_u:o_p].reshape(n_pairs, 1) < sbx_prob
        do_gene = u[o_p:o_g].reshape(n_pairs, n) < 0.5
        use = do_pair & do_gene
        c1 = jnp.clip(jnp.where(use, c1, p1), 0.0, 1.0 - 1e-7)
        c2 = jnp.clip(jnp.where(use, c2, p2), 0.0, 1.0 - 1e-7)
        children = jnp.concatenate([c1, c2], axis=0)[:P]
        # polynomial mutation
        um = u[o_g:o_mu].reshape(P, n)
        lo = children  # delta to bounds (range = 1)
        hi = 1.0 - children
        d1 = _pow_recip_eta1(
            2 * um + (1 - 2 * um) * _pow_eta1(1 - lo, mut_eta), mut_eta) - 1
        d2 = 1 - _pow_recip_eta1(
            2 * (1 - um) + (2 * um - 1) * _pow_eta1(1 - hi, mut_eta), mut_eta)
        delta = jnp.where(um <= 0.5, d1, d2)
        do = u[o_mu:o_md].reshape(P, n) < mut_prob
        children = jnp.clip(
            jnp.where(do, children + delta, children), 0.0, 1.0 - 1e-7)
        child_scores = eval_fn(children, ctx)
        if pareto:
            # NSGA-II survival: (rank, crowding) over the 2P candidates
            allg = jnp.concatenate([pop, children], axis=0)
            allo = jnp.concatenate([objs, child_scores], axis=0)
            rank, ckey = _crowded_order_keys(allo)
            iota = jax.lax.iota(jnp.int32, 2 * P)
            if fused:
                cols = tuple(allo[:, m] for m in range(allo.shape[-1]))
                srt = jax.lax.sort((rank, ckey, iota) + cols, num_keys=3,
                                   is_stable=False)
                idx = srt[2]
                new_pop = allg[idx[:P]]
                new_objs = jnp.stack(srt[3:], axis=-1)[:P]
            else:
                _, _, idx = jax.lax.sort((rank, ckey, iota), num_keys=3,
                                         is_stable=False)
                new_pop, new_objs = allg[idx[:P]], allo[idx[:P]]
            # survival order == crowded order, so the next tournament's
            # selection key is just the position
            new_sel = jax.lax.iota(jnp.int32, P).astype(jnp.float32)
            return (new_pop, new_objs, new_sel), (children, child_scores)
        # (mu + lambda) elitist survival
        allg = jnp.concatenate([pop, children], axis=0)
        alls = jnp.concatenate([scores, child_scores], axis=0)
        if fused:
            bits = jax.lax.bitcast_convert_type(
                alls.astype(jnp.float32), jnp.int32)
            okey = jnp.where(bits < 0, -(bits & jnp.int32(0x7FFFFFFF)), bits)
            iota = jax.lax.iota(jnp.int32, 2 * P)
            _, idx, srt = jax.lax.sort(
                (okey, iota, alls), num_keys=2, is_stable=False)
            new_pop, new_scores = allg[idx[:P]], srt[:P]
        else:
            order = _survivor_indices(alls, P)
            new_pop, new_scores = allg[order], alls[order]
        return (new_pop, new_scores), (children, child_scores)

    return gen


def _ga_core(
    key, eval_fn, pop_size, generations, init_genomes, ctx,
    sbx_prob, sbx_eta, mut_eta, fused,
) -> GAResult:
    n = init_genomes.shape[-1]
    s0 = eval_fn(init_genomes, ctx)
    gen = _make_gen_step(eval_fn, ctx, pop_size, n, sbx_prob, sbx_eta, mut_eta,
                         fused=fused)
    keys = jax.random.split(key, generations)
    (pop, scores), (hist_g, hist_s) = jax.lax.scan(gen, (init_genomes, s0), keys)

    genomes_hist = jnp.concatenate([init_genomes[None], hist_g], axis=0)
    scores_hist = jnp.concatenate([s0[None], hist_s], axis=0)
    flat_s = scores_hist.reshape(-1)
    best = jnp.argmin(flat_s)
    return GAResult(
        genomes=genomes_hist,
        scores=scores_hist,
        best_genome=genomes_hist.reshape(-1, n)[best],
        best_score=flat_s[best],
    )


def _pareto_core(
    key, eval_fn, pop_size, generations, init_genomes, ctx,
    sbx_prob, sbx_eta, mut_eta, fused,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The Pareto twin of ``_ga_core``: same master-key stream, same
    variation, NSGA-II survival.  ``eval_fn(genomes, ctx)`` must return
    (P, M) objective vectors.  Returns the evaluated history
    ``(genomes_hist (G+1, P, n), objs_hist (G+1, P, M))``; front
    extraction is the epilogue's job (``_pareto_epilogue``)."""
    n = init_genomes.shape[-1]
    o0 = eval_fn(init_genomes, ctx)
    sel0 = _crowded_positions(o0)
    gen = _make_gen_step(eval_fn, ctx, pop_size, n, sbx_prob, sbx_eta,
                         mut_eta, fused=fused, pareto=True)
    keys = jax.random.split(key, generations)
    _, (hist_g, hist_o) = jax.lax.scan(gen, (init_genomes, o0, sel0), keys)
    genomes_hist = jnp.concatenate([init_genomes[None], hist_g], axis=0)
    objs_hist = jnp.concatenate([o0[None], hist_o], axis=0)
    return genomes_hist, objs_hist


def _segment_core(
    state, eval_fn, ctx, seg_gens, total_gens, sbx_prob, sbx_eta, mut_eta,
    fused,
) -> Tuple[GAState, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Advance ``seg_gens`` generations from ``state``.

    Key derivation: split the master key into the run's FULL
    ``total_gens`` keys (static, so the program caches per (seg, total)
    pair) and dynamic-slice this segment's window at the traced ``gen``
    counter.  ``jax.random.split`` is NOT prefix-stable across counts
    (``split(k, a)[:b] != split(k, b)``), so slicing the full split is the
    only derivation that reproduces ``run_ga``'s stream bit-exactly.
    """
    pop, scores = state.genomes, state.scores
    P, n = pop.shape[-2], pop.shape[-1]
    gen = _make_gen_step(eval_fn, ctx, P, n, sbx_prob, sbx_eta, mut_eta,
                         fused=fused)
    all_keys = jax.random.split(state.key, total_gens)
    keys = jax.lax.dynamic_slice_in_dim(all_keys, state.gen, seg_gens)
    (pop, scores), hist = jax.lax.scan(gen, (pop, scores), keys)
    new_state = GAState(
        genomes=pop, scores=scores, key=state.key,
        gen=state.gen + jnp.int32(seg_gens),
    )
    return new_state, hist


_GA_STATICS = ("eval_fn", "pop_size", "generations", "sbx_prob", "sbx_eta",
               "mut_eta", "fused")
_SEG_STATICS = ("eval_fn", "seg_gens", "total_gens", "sbx_prob", "sbx_eta",
                "mut_eta", "fused")


@partial(jax.jit, static_argnames=_GA_STATICS, donate_argnames=("init_genomes",))
def _run_ga_jit(key, init_genomes, ctx, *, eval_fn, pop_size, generations,
                sbx_prob, sbx_eta, mut_eta, fused):
    return _ga_core(key, eval_fn, pop_size, generations, init_genomes, ctx,
                    sbx_prob, sbx_eta, mut_eta, fused)


@partial(jax.jit, static_argnames=_GA_STATICS, donate_argnames=("init_genomes",))
def _run_ga_batched_jit(keys, init_genomes, ctx, *, eval_fn, pop_size,
                        generations, sbx_prob, sbx_eta, mut_eta, fused):
    def one(key, init, c):
        return _ga_core(key, eval_fn, pop_size, generations, init, c,
                        sbx_prob, sbx_eta, mut_eta, fused)

    ctx_axes = jax.tree_util.tree_map(lambda _: 0, ctx)
    return jax.vmap(one, in_axes=(0, 0, ctx_axes))(keys, init_genomes, ctx)


@partial(jax.jit, static_argnames=("eval_fn",))
def _init_state_jit(key, init_genomes, ctx, *, eval_fn):
    return GAState(
        genomes=init_genomes, scores=eval_fn(init_genomes, ctx),
        key=key, gen=jnp.int32(0),
    )


@partial(jax.jit, static_argnames=("eval_fn",))
def _init_state_batched_jit(keys, init_genomes, ctx, *, eval_fn):
    def one(key, init, c):
        return GAState(genomes=init, scores=eval_fn(init, c),
                       key=key, gen=jnp.int32(0))

    ctx_axes = jax.tree_util.tree_map(lambda _: 0, ctx)
    return jax.vmap(one, in_axes=(0, 0, ctx_axes))(keys, init_genomes, ctx)


@partial(jax.jit, static_argnames=_SEG_STATICS)
def _run_ga_segment_jit(state, ctx, *, eval_fn, seg_gens, total_gens,
                        sbx_prob, sbx_eta, mut_eta, fused):
    return _segment_core(state, eval_fn, ctx, seg_gens, total_gens,
                         sbx_prob, sbx_eta, mut_eta, fused)


@partial(jax.jit, static_argnames=_SEG_STATICS)
def _run_ga_batched_segment_jit(state, ctx, *, eval_fn, seg_gens, total_gens,
                                sbx_prob, sbx_eta, mut_eta, fused):
    def one(st, c):
        return _segment_core(st, eval_fn, c, seg_gens, total_gens,
                             sbx_prob, sbx_eta, mut_eta, fused)

    ctx_axes = jax.tree_util.tree_map(lambda _: 0, ctx)
    return jax.vmap(one, in_axes=(0, ctx_axes))(state, ctx)


def run_ga(
    key: jax.Array,
    eval_fn: Callable,
    *,
    pop_size: int,
    generations: int,
    init_genomes: jnp.ndarray,
    ctx: Any = None,
    sbx_prob: float = SBX_PROB,
    sbx_eta: float = SBX_ETA,
    mut_eta: float = MUT_ETA,
    fused: Optional[bool] = None,
) -> GAResult:
    """Run the GA as one cached jit.  Lower score = better.

    ``fused`` selects the combined-sort survival epilogue (bit-identical
    to the unfused one); ``None`` means ``default_fused()``.

    ``eval_fn(genomes (P, n)) -> scores (P,)`` when ``ctx`` is ``None``, or
    ``eval_fn(genomes, ctx) -> scores`` with ``ctx`` an arbitrary pytree of
    traced arrays (e.g. packed workload tensors).  Pass workload data via
    ``ctx`` and reuse the same ``eval_fn`` object to avoid retracing.

    ``init_genomes`` must already satisfy the paper's seeding rule (only
    designs that fit the largest workload — see ``search.seed_population``)
    and is DONATED to XLA: pass a copy if the caller needs it afterwards.
    """
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    with warnings.catch_warnings():
        # the full population history is returned, so no output ever aliases
        # the donated init buffer on CPU — silence only that diagnostic
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return _run_ga_jit(
            key, init_genomes, ctx,
            eval_fn=eval_fn, pop_size=int(pop_size), generations=int(generations),
            sbx_prob=float(sbx_prob), sbx_eta=float(sbx_eta), mut_eta=float(mut_eta),
            fused=bool(default_fused() if fused is None else fused),
        )


def run_ga_batched(
    keys: jnp.ndarray,
    eval_fn: Callable,
    *,
    pop_size: int,
    generations: int,
    init_genomes: jnp.ndarray,
    ctx: Any = None,
    sbx_prob: float = SBX_PROB,
    sbx_eta: float = SBX_ETA,
    mut_eta: float = MUT_ETA,
    fused: Optional[bool] = None,
) -> GAResult:
    """B independent GAs in one vmapped XLA program.

    ``keys`` is a stacked (B, 2) PRNG-key array, ``init_genomes`` is
    (B, P, n) (donated), and every leaf of ``ctx`` carries a leading batch
    axis — one slice per GA (per-workload tensors for ``separate_search``,
    broadcast copies for multi-seed search).  Returns a ``GAResult`` whose
    fields all have a leading B axis.  Per-batch-element results match
    ``run_ga(keys[b], ..., ctx=ctx[b])`` exactly (same RNG stream).
    """
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return _run_ga_batched_jit(
            keys, init_genomes, ctx,
            eval_fn=eval_fn, pop_size=int(pop_size), generations=int(generations),
            sbx_prob=float(sbx_prob), sbx_eta=float(sbx_eta), mut_eta=float(mut_eta),
            fused=bool(default_fused() if fused is None else fused),
        )


def init_ga_state(
    key: jax.Array, eval_fn: Callable, init_genomes: jnp.ndarray,
    ctx: Any = None,
) -> GAState:
    """Evaluate the seed population into a resumable ``GAState`` at
    generation 0.  ``key`` is the run's master key — the SAME key a
    single-shot ``run_ga`` of the whole budget would receive.  Unlike
    ``run_ga``, ``init_genomes`` is NOT donated (a failed segment retries
    from the last state, which must stay alive)."""
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    return _init_state_jit(key, init_genomes, ctx, eval_fn=eval_fn)


def init_ga_state_batched(
    keys: jnp.ndarray, eval_fn: Callable, init_genomes: jnp.ndarray,
    ctx: Any = None,
) -> GAState:
    """Batched ``init_ga_state``: (B, 2) keys, (B, P, n) seeds, batched
    ctx leaves -> a ``GAState`` with a leading (B,) axis on every field."""
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    return _init_state_batched_jit(keys, init_genomes, ctx, eval_fn=eval_fn)


def run_ga_segment(
    state: GAState,
    eval_fn: Callable,
    *,
    generations: int,
    total_generations: int,
    ctx: Any = None,
    sbx_prob: float = SBX_PROB,
    sbx_eta: float = SBX_ETA,
    mut_eta: float = MUT_ETA,
    fused: Optional[bool] = None,
) -> Tuple[GAState, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Advance ``generations`` (k) generations through ONE cached jit,
    returning ``(new_state, (children (k, P, n), child_scores (k, P)))``.

    ``total_generations`` is the run's full budget (static): the segment
    reproduces exactly the key window ``split(key, total)[gen:gen+k]``, so
    chaining segments covering the budget is bit-identical to a single
    ``run_ga(key, ..., generations=total_generations)`` — same history,
    same best.  Requires ``state.gen + k <= total_generations``.  Nothing
    is donated; a failed launch can re-run from the same ``state``.
    """
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    return _run_ga_segment_jit(
        state, ctx, eval_fn=eval_fn,
        seg_gens=int(generations), total_gens=int(total_generations),
        sbx_prob=float(sbx_prob), sbx_eta=float(sbx_eta), mut_eta=float(mut_eta),
        fused=bool(default_fused() if fused is None else fused),
    )


def run_ga_batched_segment(
    state: GAState,
    eval_fn: Callable,
    *,
    generations: int,
    total_generations: int,
    ctx: Any = None,
    sbx_prob: float = SBX_PROB,
    sbx_eta: float = SBX_ETA,
    mut_eta: float = MUT_ETA,
    fused: Optional[bool] = None,
) -> Tuple[GAState, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Batched ``run_ga_segment``: state fields and ctx leaves carry a
    leading (B,) axis; histories come back as (B, k, P, n) / (B, k, P).
    Per-element results match the unbatched segment (and therefore
    ``run_ga``) exactly."""
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    return _run_ga_batched_segment_jit(
        state, ctx, eval_fn=eval_fn,
        seg_gens=int(generations), total_gens=int(total_generations),
        sbx_prob=float(sbx_prob), sbx_eta=float(sbx_eta), mut_eta=float(mut_eta),
        fused=bool(default_fused() if fused is None else fused),
    )


# ------------------------------------------------------- thin epilogue
def _cell_codes(flat_g: jnp.ndarray) -> list:
    """Decoded-grid-cell identity of each design as 1-2 mixed-radix
    int32 codes (columns packed greedily while the radix product fits —
    the host's single int64 code is unavailable in-jit without global
    x64; SPACE_SIZE overflows int32 at grid density >= 2).  Two designs
    share a cell iff every code matches.  Shared by the scalar and
    Pareto thin epilogues so both dedup in exactly the host
    ``engine._top_unique`` cell space."""
    n = flat_g.shape[-1]
    idx = space.decode_indices(flat_g)  # (N, n) int32 grid cells
    sizes = [len(space.SPACE[f]) for f in space.FIELDS]
    codes, grp, prod = [], None, 1
    for j in range(n):
        if grp is None or prod * sizes[j] > 0x7FFFFFFF:
            grp, prod = jnp.int32(0), 1
            codes.append(None)
        grp = grp * jnp.int32(sizes[j]) + idx[:, j]
        prod *= sizes[j]
        codes[-1] = grp
    return codes


def _thin_epilogue(genomes_hist, scores_hist, top_k: int) -> GAThin:
    """In-jit top-k-unique + convergence over one slot's full history.

    Replicates the host finalize (``engine._top_unique`` semantics) ON
    DEVICE so the pipelined engine only syncs (K, n) genomes, (K,) scores
    and the (G+1,) convergence curve instead of the whole history.  The
    selection must be BIT-identical to the host path, which is:

      stable argsort by score -> first occurrence per decoded-grid-cell
      class -> classes ordered by that first occurrence -> finite filter
      -> truncate to k.

    Step by step:
      * key every design with the sign-folded total-order sort bits from
        ``_survivor_indices`` — for finite and +/-inf scores ascending
        (key, flat index) order IS numpy's stable score argsort (both
        zero signs collapse to 0 there too), and non-finite designs are
        masked out up front: a decoded cell evaluates to ONE score, so
        NaN/inf classes are wholly non-finite and dropped by the finite
        filter on both paths — pre-masking them changes nothing the
        selection ever reads.
      * ``top_k`` rounds of masked ``argmin`` (a ``fori_loop``; XLA's
        variadic comparator sorts are an order of magnitude slower on
        CPU than k vectorized min-reductions): ``jnp.argmin`` returns
        the FIRST index attaining the minimum, i.e. exactly the stable
        tie-break, so each round yields the best-ranked design whose
        grid cell has not been seen — and overwriting the key of every
        design decoding to that cell with the sentinel afterwards
        replays the host's first-occurrence-per-class dedup in rank
        order (the key array doubles as the mask: non-finite designs
        start at the sentinel).  Cells compare as 1-2 mixed-radix int32
        codes over the decoded index columns — the host's single int64
        code is unavailable in-jit without global x64 (SPACE_SIZE
        overflows int32 at grid density >= 2), so columns are packed
        greedily while the radix product fits.
      * ``n_kept`` counts the rounds that found a fresh finite class,
        i.e. ``min(#unique finite classes, top_k)`` — all any consumer
        reads.

    Padding rows (beyond ``n_kept``) are genome 0 / score +inf; the host
    slices them off before they reach a ``SearchResult``."""
    G1, P, n = genomes_hist.shape
    N = G1 * P
    flat_g = genomes_hist.reshape(N, n)
    flat_s = scores_hist.reshape(N)
    fold = _fold_bits(flat_s)
    codes = _cell_codes(flat_g)
    k = min(int(top_k), N)
    sentinel = jnp.int32(0x7FFFFFFF)  # > every folded finite/inf key

    def pick(i, carry):
        okey, top_g, top_s, cnt = carry
        j = jnp.argmin(okey)
        valid = okey[j] < sentinel
        top_g = top_g.at[i].set(jnp.where(valid, flat_g[j], jnp.float32(0.0)))
        top_s = top_s.at[i].set(jnp.where(valid, flat_s[j], jnp.float32(jnp.inf)))
        same = codes[0] == codes[0][j]
        for c in codes[1:]:
            same = same & (c == c[j])
        okey = jnp.where(same, sentinel, okey)
        return okey, top_g, top_s, cnt + valid.astype(jnp.int32)

    _, top_g, top_s, n_kept = jax.lax.fori_loop(0, k, pick, (
        jnp.where(jnp.isfinite(flat_s), fold, sentinel),
        jnp.zeros((k, n), flat_g.dtype),
        jnp.full((k,), jnp.inf, jnp.float32),
        jnp.int32(0),
    ))
    conv = jax.lax.cummin(jnp.min(scores_hist, axis=1))
    return GAThin(top_genomes=top_g, top_scores=top_s, n_kept=n_kept,
                  convergence=conv)


@partial(jax.jit, static_argnames=_GA_STATICS + ("top_k",),
         donate_argnames=("init_genomes",))
def _run_ga_batched_thin_jit(keys, init_genomes, ctx, *, eval_fn, pop_size,
                             generations, sbx_prob, sbx_eta, mut_eta, fused,
                             top_k):
    def one(key, init, c):
        ga = _ga_core(key, eval_fn, pop_size, generations, init, c,
                      sbx_prob, sbx_eta, mut_eta, fused)
        return _thin_epilogue(ga.genomes, ga.scores, top_k)

    ctx_axes = jax.tree_util.tree_map(lambda _: 0, ctx)
    return jax.vmap(one, in_axes=(0, 0, ctx_axes))(keys, init_genomes, ctx)


@partial(jax.jit, static_argnames=("top_k",))
def _epilogue_batched_jit(genomes_hist, scores_hist, *, top_k):
    return jax.vmap(
        lambda g, s: _thin_epilogue(g, s, top_k)
    )(genomes_hist, scores_hist)


def run_ga_batched_thin(
    keys: jnp.ndarray,
    eval_fn: Callable,
    *,
    pop_size: int,
    generations: int,
    init_genomes: jnp.ndarray,
    top_k: int,
    ctx: Any = None,
    sbx_prob: float = SBX_PROB,
    sbx_eta: float = SBX_ETA,
    mut_eta: float = MUT_ETA,
    fused: Optional[bool] = None,
) -> GAThin:
    """``run_ga_batched`` with the thin epilogue fused onto the SAME
    program: one donated jit runs B GAs and reduces each full history to
    its ``GAThin`` on device, so the host never transfers the (B, G+1,
    P, n) history.  The selected designs/scores/convergence are
    bit-identical to finalizing ``run_ga_batched``'s history on host
    (tests/test_pipelined.py).  The history itself is unavailable —
    callers that need ``GAResult`` (result-cache writes, fault partials)
    must use the history path."""
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    with warnings.catch_warnings():
        # the thin outputs are far smaller than the donated seed buffer
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return _run_ga_batched_thin_jit(
            keys, init_genomes, ctx,
            eval_fn=eval_fn, pop_size=int(pop_size),
            generations=int(generations), sbx_prob=float(sbx_prob),
            sbx_eta=float(sbx_eta), mut_eta=float(mut_eta),
            fused=bool(default_fused() if fused is None else fused),
            top_k=int(top_k),
        )


def ga_epilogue_batched(
    genomes_hist: jnp.ndarray, scores_hist: jnp.ndarray, *, top_k: int,
) -> GAThin:
    """Standalone batched thin epilogue over accumulated histories
    ((B, G+1, P, n) / (B, G+1, P), host or device): what the segmented
    engine runs on its device-resident history to build streaming
    snapshots and the final result without syncing the history itself."""
    return _epilogue_batched_jit(
        jnp.asarray(genomes_hist), jnp.asarray(scores_hist),
        top_k=int(top_k),
    )


# ---------------------------------------------------- pareto epilogue
def _pareto_epilogue(genomes_hist, objs_hist, top_k: int) -> ParetoThin:
    """In-jit k-best-front-members + convergence over one slot's full
    evaluated history — the Pareto twin of ``_thin_epilogue``, and the
    single selection every execution mode shares (sequential engines run
    it on the device history, pipelined engines fuse it onto the GA
    program), which is what makes sequential/pipelined fronts
    bit-identical by construction.

    Selection order: ascending non-domination rank over ALL (G+1)*P
    evaluated designs (``_dominance_rank`` — the O(N^2) mask the numpy
    oracle replays), descending crowding within a rank (``_crowding``,
    folded-bit sort passes), flat history index as the final tie-break.
    Non-finite rows (infeasible all-+inf, NaN-guarded evals) are masked
    to the sentinel before selection — same role as the finite filter of
    the scalar path.  ``top_k`` masked-argmin rounds then pick the best
    unseen design and retire its whole decoded grid cell
    (``_cell_codes``), exactly the scalar epilogue's
    first-occurrence-per-class dedup but in crowded order, so a cell's
    representative is its best-crowded occurrence.  ``n_kept`` counts
    the fresh feasible cells found, i.e. ``min(#unique feasible cells,
    top_k)`` — with ``top_k`` large enough the picks cover the entire
    first front (and only then spill into rank 1, 2, ...).

    ``convergence`` tracks the running best scalar E*L*A proxy
    (``objectives.pareto_scalar``), bit-identical to an ``ela`` curve
    over the same designs.  Padding rows are genome 0 / vector + score
    +inf; the host slices them off."""
    G1, P, n = genomes_hist.shape
    M = objs_hist.shape[-1]
    N = G1 * P
    flat_g = genomes_hist.reshape(N, n)
    flat_o = objs_hist.reshape(N, M)
    flat_s = pareto_scalar(flat_o)
    rank, ckey = _crowded_order_keys(flat_o)
    feas = jnp.isfinite(flat_o).all(axis=-1)
    iota = jax.lax.iota(jnp.int32, N)
    _, _, perm = jax.lax.sort((rank, ckey, iota), num_keys=3, is_stable=False)
    pos = jnp.zeros((N,), jnp.int32).at[perm].set(iota)
    sentinel = jnp.int32(0x7FFFFFFF)  # > every position (N << 2^31)
    codes = _cell_codes(flat_g)
    k = min(int(top_k), N)

    def pick(i, carry):
        okey, top_g, top_v, top_s, cnt = carry
        j = jnp.argmin(okey)
        valid = okey[j] < sentinel
        top_g = top_g.at[i].set(jnp.where(valid, flat_g[j], jnp.float32(0.0)))
        top_v = top_v.at[i].set(
            jnp.where(valid, flat_o[j], jnp.float32(jnp.inf)))
        top_s = top_s.at[i].set(
            jnp.where(valid, flat_s[j], jnp.float32(jnp.inf)))
        same = codes[0] == codes[0][j]
        for c in codes[1:]:
            same = same & (c == c[j])
        okey = jnp.where(same, sentinel, okey)
        return okey, top_g, top_v, top_s, cnt + valid.astype(jnp.int32)

    _, top_g, top_v, top_s, n_kept = jax.lax.fori_loop(0, k, pick, (
        jnp.where(feas, pos, sentinel),
        jnp.zeros((k, n), flat_g.dtype),
        jnp.full((k, M), jnp.inf, jnp.float32),
        jnp.full((k,), jnp.inf, jnp.float32),
        jnp.int32(0),
    ))
    conv = jax.lax.cummin(jnp.min(flat_s.reshape(G1, P), axis=1))
    return ParetoThin(top_genomes=top_g, top_vectors=top_v, top_scores=top_s,
                      n_kept=n_kept, convergence=conv)


_PARETO_STATICS = _GA_STATICS + ("top_k", "history")


@partial(jax.jit, static_argnames=_PARETO_STATICS,
         donate_argnames=("init_genomes",))
def _run_pareto_batched_jit(keys, init_genomes, ctx, *, eval_fn, pop_size,
                            generations, sbx_prob, sbx_eta, mut_eta, fused,
                            top_k, history):
    def one(key, init, c):
        gh, oh = _pareto_core(key, eval_fn, pop_size, generations, init, c,
                              sbx_prob, sbx_eta, mut_eta, fused)
        thin = _pareto_epilogue(gh, oh, top_k)
        if history:
            return gh, oh, thin
        return thin

    ctx_axes = jax.tree_util.tree_map(lambda _: 0, ctx)
    return jax.vmap(one, in_axes=(0, 0, ctx_axes))(keys, init_genomes, ctx)


@partial(jax.jit, static_argnames=("top_k",))
def _pareto_epilogue_batched_jit(genomes_hist, objs_hist, *, top_k):
    return jax.vmap(
        lambda g, o: _pareto_epilogue(g, o, top_k)
    )(genomes_hist, objs_hist)


def run_pareto_batched(
    keys: jnp.ndarray,
    eval_fn: Callable,
    *,
    pop_size: int,
    generations: int,
    init_genomes: jnp.ndarray,
    top_k: int,
    ctx: Any = None,
    sbx_prob: float = SBX_PROB,
    sbx_eta: float = SBX_ETA,
    mut_eta: float = MUT_ETA,
    fused: Optional[bool] = None,
    history: bool = False,
):
    """B independent NSGA-II Pareto searches in one vmapped, donated XLA
    program, front extraction fused on device.

    ``eval_fn(genomes, ctx)`` must return (P, M) minimization objective
    vectors (``objectives.make_pareto_objective``).  With
    ``history=False`` (the pipelined engine) only the batched
    ``ParetoThin`` is returned/synced; ``history=True`` (sequential
    engines, which also need the history for result caching and
    partials) additionally returns ``(genomes_hist (B, G+1, P, n),
    objs_hist (B, G+1, P, M))``.  Both run the IDENTICAL program prefix
    and epilogue, so the selected front members are bit-identical across
    the two modes, and across ``fused``/unfused survival (same sort
    permutation — tests/test_pareto.py)."""
    if ctx is None and not isinstance(eval_fn, _IgnoreCtx):
        eval_fn = _IgnoreCtx(eval_fn)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return _run_pareto_batched_jit(
            keys, init_genomes, ctx,
            eval_fn=eval_fn, pop_size=int(pop_size),
            generations=int(generations), sbx_prob=float(sbx_prob),
            sbx_eta=float(sbx_eta), mut_eta=float(mut_eta),
            fused=bool(default_fused() if fused is None else fused),
            top_k=int(top_k), history=bool(history),
        )


def pareto_epilogue_batched(
    genomes_hist: jnp.ndarray, objs_hist: jnp.ndarray, *, top_k: int,
) -> ParetoThin:
    """Standalone batched Pareto epilogue over accumulated histories
    ((B, G+1, P, n) / (B, G+1, P, M), host or device) — the reference
    entry point the oracle-parity tests drive directly."""
    return _pareto_epilogue_batched_jit(
        jnp.asarray(genomes_hist), jnp.asarray(objs_hist),
        top_k=int(top_k),
    )
