"""Objective functions f(E_w, L_w, A) s.t. A <= A_constr  (paper Eq. 1).

The *joint* part: metrics reduce with `max` over the workload axis — one
chip must serve the worst-case workload well.  Failed/invalid designs score
+inf (the GA can sample them; they never survive).

Four objective families (paper Fig. 3 evaluates several):
  ela   : max(E) * max(L) * A           (energy-latency-area, the headline)
  edp   : max(E) * max(L)               (energy-delay product)
  e     : max(E)
  l     : max(L)
all under the area constraint.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.imc.cost import EvalResult

INF = jnp.float32(jnp.inf)


def _joint(x: jnp.ndarray) -> jnp.ndarray:
    """(P, W) -> (P,) worst-case over the workload set."""
    return x.max(axis=-1)


@jax.custom_batching.custom_vmap
def _pin(e, l, a):
    """``lax.optimization_barrier`` with a vmap rule (the primitive has
    none): pins the metric triple as standalone buffers so neither fusion
    nor GSPMD sharding propagation rewrites the upstream cost model to
    suit the consumers.  Under vmap the barrier simply applies to the
    batched arrays — the pinning is exactly as effective."""
    return jax.lax.optimization_barrier((e, l, a))


@_pin.def_vmap
def _pin_vmap(axis_size, in_batched, e, l, a):
    return jax.lax.optimization_barrier((e, l, a)), tuple(in_batched)


def make_objective(kind: str, area_constr_mm2: float = 150.0) -> Callable[[EvalResult], jnp.ndarray]:
    """Score (lower is better), +inf when infeasible."""

    def score(r: EvalResult) -> jnp.ndarray:
        e = _joint(r.energy_pj)
        l = _joint(r.latency_ns)
        a = r.area_mm2
        if kind == "ela":
            s = e * l * a
        elif kind == "edp":
            s = e * l
        elif kind == "e":
            s = e
        elif kind == "l":
            s = l
        else:
            raise ValueError(kind)
        feasible = r.fits.all(axis=-1) & r.valid & (a <= area_constr_mm2)
        return jnp.where(feasible, s, INF)

    score.kind = kind
    score.area_constr = area_constr_mm2
    return score


OBJECTIVES = ("ela", "edp", "e", "l")

# the Pareto-front objective family (NSGA-II survival in core.ga): not a
# scalar kind — requests select it with objective="pareto" and plan into
# their own signature group (core.engine)
PARETO = "pareto"

# component order of the Pareto objective vector: (max_W E, max_W L, A)
PARETO_AXES = ("e", "l", "a")
N_PARETO = len(PARETO_AXES)


def make_pareto_objective() -> Callable:
    """Vector objective for Pareto-front search: per design the
    minimization triple ``(max_W E, max_W L, A)`` with a *traced* area
    constraint (a () float32 ctx leaf under vmap, so mixed-area requests
    pack into one XLA program exactly like ``make_indexed_objective``).

    Infeasible designs (doesn't fit / invalid / over area) get +inf on
    EVERY component: they dominate nothing, are dominated by any feasible
    design, and tie with each other — the vector twin of the scalar
    families' +inf encoding.  The scalar proxy ``e*l*a`` of a feasible
    row is bit-identical to the ``ela`` objective (same products, same
    association), which is what convergence curves and NaN guards read."""

    def score(r: EvalResult, area_constr: jnp.ndarray) -> jnp.ndarray:
        # Barrier the metric triple BEFORE the NSGA-II consumers see it:
        # the dominance pass broadcasts objs across the population dim
        # (P x P), and without the barrier GSPMD answers that all-to-all
        # consumer by resharding the upstream cost-model reductions —
        # ULP-shifting E relative to the unsharded program (the same
        # failure mode the trailing-stack note in make_indexed_objective
        # documents).  The barrier pins e/l/a as standalone buffers, so
        # the cost model compiles identically with and without a mesh.
        e, l, a = _pin(_joint(r.energy_pj), _joint(r.latency_ns), r.area_mm2)
        feasible = r.fits.all(axis=-1) & r.valid & (a <= area_constr)
        objs = jnp.stack([e, l, a], axis=-1)  # (P, N_PARETO)
        return jnp.where(feasible[..., None], objs, INF)

    return score


def pareto_scalar(objs: jnp.ndarray) -> jnp.ndarray:
    """Scalar E*L*A proxy of a (..., N_PARETO) objective-vector array —
    bit-identical to the ``ela`` objective on feasible rows, +inf on
    infeasible (all-inf) rows.  Used for convergence curves, NaN guards
    and the ``top_scores`` of Pareto results."""
    return objs[..., 0] * objs[..., 1] * objs[..., 2]

# exponents (w_E, w_L, w_A) reproducing each kind as E^wE * L^wL * A^wA
OBJECTIVE_WEIGHTS: Dict[str, tuple] = {
    "ela": (1.0, 1.0, 1.0),
    "edp": (1.0, 1.0, 0.0),
    "e": (1.0, 0.0, 0.0),
    "l": (0.0, 1.0, 0.0),
}

# kind -> traced selector index for make_indexed_objective
OBJECTIVE_INDEX: Dict[str, int] = {k: i for i, k in enumerate(OBJECTIVES)}


def make_indexed_objective() -> Callable:
    """Objective selected by a *traced* kind index and area constraint.

    Every branch computes exactly the expression of the matching
    ``make_objective`` kind (same products, same association), so scores
    are BIT-IDENTICAL to the static string path per element — unlike the
    exponent-weighted form, whose ``x ** 1.0`` need not be bitwise ``x``.
    This is the objective the DSE engine (``core.engine``) packs
    heterogeneous requests with: one XLA program covers every kind in
    ``OBJECTIVES`` *and* every area constraint, because both enter as
    per-element data (a () int32 and a () float32 ctx leaf under vmap)."""

    def score(r: EvalResult, kind_index: jnp.ndarray,
              area_constr: jnp.ndarray) -> jnp.ndarray:
        e = _joint(r.energy_pj)
        l = _joint(r.latency_ns)
        a = r.area_mm2
        # Stack the four kind expressions (each computed exactly as its
        # static ``make_objective`` branch) on a TRAILING axis and gather
        # by the traced index.  The select form matters empirically:
        # elementwise selects (where-chains, masked-factor products,
        # ``select_n``) let XLA fuse the objective into the in-scan
        # cost-model graph, whose contraction choices shift with the
        # vmapped batch size — costing the packed program its bit-parity
        # with the per-request one — while a LEADING-axis stack gathers
        # across the population dim, so GSPMD reshards the upstream
        # reductions — costing the sharded run its bit-parity with the
        # unsharded one.  Trailing-axis stack + gather keeps the branch
        # values as standalone buffers (codegen pinned across batch
        # sizes) without touching the population dim's partitioning
        # (tests/test_engine.py + tests/test_search_sharded.py cover the
        # two directions).
        branches = jnp.stack([e * l * a, e * l, e, l], axis=-1)  # OBJECTIVES order
        s = branches[..., kind_index]
        feasible = r.fits.all(axis=-1) & r.valid & (a <= area_constr)
        return jnp.where(feasible, s, INF)

    return score


def make_weighted_objective(area_constr_mm2: float = 150.0) -> Callable:
    """Exponent-weighted objective s = max(E)^wE * max(L)^wL * A^wA with a
    *traced* weight vector, covering every kind in ``OBJECTIVES``.  Lets a
    vmapped search batch mix objective families inside ONE XLA program
    (``core.search.batched_search(obj_weights=...)``) instead of retracing
    the GA once per objective."""

    def score(r: EvalResult, weights: jnp.ndarray) -> jnp.ndarray:
        e = _joint(r.energy_pj)
        l = _joint(r.latency_ns)
        a = r.area_mm2
        s = e ** weights[0] * l ** weights[1] * a ** weights[2]
        feasible = r.fits.all(axis=-1) & r.valid & (a <= area_constr_mm2)
        return jnp.where(feasible, s, INF)

    score.area_constr = area_constr_mm2
    return score


def rescore(r: EvalResult, kind: str, area_constr_mm2: float = 150.0) -> jnp.ndarray:
    """Re-evaluate stored designs under a different objective/workload set."""
    return make_objective(kind, area_constr_mm2)(r)
