"""Joint / separate hardware-workload search drivers (paper Sec. III-A, IV).

``joint_search``    — one GA over the full workload set (the paper's method):
                      objective reduces metrics with max over workloads.
``separate_search`` — the baseline: one GA per single workload.
``rescore_designs`` — re-evaluate any designs on any workload set/objective
                      (used for the paper's "failed designs" analysis and
                      for fair joint-vs-separate comparison).
``seed_population`` — initial population sampling with the paper's rule:
                      configs that cannot fit the *largest* workload are
                      discarded up front.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space
from repro.core.ga import GAResult, run_ga
from repro.core.objectives import make_objective
from repro.imc.cost import DesignArrays, EvalResult, evaluate_designs
from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet


@dataclasses.dataclass
class SearchResult:
    workload_names: Tuple[str, ...]
    objective: str
    ga: GAResult
    top_designs: List[Dict[str, float]]  # decoded, deduped, best-first
    top_scores: np.ndarray
    top_genomes: np.ndarray
    convergence: np.ndarray  # best-so-far score per generation


def make_eval_fn(
    ws: WorkloadSet,
    objective: str,
    area_constr: float,
    tech: TechParams = TECH,
    *,
    backend: str = "jnp",
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """backend: "jnp" (portable) or "pallas" (the imc_eval TPU kernel;
    interpret-mode on CPU — numerically identical, see tests)."""
    obj = make_objective(objective, area_constr)

    if backend == "pallas":
        from repro.kernels.imc_eval.ops import evaluate_designs_kernel

        def eval_fn(genomes: jnp.ndarray) -> jnp.ndarray:
            return obj(evaluate_designs_kernel(space.decode(genomes), ws, tech))

        return eval_fn

    def eval_fn(genomes: jnp.ndarray) -> jnp.ndarray:
        return obj(evaluate_designs(space.decode(genomes), ws, tech))

    return eval_fn


def largest_workload_index(ws: WorkloadSet) -> int:
    """Largest = most crossbar demand at a reference design (most weights)."""
    weights = (ws.feats[..., 1] * ws.feats[..., 2] * ws.feats[..., 5] * ws.mask).sum(-1)
    return int(jnp.argmax(weights))


def seed_population(
    key: jax.Array,
    ws: WorkloadSet,
    pop_size: int,
    *,
    tech: TechParams = TECH,
    oversample: int = 64,
    max_rounds: int = 8,
) -> jnp.ndarray:
    """Random init; designs failing the largest workload (or V/f-invalid)
    are discarded (paper Sec. III-C)."""
    wl = ws.subset([largest_workload_index(ws)])
    found: List[np.ndarray] = []
    for _ in range(max_rounds):
        key, k = jax.random.split(key)
        cand = space.random_genomes(k, pop_size * oversample)
        r = evaluate_designs(space.decode(cand), wl, tech)
        ok = np.asarray(r.fits[:, 0] & r.valid)
        found.append(np.asarray(cand)[ok])
        if sum(len(f) for f in found) >= pop_size:
            break
    pool = np.concatenate(found, axis=0)
    if len(pool) < pop_size:
        raise RuntimeError(
            f"could not seed {pop_size} valid designs ({len(pool)} found); "
            "largest workload may not fit anywhere in the search space"
        )
    return jnp.asarray(pool[:pop_size])


def _top_unique(
    genomes: np.ndarray, scores: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Best-k designs, unique in *decoded grid index* space."""
    idx = np.asarray(space.decode_indices(jnp.asarray(genomes)))
    order = np.argsort(scores)
    seen = set()
    keep = []
    for i in order:
        if not np.isfinite(scores[i]):
            break
        t = tuple(idx[i])
        if t in seen:
            continue
        seen.add(t)
        keep.append(i)
        if len(keep) == k:
            break
    keep = np.array(keep, np.int64) if keep else np.zeros((0,), np.int64)
    return genomes[keep], scores[keep]


def run_search(
    key: jax.Array,
    ws: WorkloadSet,
    *,
    objective: str = "ela",
    area_constr: float = 150.0,
    pop_size: int = 40,
    generations: int = 10,
    top_k: int = 10,
    init_genomes: Optional[jnp.ndarray] = None,
    tech: TechParams = TECH,
    backend: str = "jnp",
) -> SearchResult:
    k_seed, k_ga = jax.random.split(key)
    if init_genomes is None:
        init_genomes = seed_population(k_seed, ws, pop_size, tech=tech)
    eval_fn = make_eval_fn(ws, objective, area_constr, tech, backend=backend)
    ga = run_ga(
        k_ga,
        eval_fn,
        pop_size=pop_size,
        generations=generations,
        init_genomes=init_genomes,
    )
    G1, P, n = ga.genomes.shape
    flat_g = np.asarray(ga.genomes).reshape(-1, n)
    flat_s = np.asarray(ga.scores).reshape(-1)
    top_g, top_s = _top_unique(flat_g, flat_s, top_k)
    designs = space.decode(jnp.asarray(top_g)) if len(top_g) else None
    top_designs = [
        space.design_dict(designs, i) for i in range(len(top_g))
    ] if designs is not None else []
    conv = np.minimum.accumulate(np.asarray(ga.scores).min(axis=1))
    return SearchResult(
        workload_names=ws.names,
        objective=objective,
        ga=ga,
        top_designs=top_designs,
        top_scores=top_s,
        top_genomes=top_g,
        convergence=conv,
    )


def joint_search(key, ws: WorkloadSet, **kw) -> SearchResult:
    return run_search(key, ws, **kw)


def separate_search(
    key, ws: WorkloadSet, *, share_init: Optional[jnp.ndarray] = None, **kw
) -> Dict[str, SearchResult]:
    """One single-workload GA per workload (the paper's baseline)."""
    out = {}
    for i, name in enumerate(ws.names):
        key, k = jax.random.split(key)
        out[name] = run_search(
            k, ws.subset([i]), init_genomes=share_init, **kw
        )
    return out


def rescore_designs(
    genomes: np.ndarray,
    ws: WorkloadSet,
    *,
    objective: str = "ela",
    area_constr: float = 150.0,
    tech: TechParams = TECH,
) -> Tuple[np.ndarray, EvalResult]:
    """Scores + full metrics of given designs on a (possibly different)
    workload set — the paper's cross-evaluation."""
    g = jnp.asarray(genomes)
    r = evaluate_designs(space.decode(g), ws, tech)
    s = make_objective(objective, area_constr)(r)
    return np.asarray(s), r
