"""Joint / separate hardware-workload search drivers (paper Sec. III-A, IV).

Since the engine refactor every driver here is a THIN wrapper: it builds
``core.engine.SearchRequest``s and hands them to the shared
``core.engine.SearchEngine``, which plans (groups by traced-shape
signature, slot-packs) and executes them as cached one-jit vmapped GA
programs.  The layering:

    serve/dse.py        continuous-batching queue over heterogeneous
                        requests (submit / step / drain / stream)
    core/engine.py      SearchRequest -> plan_batch -> SearchEngine.execute
                        (ctx/seeding/finalize plumbing lives HERE, once)
    core/ga.py          the one-jit, donated, vmapped GA
    imc/{cost,tables}   dense oracle + factorized table backends

Drivers (public API unchanged from the pre-engine stack):

``joint_search``/``run_search`` — one GA over the full workload set (the
                           paper's method): objective reduces metrics with
                           max over workloads.  One single-slot plan.
``separate_search``      — the baseline: one GA per single workload.  By
                           default all W GAs run as ONE plan
                           (``batched=False`` keeps the sequential
                           reference path; both produce identical scores).
``batched_search``       — B independent GAs (any mix of workload sets /
                           seeds / objective weights) as one plan.
``joint_search_batched`` — multi-seed joint search on top of it.
``rescore_designs``      — re-evaluate any designs on any workload set or
                           objective (the paper's "failed designs"
                           analysis).
``seed_population``      — the paper's seeding rule (configs that cannot
                           fit the *largest* workload are discarded) as a
                           jitted rejection sampler (lives in the engine).

Everything workload-dependent enters the jitted programs as traced array
arguments (string objectives become a traced kind index + area through
``objectives.make_indexed_objective``), and the evaluation callbacks are
cached per (objective-mode, tech, backend) — repeated searches of the
same shape never retrace, and heterogeneous batches (mixed workload
subsets, objectives, areas, seeds) share ONE program.  The batched
drivers take ``mesh=`` (``launch.mesh.make_search_mesh``) to lay the B
independent GAs out over a 2-D (search, population) device mesh — see
``core.distributed`` — with bit-identical scores.

Three evaluation backends (``backend=``): ``"jnp"`` (dense (P, W, L)
oracle), ``"pallas"`` (the imc_eval TPU kernel), and ``"table"`` — the
factorized cost model (``imc.tables``): the layer axis is reduced once per
workload set into grid tables that travel through the traced ``ctx``, and
every per-generation evaluation is O(W) gathers per design, independent of
workload depth L.  Because the table ctx is layer-free, the engine packs
requests over DIFFERENT workload sets into one program (zero-padded table
rows are exactly neutral under the max-reduction) — the basis of the DSE
service (``serve.dse``), which drains hundreds of heterogeneous requests
through a handful of compiled programs (tests/test_engine.py asserts
bit-identical parity with per-request ``run_search``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space
from repro.core.engine import (  # noqa: F401 — re-exported public/test API
    BACKENDS,
    EngineFault,
    NonFiniteScoreError,
    SearchRequest,
    SearchResult,
    _ctx_eval,
    _eval_ctx,
    _finalize,
    _top_unique,
    _workload_weights,
    default_engine,
    empty_partial_result,
    largest_workload_index,
    make_eval_fn,
    seed_population,
    seed_population_batched,
)
from repro.core.objectives import make_objective
from repro.imc.cost import EvalResult, evaluate_designs
from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet


def _resolve_engine(engine, fused, pipelined=None):
    """The engine a driver call runs on: an explicit ``engine`` wins (its
    own ``fused``/``pipelined`` settings govern), otherwise the shared
    default — or, when the caller pins ``fused`` or ``pipelined``, a
    per-call engine carrying the flags (engines are stateless apart from
    content caches, so this costs one object, not a retrace: the jit
    caches are global)."""
    if engine is not None:
        return engine
    if fused is None and pipelined is None:
        return default_engine()
    from repro.core.engine import SearchEngine

    return SearchEngine(fused=fused, pipelined=bool(pipelined))


# ----------------------------------------------------------------- drivers
def run_search(
    key: jax.Array,
    ws: WorkloadSet,
    *,
    objective: str = "ela",
    area_constr: float = 150.0,
    pop_size: int = 40,
    generations: int = 10,
    top_k: int = 10,
    pareto_k: int = 10,
    init_genomes: Optional[jnp.ndarray] = None,
    tech: TechParams = TECH,
    backend: str = "jnp",
    engine=None,
    fused: Optional[bool] = None,
    pipelined: Optional[bool] = None,
) -> SearchResult:
    """One joint search = a single-request engine plan.  ``engine``
    substitutes a configured ``SearchEngine`` (e.g. segmented execution
    with checkpoints) for the shared default.  ``fused`` pins the GA
    survival-epilogue mode (None = the process default; both settings are
    bit-identical — it only changes the compiled program shape).
    ``pipelined`` pins the transfer-thin engine path: identical result
    fields, but ``result.ga`` is ``None`` (the history stays on device —
    see ``SearchEngine``).  ``objective="pareto"`` switches to NSGA-II
    front search: the result's ``top_*`` fields hold the ``pareto_k``
    best front members in crowded order and ``objective_vectors`` their
    per-member (E, L, A) triples."""
    req = SearchRequest(
        ws=ws, objective=objective, area_constr=float(area_constr),
        key=key, backend=backend, pop_size=int(pop_size),
        generations=int(generations), top_k=int(top_k),
        pareto_k=int(pareto_k), tech=tech,
        init_genomes=init_genomes,
    )
    return _resolve_engine(engine, fused, pipelined).run([req])[0]


def joint_search(key, ws: WorkloadSet, **kw) -> SearchResult:
    return run_search(key, ws, **kw)


def batched_search(
    keys: jnp.ndarray,
    feats: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    names: Optional[Sequence] = None,
    objective: str = "ela",
    obj_weights: Optional[jnp.ndarray] = None,
    area_constr: float = 150.0,
    pop_size: int = 40,
    generations: int = 10,
    top_k: int = 10,
    pareto_k: int = 10,
    init_genomes: Optional[jnp.ndarray] = None,
    tech: TechParams = TECH,
    backend: str = "jnp",
    mesh=None,
    engine=None,
    fused: Optional[bool] = None,
    pipelined: Optional[bool] = None,
) -> List[SearchResult]:
    """B independent searches through the engine (one plan when shapes
    agree, chunked at the engine's slot limit for very large B).

    ``keys`` (B, 2) stacked PRNG keys; ``feats`` (B, W, L, 6) / ``mask``
    (B, W, L) per-element workload sets; ``init_genomes`` (B, P, n) or
    ``None`` (batched largest-workload rejection seeding).  With
    ``obj_weights`` (B, 3) the exponent-weighted objective scores each
    element with its own weights — one program covers every objective
    family.  Per-element RNG matches ``run_search(keys[b], ...)`` exactly,
    so batched and sequential drivers return identical scores.

    ``mesh`` (a ``launch.mesh.make_search_mesh`` layout) commits the inputs
    to the 2-D (search, population) placement: the B axis shards over the
    ``search`` mesh axis and each population over ``pod``/``data`` — GSPMD
    partitions the cached GA program accordingly (no retrace of the traced
    ctx path).  Scores stay bit-identical to ``mesh=None``
    (tests/test_search_sharded.py).
    """
    # ONE device->host transfer per input; the per-request WorkloadSets are
    # numpy-backed views, so the engine's slot packing (and fingerprinting)
    # never syncs the device again on the warm path
    keys = np.asarray(keys)
    feats = np.asarray(feats, np.float32)
    mask = np.asarray(mask, bool)
    B = keys.shape[0]
    if names is None:
        names_b = [tuple(f"w{j}" for j in range(feats.shape[1]))] * B
    elif isinstance(names[0], str):
        names_b = [tuple(names)] * B
    else:
        names_b = [tuple(n) for n in names]
    if obj_weights is not None:
        obj_weights = np.asarray(obj_weights, np.float64)
    if init_genomes is not None:
        init_genomes = np.asarray(init_genomes)
    reqs = [
        SearchRequest(
            ws=WorkloadSet(names=names_b[b], feats=feats[b], mask=mask[b]),
            objective=objective,
            obj_weights=(
                None if obj_weights is None else tuple(obj_weights[b])
            ),
            area_constr=float(area_constr),
            key=keys[b],
            backend=backend,
            pop_size=int(pop_size),
            generations=int(generations),
            top_k=int(top_k),
            pareto_k=int(pareto_k),
            tech=tech,
            init_genomes=None if init_genomes is None else init_genomes[b],
        )
        for b in range(B)
    ]
    return _resolve_engine(engine, fused, pipelined).run(reqs, mesh=mesh)


def joint_search_batched(keys: jnp.ndarray, ws: WorkloadSet, **kw) -> List[SearchResult]:
    """Multi-seed joint search: one GA per key, all in one XLA program."""
    keys = jnp.asarray(keys)
    B = keys.shape[0]
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    return batched_search(keys, feats, mask, names=ws.names, **kw)


def separate_search(
    key,
    ws: WorkloadSet,
    *,
    share_init: Optional[jnp.ndarray] = None,
    batched: bool = True,
    mesh=None,
    **kw,
) -> Dict[str, SearchResult]:
    """One single-workload GA per workload (the paper's baseline).

    ``batched=True`` (default) runs all W GAs as one engine plan;
    ``batched=False`` is the sequential reference path (one single-slot
    plan per workload).  Both derive per-workload keys from
    ``jax.random.split(key, W)`` and return identical scores (asserted in
    tests/test_search_batched.py).  ``mesh`` shards the W GAs over the
    ``search`` mesh axis (batched path only)."""
    if mesh is not None and not batched:
        raise ValueError("mesh= requires the batched path (batched=True)")
    keys = jax.random.split(key, ws.n)
    if batched:
        init = None
        if share_init is not None:
            init = jnp.tile(jnp.asarray(share_init)[None], (ws.n, 1, 1))
        res = batched_search(
            keys,
            ws.feats[:, None],  # (W, 1, L, 6): one workload per element
            ws.mask[:, None],
            names=[(n,) for n in ws.names],
            init_genomes=init,
            mesh=mesh,
            **kw,
        )
        return dict(zip(ws.names, res))
    out = {}
    for i, name in enumerate(ws.names):
        out[name] = run_search(keys[i], ws.subset([i]), init_genomes=share_init, **kw)
    return out


def rescore_designs(
    genomes: np.ndarray,
    ws: WorkloadSet,
    *,
    objective: str = "ela",
    area_constr: float = 150.0,
    tech: TechParams = TECH,
) -> Tuple[np.ndarray, EvalResult]:
    """Scores + full metrics of given designs on a (possibly different)
    workload set — the paper's cross-evaluation."""
    g = jnp.asarray(genomes)
    r = evaluate_designs(space.decode(g), ws, tech)
    s = make_objective(objective, area_constr)(r)
    return np.asarray(s), r
