"""Joint / separate hardware-workload search drivers (paper Sec. III-A, IV).

``joint_search``         — one GA over the full workload set (the paper's
                           method): objective reduces metrics with max over
                           workloads.
``separate_search``      — the baseline: one GA per single workload.  By
                           default all W GAs run as ONE vmapped XLA program
                           (``batched=False`` keeps the sequential reference
                           path; both produce identical scores).
``batched_search``       — the general batched driver: B independent GAs
                           (any mix of workload sets / seeds / objective
                           weights) vmapped into a single jit.
``joint_search_batched`` — multi-seed joint search on top of it.
``rescore_designs``      — re-evaluate any designs on any workload set or
                           objective (the paper's "failed designs" analysis).
``seed_population``      — initial population sampling with the paper's rule
                           (configs that cannot fit the *largest* workload
                           are discarded) as a jitted ``lax.while_loop``
                           rejection sampler — no per-round host sync.

Everything workload-dependent enters the jitted programs as traced array
arguments, and the evaluation callbacks are cached per (objective, area,
tech, backend) — repeated searches of the same shape never retrace.  The
batched drivers take ``mesh=`` (``launch.mesh.make_search_mesh``) to lay
the B independent GAs out over a 2-D (search, population) device mesh —
see ``core.distributed`` — with bit-identical scores.

Three evaluation backends (``backend=``): ``"jnp"`` (dense (P, W, L)
oracle), ``"pallas"`` (the imc_eval TPU kernel), and ``"table"`` — the
factorized cost model (``imc.tables``): the layer axis is reduced once per
workload set into grid tables that travel through the traced ``ctx``, and
every per-generation evaluation is O(W) gathers per design, independent of
workload depth L.  Scores are allclose across backends and the table path
picks identical top designs on the paper CNN set (tests/test_tables.py).
Measured on this container (benchmarks/bench_joint_vs_separate, 5 seeds =
5 joint + 20 separate GAs): 83 s sequential -> 15 s batched cold
(5.5x, including XLA compile of the two programs) -> 2 s with a warm
program cache (~40x); a warm P=40 x G=10 joint search itself runs at
~14k designs evaluated/s (experiments/search_throughput.json).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space
from repro.core.ga import GAResult, run_ga, run_ga_batched
from repro.core.objectives import (
    OBJECTIVE_WEIGHTS,
    make_objective,
    make_weighted_objective,
)
from repro.imc.cost import (
    DesignArrays,
    EvalResult,
    evaluate_designs,
    evaluate_designs_arrays,
)
from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet


@dataclasses.dataclass
class SearchResult:
    workload_names: Tuple[str, ...]
    objective: str
    ga: GAResult
    top_designs: List[Dict[str, float]]  # decoded, deduped, best-first
    top_scores: np.ndarray
    top_genomes: np.ndarray
    convergence: np.ndarray  # best-so-far score per generation


# --------------------------------------------------------- eval callbacks
BACKENDS = ("jnp", "pallas", "table")


@lru_cache(maxsize=None)
def _ctx_eval(
    objective: Optional[str], area_constr: float, tech: TechParams, backend: str
) -> Callable:
    """Cached ``eval_fn(genomes, ctx)`` with ``ctx = (feats (W, L, 6),
    mask (W, L))`` — or, for ``backend="table"``, ``ctx = (tables,)`` with
    ``tables`` an ``imc.tables.WorkloadTables`` pytree (``_eval_ctx`` builds
    the right one).  When ``objective`` is ``None`` a trailing ``weights
    (3,)`` leaf selects the exponent-weighted objective.  The cache (plus
    workload tensors/tables being traced, not closed over) is what keeps
    the GA jit from retracing across seeds and workload sets."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    obj = (
        make_weighted_objective(area_constr)
        if objective is None
        else make_objective(objective, area_constr)
    )

    if backend == "table":
        from repro.imc.tables import evaluate_genomes_tables

        def ev(genomes, ctx):
            return evaluate_genomes_tables(genomes, ctx[0], tech)

    elif backend == "pallas":
        from repro.kernels.imc_eval.ops import evaluate_designs_kernel_arrays

        def ev(genomes, ctx):
            return evaluate_designs_kernel_arrays(
                space.decode(genomes), ctx[0], ctx[1], tech
            )

    else:

        def ev(genomes, ctx):
            return evaluate_designs_arrays(space.decode(genomes), ctx[0], ctx[1], tech)

    def eval_fn(genomes: jnp.ndarray, ctx) -> jnp.ndarray:
        r = ev(genomes, ctx)
        return obj(r, ctx[-1]) if objective is None else obj(r)

    return eval_fn


def _eval_ctx(
    feats: jnp.ndarray,
    mask: jnp.ndarray,
    tech: TechParams,
    backend: str,
    *,
    batched: bool = False,
) -> Tuple:
    """The workload half of an eval ``ctx`` for ``backend``: the raw
    ``(feats, mask)`` tensors, or — for the table backend — the factorized
    ``(tables,)`` statistics, reduced over the layer axis here, ONCE, so
    the per-generation evaluation never sees L again."""
    if backend != "table":
        return (feats, mask)
    from repro.imc.tables import build_tables_arrays, build_tables_batched

    build = build_tables_batched if batched else build_tables_arrays
    return (build(feats, mask, tech),)


def make_eval_fn(
    ws: WorkloadSet,
    objective: str,
    area_constr: float,
    tech: TechParams = TECH,
    *,
    backend: str = "jnp",
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """backend: "jnp" (portable), "pallas" (the imc_eval TPU kernel;
    interpret-mode off-TPU — numerically identical, see tests) or "table"
    (factorized per-workload grid tables: O(W) gathers per design, no
    layer axis — allclose to "jnp", see tests/test_tables.py)."""
    fn = _ctx_eval(objective, float(area_constr), tech, backend)
    ctx = (ws.tables(tech),) if backend == "table" else (ws.feats, ws.mask)

    def eval_fn(genomes: jnp.ndarray) -> jnp.ndarray:
        return fn(genomes, ctx)

    return eval_fn


def _workload_weights(feats: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Crossbar-demand proxy per workload (total weight count K * N * groups);
    the single definition of "largest" shared by sequential and batched
    seeding so their largest-workload picks can never diverge."""
    return (feats[..., 1] * feats[..., 2] * feats[..., 5] * mask).sum(-1)


def largest_workload_index(ws: WorkloadSet) -> int:
    """Largest = most crossbar demand at a reference design (most weights)."""
    return int(jnp.argmax(_workload_weights(ws.feats, ws.mask)))


# ----------------------------------------------------------------- seeding
def _seed_rounds(key, feats, mask, pop_size, oversample, max_rounds, tech):
    """Jit-traceable rejection sampler against ONE workload (feats (L, 6)).

    Each round draws ``pop_size * oversample`` candidates, keeps those that
    fit and are V/f-valid, and scatters them into the next free pool slots;
    a ``lax.while_loop`` repeats until the pool is full or ``max_rounds``
    is hit — the host only syncs once, on the final (pool, count)."""
    n_cand = pop_size * oversample

    def cond(st):
        _, _, count, rnd = st
        return (count < pop_size) & (rnd < max_rounds)

    def body(st):
        key, pool, count, rnd = st
        key, k = jax.random.split(key)
        cand = space.random_genomes(k, n_cand)
        r = evaluate_designs_arrays(space.decode(cand), feats[None], mask[None], tech)
        ok = r.fits[:, 0] & r.valid
        pos = count + jnp.cumsum(ok) - 1
        idx = jnp.where(ok & (pos < pop_size), pos, pop_size)  # OOB -> dropped
        pool = pool.at[idx].set(cand, mode="drop")
        count = jnp.minimum(count + ok.sum(), pop_size)
        return key, pool, count, rnd + jnp.int32(1)

    pool0 = jnp.zeros((pop_size, space.N_GENES), jnp.float32)
    st = (key, pool0, jnp.int32(0), jnp.int32(0))
    _, pool, count, _ = jax.lax.while_loop(cond, body, st)
    return pool, count


_SEED_STATICS = ("pop_size", "oversample", "max_rounds", "tech")


@partial(jax.jit, static_argnames=_SEED_STATICS)
def _seed_jit(key, feats, mask, *, pop_size, oversample, max_rounds, tech):
    return _seed_rounds(key, feats, mask, pop_size, oversample, max_rounds, tech)


@partial(jax.jit, static_argnames=_SEED_STATICS)
def _seed_batched_jit(keys, feats, mask, *, pop_size, oversample, max_rounds, tech):
    """keys (B, 2), feats (B, W, L, 6), mask (B, W, L).  Each element's
    largest workload is picked as a TRACED argmax+gather inside the
    program — no host-side device sync before the seeding launch."""

    def one(k, ft, mk):
        li = jnp.argmax(_workload_weights(ft, mk))
        return _seed_rounds(k, ft[li], mk[li], pop_size, oversample, max_rounds, tech)

    return jax.vmap(one)(keys, feats, mask)


def seed_population(
    key: jax.Array,
    ws: WorkloadSet,
    pop_size: int,
    *,
    tech: TechParams = TECH,
    oversample: int = 64,
    max_rounds: int = 8,
) -> jnp.ndarray:
    """Random init; designs failing the largest workload (or V/f-invalid)
    are discarded (paper Sec. III-C).  One jitted while-loop program."""
    wi = largest_workload_index(ws)
    pool, count = _seed_jit(
        key, ws.feats[wi], ws.mask[wi],
        pop_size=int(pop_size), oversample=int(oversample),
        max_rounds=int(max_rounds), tech=tech,
    )
    if int(count) < pop_size:
        raise RuntimeError(
            f"could not seed {pop_size} valid designs ({int(count)} found); "
            "largest workload may not fit anywhere in the search space"
        )
    return pool


def seed_population_batched(
    keys: jnp.ndarray,
    feats: jnp.ndarray,
    mask: jnp.ndarray,
    pop_size: int,
    *,
    tech: TechParams = TECH,
    oversample: int = 64,
    max_rounds: int = 8,
    mesh=None,
) -> jnp.ndarray:
    """Per-batch-element seeding: keys (B, 2), feats (B, W, L, 6), mask
    (B, W, L) -> pools (B, pop_size, n).  Each element rejects against its
    own largest workload — selected by a traced argmax INSIDE the jit, so
    nothing blocks on device between the call and the seeding launch — all
    under one vmapped while-loop.  With ``mesh`` (a
    ``launch.mesh.make_search_mesh`` layout) the batch axis is committed
    to the ``search`` mesh axis before the launch, so each mesh slice seeds
    its own searches."""
    if mesh is not None:
        from repro.core.distributed import place_batched

        keys = place_batched(mesh, keys)
        feats = place_batched(mesh, feats)
        mask = place_batched(mesh, mask)
    pools, counts = _seed_batched_jit(
        keys, feats, mask,
        pop_size=int(pop_size), oversample=int(oversample),
        max_rounds=int(max_rounds), tech=tech,
    )
    counts = np.asarray(counts)
    if counts.min() < pop_size:
        bad = int(np.argmin(counts))
        raise RuntimeError(
            f"could not seed {pop_size} valid designs for batch element {bad} "
            f"({int(counts[bad])} found)"
        )
    return pools


# ------------------------------------------------------------- result prep
def _top_unique(
    genomes: np.ndarray, scores: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Best-k designs, unique in *decoded grid index* space.

    Fully vectorized host-side numpy (``np.unique`` over score-sorted grid
    indices instead of a Python loop over all G*P designs, and a host
    decode instead of per-call jnp dispatches): sorting by score first
    means each unique design's first occurrence is its best-scoring one,
    and non-finite scores (inf/nan) sort to the end, so dropping them
    equals the old truncate-at-first-non-finite rule."""
    idx = space.decode_indices_np(genomes)
    order = np.argsort(scores, kind="stable")
    _, first = np.unique(idx[order], axis=0, return_index=True)
    first.sort()  # positions within `order`, ascending = best-first
    keep = order[first]
    keep = keep[np.isfinite(scores[keep])][:k]
    return genomes[keep], scores[keep]


def _finalize(
    ga: GAResult, names: Sequence[str], objective: str, top_k: int
) -> SearchResult:
    G1, P, n = ga.genomes.shape
    flat_g = np.asarray(ga.genomes).reshape(-1, n)
    flat_s = np.asarray(ga.scores).reshape(-1)
    top_g, top_s = _top_unique(flat_g, flat_s, top_k)
    top_designs = space.design_dicts_from_indices(space.decode_indices_np(top_g))
    conv = np.minimum.accumulate(np.asarray(ga.scores).min(axis=1))
    return SearchResult(
        workload_names=tuple(names),
        objective=objective,
        ga=ga,
        top_designs=top_designs,
        top_scores=top_s,
        top_genomes=top_g,
        convergence=conv,
    )


# ----------------------------------------------------------------- drivers
def run_search(
    key: jax.Array,
    ws: WorkloadSet,
    *,
    objective: str = "ela",
    area_constr: float = 150.0,
    pop_size: int = 40,
    generations: int = 10,
    top_k: int = 10,
    init_genomes: Optional[jnp.ndarray] = None,
    tech: TechParams = TECH,
    backend: str = "jnp",
) -> SearchResult:
    k_seed, k_ga = jax.random.split(key)
    if init_genomes is None:
        init_genomes = seed_population(k_seed, ws, pop_size, tech=tech)
    else:
        init_genomes = jnp.array(init_genomes)  # copy: the GA donates its init
    eval_fn = _ctx_eval(objective, float(area_constr), tech, backend)
    ga = run_ga(
        k_ga,
        eval_fn,
        pop_size=pop_size,
        generations=generations,
        init_genomes=init_genomes,
        ctx=_eval_ctx(ws.feats, ws.mask, tech, backend),
    )
    return _finalize(ga, ws.names, objective, top_k)


def joint_search(key, ws: WorkloadSet, **kw) -> SearchResult:
    return run_search(key, ws, **kw)


def batched_search(
    keys: jnp.ndarray,
    feats: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    names: Optional[Sequence] = None,
    objective: str = "ela",
    obj_weights: Optional[jnp.ndarray] = None,
    area_constr: float = 150.0,
    pop_size: int = 40,
    generations: int = 10,
    top_k: int = 10,
    init_genomes: Optional[jnp.ndarray] = None,
    tech: TechParams = TECH,
    backend: str = "jnp",
    mesh=None,
) -> List[SearchResult]:
    """B independent searches as ONE vmapped, cached XLA program.

    ``keys`` (B, 2) stacked PRNG keys; ``feats`` (B, W, L, 6) / ``mask``
    (B, W, L) per-element workload sets; ``init_genomes`` (B, P, n) or
    ``None`` (batched largest-workload rejection seeding).  With
    ``obj_weights`` (B, 3) the exponent-weighted objective scores each
    element with its own weights — one program covers every objective
    family.  Per-element RNG matches ``run_search(keys[b], ...)`` exactly,
    so batched and sequential drivers return identical scores.

    ``mesh`` (a ``launch.mesh.make_search_mesh`` layout) commits the inputs
    to the 2-D (search, population) placement: the B axis shards over the
    ``search`` mesh axis and each population over ``pod``/``data`` — GSPMD
    partitions the cached GA program accordingly (no retrace of the traced
    ctx path).  Scores stay bit-identical to ``mesh=None``
    (tests/test_search_sharded.py).
    """
    keys = jnp.asarray(keys)
    feats = jnp.asarray(feats)
    mask = jnp.asarray(mask)
    if mesh is None:
        place = lambda x, **_: x  # noqa: E731 — identity placement
    else:
        from repro.core.distributed import place_batched

        place = partial(place_batched, mesh)
    keys, feats, mask = place(keys), place(feats), place(mask)
    B = keys.shape[0]
    ks = jax.vmap(lambda k: jax.random.split(k))(keys)  # (B, 2, 2)
    k_seed, k_ga = ks[:, 0], ks[:, 1]
    if init_genomes is None:
        init_genomes = seed_population_batched(
            k_seed, feats, mask, pop_size, tech=tech, mesh=mesh
        )
    else:
        init_genomes = jnp.array(init_genomes)  # copy: the GA donates its init
    init_genomes = place(init_genomes, pop_dim=1)
    # table backend: reduce the layer axis ONCE per element here; the GA's
    # per-generation evals then gather from the (search-sharded) tables
    ctx = tuple(
        jax.tree_util.tree_map(place, c)
        for c in _eval_ctx(feats, mask, tech, backend, batched=True)
    )
    if obj_weights is None:
        eval_fn = _ctx_eval(objective, float(area_constr), tech, backend)
    else:
        ctx = ctx + (place(jnp.asarray(obj_weights, jnp.float32)),)
        eval_fn = _ctx_eval(None, float(area_constr), tech, backend)
    ga = run_ga_batched(
        k_ga,
        eval_fn,
        pop_size=pop_size,
        generations=generations,
        init_genomes=init_genomes,
        ctx=ctx,
    )
    if names is None:
        names_b = [tuple(f"w{j}" for j in range(feats.shape[1]))] * B
    elif isinstance(names[0], str):
        names_b = [tuple(names)] * B
    else:
        names_b = [tuple(n) for n in names]
    if obj_weights is None:
        labels = [objective] * B
    else:
        # label each element with the kind its weights reproduce, so
        # SearchResult.objective stays truthful under the weighted path
        inv = {v: k for k, v in OBJECTIVE_WEIGHTS.items()}
        wv = np.asarray(obj_weights, np.float64)
        labels = [
            inv.get(tuple(wv[b]), f"weighted{tuple(wv[b])}") for b in range(B)
        ]
    # one device->host transfer per field, then pure-numpy per-element prep
    ga_np = GAResult(*(np.asarray(f) for f in ga))
    return [
        _finalize(GAResult(*(f[b] for f in ga_np)), names_b[b], labels[b], top_k)
        for b in range(B)
    ]


def joint_search_batched(keys: jnp.ndarray, ws: WorkloadSet, **kw) -> List[SearchResult]:
    """Multi-seed joint search: one GA per key, all in one XLA program."""
    keys = jnp.asarray(keys)
    B = keys.shape[0]
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    return batched_search(keys, feats, mask, names=ws.names, **kw)


def separate_search(
    key,
    ws: WorkloadSet,
    *,
    share_init: Optional[jnp.ndarray] = None,
    batched: bool = True,
    mesh=None,
    **kw,
) -> Dict[str, SearchResult]:
    """One single-workload GA per workload (the paper's baseline).

    ``batched=True`` (default) runs all W GAs as one vmapped XLA program;
    ``batched=False`` is the sequential reference path.  Both derive
    per-workload keys from ``jax.random.split(key, W)`` and return
    identical scores (asserted in tests/test_search_batched.py).  ``mesh``
    shards the W GAs over the ``search`` mesh axis (batched path only; the
    sequential reference is single-device by construction)."""
    if mesh is not None and not batched:
        raise ValueError("mesh= requires the batched path (batched=True)")
    keys = jax.random.split(key, ws.n)
    if batched:
        init = None
        if share_init is not None:
            init = jnp.tile(jnp.asarray(share_init)[None], (ws.n, 1, 1))
        res = batched_search(
            keys,
            ws.feats[:, None],  # (W, 1, L, 6): one workload per element
            ws.mask[:, None],
            names=[(n,) for n in ws.names],
            init_genomes=init,
            mesh=mesh,
            **kw,
        )
        return dict(zip(ws.names, res))
    out = {}
    for i, name in enumerate(ws.names):
        out[name] = run_search(keys[i], ws.subset([i]), init_genomes=share_init, **kw)
    return out


def rescore_designs(
    genomes: np.ndarray,
    ws: WorkloadSet,
    *,
    objective: str = "ela",
    area_constr: float = 150.0,
    tech: TechParams = TECH,
) -> Tuple[np.ndarray, EvalResult]:
    """Scores + full metrics of given designs on a (possibly different)
    workload set — the paper's cross-evaluation."""
    g = jnp.asarray(genomes)
    r = evaluate_designs(space.decode(g), ws, tech)
    s = make_objective(objective, area_constr)(r)
    return np.asarray(s), r
