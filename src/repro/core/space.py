"""The paper's hardware search space (~1.9e7 configurations).

Nine discrete parameters (paper Fig. 1 / Sec. III-B).  The genome is a
continuous relaxation: 9 genes in [0, 1), decoded per-gene to a grid index
(exactly how pymoo treats integer grids under SBX/polynomial mutation [33]).

Grid sizes multiply to 5*5*5*4*6 * 20 * 4 * 8 * 10 = 19,200,000 ~ 1.9e7,
matching the paper's stated search-space size.

Densified grids: ``configure_grid(density)`` refines every axis except
``bits_cell`` by inserting ``density - 1`` interpolated points per
interval (geometric for the power-of-two-ish hardware counts and
timing/buffer axes, linear for ``v_op``), keeping every original grid
point as an exact subset.  ``density=2`` grows the space ~130x (2.5e9
designs), ``density=3`` ~2600x.  The whole factorized-table stack reads
``SPACE`` at trace time, so the densified grids flow through table
builds, decoding, and the search engine automatically — every content
cache keyed by workload fingerprint also keys on ``grid_token()``.  The
default density is 1 (the paper's grid), overridable with the
``REPRO_GRID_DENSITY`` env var at import.
"""
from __future__ import annotations

import hashlib
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.imc.cost import DesignArrays

# name -> grid of values (ordered); the paper's density-1 grid
_BASE_SPACE: Dict[str, np.ndarray] = {
    "rows": np.array([32, 64, 128, 256, 512], np.float32),
    "cols": np.array([32, 64, 128, 256, 512], np.float32),
    "c_per_tile": np.array([2, 4, 8, 16, 32], np.float32),
    "t_per_router": np.array([2, 4, 8, 16], np.float32),
    "g_per_chip": np.array([2, 4, 8, 16, 32, 64], np.float32),
    "v_op": np.round(np.arange(0.70, 1.20, 0.025), 3).astype(np.float32),  # 20
    "bits_cell": np.array([1, 2, 3, 4], np.float32),
    "t_cycle_ns": np.array([0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 8.0], np.float32),
    "glb_mb": np.array(
        [0.125, 0.25, 0.5, 0.75, 1.0, 2.0, 3.0, 4.0, 8.0, 16.0], np.float32
    ),
}

# how each axis refines: geometric midpoints rounded to integers for the
# hardware counts, geometric for timings/buffers, linear for voltage;
# bits_cell stays exact (fractional cell bits are not physical)
_REFINE_KIND: Dict[str, str] = {
    "rows": "geom_int",
    "cols": "geom_int",
    "c_per_tile": "geom_int",
    "t_per_router": "geom_int",
    "g_per_chip": "geom_int",
    "v_op": "linear",
    "bits_cell": "exact",
    "t_cycle_ns": "geom",
    "glb_mb": "geom",
}

FIELDS: Tuple[str, ...] = tuple(DesignArrays._fields)
assert set(_BASE_SPACE) == set(FIELDS), (set(_BASE_SPACE), set(FIELDS))
N_GENES = len(FIELDS)


def _refine_axis(vals: np.ndarray, density: int, kind: str) -> np.ndarray:
    if density <= 1 or kind == "exact":
        return vals.copy()
    out = []
    for a, b in zip(vals[:-1], vals[1:]):
        out.append(float(a))
        for j in range(1, density):
            t = j / density
            if kind == "linear":
                m = round(a + (b - a) * t, 4)
            else:
                m = a * (b / a) ** t
                if kind == "geom_int":
                    m = round(m)
            out.append(float(m))
    out.append(float(vals[-1]))
    # sorted unique: integer rounding of close midpoints may collide
    return np.unique(np.array(out, np.float32))


def _build_space(density: int) -> Dict[str, np.ndarray]:
    return {
        f: _refine_axis(_BASE_SPACE[f], density, _REFINE_KIND[f])
        for f in FIELDS
    }


GRID_DENSITY = max(1, int(os.environ.get("REPRO_GRID_DENSITY", "1")))
SPACE: Dict[str, np.ndarray] = _build_space(GRID_DENSITY)
GRID_SIZES = np.array([len(SPACE[f]) for f in FIELDS], np.int32)
SPACE_SIZE = int(np.prod(GRID_SIZES.astype(np.int64)))
_GRIDS = [jnp.asarray(SPACE[f]) for f in FIELDS]
_GRID_TOKEN = ""


def _compute_token() -> str:
    h = hashlib.sha256()
    for f in FIELDS:
        h.update(np.asarray(SPACE[f], np.float32).tobytes())
    return h.hexdigest()[:16]


_GRID_TOKEN = _compute_token()


def grid_token() -> str:
    """Content hash of the active grid — every cache keyed by workload
    fingerprint (table memos, padded/stacked engine tables, plan and
    result-cache keys) also keys on this, so reconfiguring the grid can
    never serve a stale table or cached result."""
    return _GRID_TOKEN


def configure_grid(density: int = 1) -> None:
    """Rebuild the search space at the given refinement density.

    Rebinds ``SPACE`` / ``GRID_SIZES`` / ``SPACE_SIZE`` / the decode grids
    and clears every jit cache: the grids are trace-time constants baked
    into compiled programs (table builds, decoders, the GA eval), so any
    cached executable would silently keep the old grid."""
    global GRID_DENSITY, SPACE, GRID_SIZES, SPACE_SIZE, _GRIDS, _GRID_TOKEN
    density = max(1, int(density))
    if density == GRID_DENSITY:
        return
    GRID_DENSITY = density
    SPACE = _build_space(density)
    GRID_SIZES = np.array([len(SPACE[f]) for f in FIELDS], np.int32)
    SPACE_SIZE = int(np.prod(GRID_SIZES.astype(np.int64)))
    _GRIDS = [jnp.asarray(SPACE[f]) for f in FIELDS]
    _GRID_TOKEN = _compute_token()
    jax.clear_caches()


def decode(genomes: jnp.ndarray) -> DesignArrays:
    """(P, 9) floats in [0,1) -> decoded design value arrays (each (P,))."""
    return designs_from_indices(decode_indices(genomes))


def designs_from_indices(idx: jnp.ndarray) -> DesignArrays:
    """(P, 9) integer grid indices -> decoded design value arrays.  The
    gather half of ``decode``; the table-backend evaluator
    (``imc.tables``) calls it directly on ``decode_indices`` output."""
    return DesignArrays(*(grid[idx[:, i]] for i, grid in enumerate(_GRIDS)))


def decode_indices(genomes: jnp.ndarray) -> jnp.ndarray:
    """(P, 9) -> integer grid indices (P, 9)."""
    out = []
    for i, grid in enumerate(_GRIDS):
        n = grid.shape[0]
        out.append(jnp.clip((genomes[:, i] * n).astype(jnp.int32), 0, n - 1))
    return jnp.stack(out, axis=1)


def decode_indices_np(genomes: np.ndarray) -> np.ndarray:
    """Host-side ``decode_indices`` (same float32 arithmetic, so identical
    indices) — result preparation decodes whole population histories
    without a device round-trip per design."""
    g = np.asarray(genomes, np.float32)
    sizes = GRID_SIZES.astype(np.float32)[None, :]
    idx = (g * sizes).astype(np.int32)
    return np.clip(idx, 0, GRID_SIZES[None, :] - 1)


def genome_from_indices(idx: np.ndarray) -> np.ndarray:
    """Integer indices (P, 9) -> genome centered in each grid cell."""
    return (np.asarray(idx, np.float64) + 0.5) / GRID_SIZES[None, :]


def design_dicts_from_indices(idx: np.ndarray) -> List[Dict[str, float]]:
    """Host-side: (P, 9) integer grid indices -> per-design name->value
    dicts (the single definition of the design-dict format)."""
    return [
        {f: float(SPACE[f][idx[i, j]]) for j, f in enumerate(FIELDS)}
        for i in range(len(idx))
    ]


def random_genomes(key: jax.Array, n: int) -> jnp.ndarray:
    return jax.random.uniform(key, (n, N_GENES))
