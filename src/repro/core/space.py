"""The paper's hardware search space (~1.9e7 configurations).

Nine discrete parameters (paper Fig. 1 / Sec. III-B).  The genome is a
continuous relaxation: 9 genes in [0, 1), decoded per-gene to a grid index
(exactly how pymoo treats integer grids under SBX/polynomial mutation [33]).

Grid sizes multiply to 5*5*5*4*6 * 20 * 4 * 8 * 10 = 19,200,000 ~ 1.9e7,
matching the paper's stated search-space size.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.imc.cost import DesignArrays

# name -> grid of values (ordered)
SPACE: Dict[str, np.ndarray] = {
    "rows": np.array([32, 64, 128, 256, 512], np.float32),
    "cols": np.array([32, 64, 128, 256, 512], np.float32),
    "c_per_tile": np.array([2, 4, 8, 16, 32], np.float32),
    "t_per_router": np.array([2, 4, 8, 16], np.float32),
    "g_per_chip": np.array([2, 4, 8, 16, 32, 64], np.float32),
    "v_op": np.round(np.arange(0.70, 1.20, 0.025), 3).astype(np.float32),  # 20
    "bits_cell": np.array([1, 2, 3, 4], np.float32),
    "t_cycle_ns": np.array([0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 8.0], np.float32),
    "glb_mb": np.array(
        [0.125, 0.25, 0.5, 0.75, 1.0, 2.0, 3.0, 4.0, 8.0, 16.0], np.float32
    ),
}

FIELDS: Tuple[str, ...] = tuple(DesignArrays._fields)
assert set(SPACE) == set(FIELDS), (set(SPACE), set(FIELDS))
N_GENES = len(FIELDS)
GRID_SIZES = np.array([len(SPACE[f]) for f in FIELDS], np.int32)
SPACE_SIZE = int(np.prod(GRID_SIZES.astype(np.int64)))

_GRIDS = [jnp.asarray(SPACE[f]) for f in FIELDS]


def decode(genomes: jnp.ndarray) -> DesignArrays:
    """(P, 9) floats in [0,1) -> decoded design value arrays (each (P,))."""
    return designs_from_indices(decode_indices(genomes))


def designs_from_indices(idx: jnp.ndarray) -> DesignArrays:
    """(P, 9) integer grid indices -> decoded design value arrays.  The
    gather half of ``decode``; the table-backend evaluator
    (``imc.tables``) calls it directly on ``decode_indices`` output."""
    return DesignArrays(*(grid[idx[:, i]] for i, grid in enumerate(_GRIDS)))


def decode_indices(genomes: jnp.ndarray) -> jnp.ndarray:
    """(P, 9) -> integer grid indices (P, 9)."""
    out = []
    for i, grid in enumerate(_GRIDS):
        n = grid.shape[0]
        out.append(jnp.clip((genomes[:, i] * n).astype(jnp.int32), 0, n - 1))
    return jnp.stack(out, axis=1)


def decode_indices_np(genomes: np.ndarray) -> np.ndarray:
    """Host-side ``decode_indices`` (same float32 arithmetic, so identical
    indices) — result preparation decodes whole population histories
    without a device round-trip per design."""
    g = np.asarray(genomes, np.float32)
    sizes = GRID_SIZES.astype(np.float32)[None, :]
    idx = (g * sizes).astype(np.int32)
    return np.clip(idx, 0, GRID_SIZES[None, :] - 1)


def genome_from_indices(idx: np.ndarray) -> np.ndarray:
    """Integer indices (P, 9) -> genome centered in each grid cell."""
    return (np.asarray(idx, np.float64) + 0.5) / GRID_SIZES[None, :]


def design_dicts_from_indices(idx: np.ndarray) -> List[Dict[str, float]]:
    """Host-side: (P, 9) integer grid indices -> per-design name->value
    dicts (the single definition of the design-dict format)."""
    return [
        {f: float(SPACE[f][idx[i, j]]) for j, f in enumerate(FIELDS)}
        for i in range(len(idx))
    ]


def random_genomes(key: jax.Array, n: int) -> jnp.ndarray:
    return jax.random.uniform(key, (n, N_GENES))
