from repro.data.pipeline import (  # noqa: F401
    DataState,
    SyntheticLM,
    make_batch_fn,
)
