"""Deterministic synthetic token pipeline (host-sharded, resumable).

Production posture without external datasets:

  * **Deterministic & seekable** — batch ``i`` is a pure function of
    (seed, i).  Restart-from-checkpoint replays the exact token stream by
    restoring ``DataState.step``; no shard files or shuffle buffers to
    reconcile.
  * **Host-sharded** — each host materializes only its slice of the global
    batch (``host_slice``); ``make_batch_fn`` returns globally-consistent
    arrays on a single-process run and per-host slices under multi-host.
  * **Double-buffered** — ``prefetch_iter`` keeps one batch ahead of the
    step (straggler mitigation: host input never blocks the device step).
  * The stream is a Zipf-ish unigram mix with Markov structure, so losses
    actually DECREASE during training (smoke-test signal, not just noise).
"""
from __future__ import annotations

import dataclasses
import threading
from queue import Queue
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    """Checkpointable pipeline position."""

    seed: int
    step: int

    def as_tree(self):
        return {"seed": jnp.int64(self.seed), "step": jnp.int64(self.step)}

    @staticmethod
    def from_tree(t) -> "DataState":
        return DataState(seed=int(t["seed"]), step=int(t["step"]))


class SyntheticLM:
    """Markov-modulated Zipf tokens: learnable but non-trivial statistics."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        # fixed "grammar": each token deterministically biases the next
        rng = np.random.default_rng(seed ^ 0xC0FFEE)
        self._succ = rng.integers(0, vocab_size, size=(min(vocab_size, 4096),), dtype=np.int64)

    def batch_at(self, step: int, *, host_slice: Optional[Tuple[int, int]] = None) -> Dict[str, np.ndarray]:
        lo, hi = host_slice or (0, self.batch)
        n = hi - lo
        rng = np.random.default_rng((self.seed, step))
        # Zipf-ish marginal over a capped alphabet (keeps gather tables small)
        alpha = 1.1
        cap = min(self.vocab, 4096)
        ranks = np.arange(1, cap + 1)
        p = ranks ** (-alpha)
        p /= p.sum()
        draws = rng.choice(cap, size=(self.batch, self.seq + 1), p=p)
        # Markov overlay: 50% of positions follow the grammar successor
        follow = rng.random((self.batch, self.seq)) < 0.5
        for t in range(1, self.seq + 1):
            idx = draws[:, t - 1] % len(self._succ)
            draws[:, t] = np.where(follow[:, t - 1], self._succ[idx], draws[:, t])
        toks = draws[lo:hi].astype(np.int32)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


def make_batch_fn(
    vocab_size: int,
    seq_len: int,
    global_batch: int,
    *,
    seed: int = 0,
    extras: Optional[Dict[str, Any]] = None,
):
    """Returns ``batch_fn(step) -> dict`` incl. modality extras (VLM frames
    etc.) generated deterministically from the same (seed, step)."""
    src = SyntheticLM(vocab_size, seq_len, global_batch, seed)
    extras = extras or {}

    def batch_fn(step: int) -> Dict[str, np.ndarray]:
        b = src.batch_at(step)
        rng = np.random.default_rng((seed ^ 0xFEED, step))
        for name, spec in extras.items():
            if name == "mrope_pos":
                pos = np.broadcast_to(
                    np.arange(seq_len, dtype=np.int32), (3, global_batch, seq_len)
                )
                b[name] = np.ascontiguousarray(pos)
            else:
                b[name] = (rng.standard_normal(spec.shape) * 0.02).astype(np.float32)
        return b

    return batch_fn


def prefetch_iter(batch_fn, start_step: int, *, depth: int = 2) -> Iterator:
    """Background-thread prefetcher (double buffering by default)."""
    q: Queue = Queue(maxsize=depth)
    stop = threading.Event()

    def producer():
        s = start_step
        while not stop.is_set():
            q.put((s, batch_fn(s)))
            s += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
