from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES,
    batch_axes,
    cache_spec,
    input_sharding,
    make_rules,
    named_sharding_tree,
    params_sharding,
)
