"""Cross-pod gradient compression: int8 quantization + error feedback.

At 1000+-node scale the pod axis is a DCN-class link ~10x slower than ICI;
the only traffic we send across it is the per-step gradient all-reduce.
Compressing that all-reduce 4x (f32 -> int8 with per-leaf scale) cuts the
slow-axis time proportionally; the quantization residual is carried in an
error-feedback buffer (Karimireddy et al.-style EF21) so the optimizer
sees an unbiased long-run gradient.

Usage inside a train step (pure jittable):

    comp, ef  = compress(grads + ef)          # int8 payload + new residual
    grads     = decompress(psum(comp, "pod")) # cheap all-reduce
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Compressed(NamedTuple):
    q: PyTree  # int8 tree
    scale: PyTree  # f32 per-leaf scalars


def ef_init(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: PyTree, ef: PyTree) -> Tuple[Compressed, PyTree]:
    """Quantize (grads + ef) to int8; return payload + new error residual."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, x - deq

    flat, treedef = jax.tree.flatten(grads)
    ef_flat = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat, ef_flat)]
    return (
        Compressed(
            q=jax.tree.unflatten(treedef, [o[0] for o in out]),
            scale=jax.tree.unflatten(treedef, [o[1] for o in out]),
        ),
        jax.tree.unflatten(treedef, [o[2] for o in out]),
    )


def decompress(c: Compressed) -> PyTree:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale
    )


def psum_compressed(c: Compressed, axis: str, n: int) -> PyTree:
    """all-reduce the int8 payload over `axis` (inside shard_map); the mean
    uses int32 accumulation to avoid int8 overflow across `n` pods."""
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis), c.q
    )
    scale = jax.tree.map(lambda s: jax.lax.pmax(s, axis), c.scale)
    return jax.tree.map(
        lambda si, sc: si.astype(jnp.float32) * sc / n, summed, scale
    )


def compressed_allreduce(grads: PyTree, ef: PyTree, axis: str, n: int):
    """One-call helper: returns (mean grads across pods, new ef)."""
    c, new_ef = compress(grads, ef)
    return psum_compressed(c, axis, n), new_ef
