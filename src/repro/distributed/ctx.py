"""Logical sharding context: lets model code give GSPMD activation hints
without depending on a concrete mesh.

Launchers enter ``use_rules(mesh, rules)``; model code calls
``constrain(x, ("batch", "experts", None, None))``.  Outside any context
(CPU tests, single device) it is a no-op, so the model stays portable.

Divisibility is checked per dim — a logical name whose dim size does not
divide the mapped mesh-axis product silently falls back to replicated for
that dim (same policy as parameter sharding in ``models.common``).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, Any]]]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, Any]):
    """rules: logical name -> mesh axis (str | tuple | None)."""
    prev = _current()
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def axis_product(mesh: Mesh, ax: Any) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(ax, 1)


def logical_axis_size(name: str) -> int:
    """Mesh-axis product a logical name maps to (1 when no context)."""
    ctx = _current()
    if ctx is None:
        return 1
    mesh, rules = ctx
    return axis_product(mesh, rules.get(name))


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint if a context is active; else no-op."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = []
    used: set = set()
    for size, name in zip(x.shape, logical):
        ax = rules.get(name) if name else None
        if ax is None:
            spec.append(None)
            continue
        axes = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        n = axis_product(mesh, ax)
        if n <= 1 or size % n != 0 or any(a in used for a in axes):
            spec.append(None)
            continue
        used.update(axes)
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
