"""Logical-axis -> mesh-axis sharding rules.

One table maps every logical parameter dimension (declared next to the
parameter in ``repro.models``) to mesh axes:

* ``model`` axis — Megatron-style tensor parallelism: attention heads, FFN
  hidden, expert dim (true EP when the expert count divides the axis,
  expert-TP fallback otherwise — see ``common.param_specs``), SSD inner dim,
  vocab-sharded embeddings.
* ``data`` axis — FSDP/ZeRO-3: the ``embed`` (d_model) dim of every weight
  is sharded over ``data``; GSPMD inserts the per-layer all-gather (fwd) and
  reduce-scatter (bwd).
* ``pod`` axis — pure data parallelism across pods: parameters are
  replicated pod-to-pod, only the gradient all-reduce crosses the DCN-class
  link (optionally int8-compressed, see ``distributed/compression.py``).

Activations: batch shards over ``("pod", "data")``; decode KV caches shard
batch over the same and *sequence* over ``model`` (flash-decode style
partial softmax + GSPMD combine); long-context (B=1) cells shard sequence
over ``model`` only by default — the §Perf hillclimb explores 2D
(data×model) sequence sharding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.common import param_specs

PyTree = Any

# logical dim name -> mesh axis (tuples = multi-axis sharding)
LOGICAL_RULES: Dict[str, Any] = {
    "vocab": "model",
    "embed": "data",  # FSDP: every weight's d_model dim sharded over data
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "moe_ff": "model",  # expert-TP fallback layout (E % model != 0)
    # EP layout: experts->model, hidden->data (2D storage sharding).  The
    # compute path explicitly gathers a BF16 copy of each layer's expert
    # weights (see moe_ffn) — gathering the f32 masters doubles both the
    # collective bytes and the live-buffer size.
    "moe_ff_ep": "data",
    "experts": "model",  # EP when divisible; else alt_logical layout kicks in
    "ssm_inner": "model",
    "layers": None,  # scanned stack dim stays unsharded
    # activations (ctx.constrain): Megatron-style sequence parallelism —
    # the inter-layer residual stream shards its seq dim over `model`
    "seq": "model",
}


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dim: ("pod","data") multi-pod, ("data",) single."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    rules = dict(LOGICAL_RULES)
    rules["_mesh_sizes"] = mesh_axis_sizes(mesh)
    rules["batch"] = batch_axes(mesh)  # activation batch dim (ctx.constrain)
    if overrides:
        rules.update(overrides)
    return rules


def params_sharding(cfg: ModelConfig, mesh: Mesh, template: PyTree,
                    overrides: Optional[Dict[str, Any]] = None) -> PyTree:
    """NamedSharding tree for the param template (and, leaf-for-leaf, the
    Adam moments)."""
    specs = param_specs(template, make_rules(mesh, overrides))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def named_sharding_tree(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------------- activations
def input_sharding(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Dict[str, P]:
    """PartitionSpecs for every input of the given shape cell."""
    ba = batch_axes(mesh)
    bspec = ba if shape.global_batch % int(np.prod([mesh_axis_sizes(mesh)[a] for a in ba])) == 0 else None
    sh: Dict[str, P] = {}
    if shape.kind == "train":
        sh["inputs"] = P(bspec, None)
        sh["targets"] = P(bspec, None)
    elif shape.kind == "prefill":
        sh["tokens"] = P(bspec, None)
    else:  # decode
        sh["token"] = P(bspec, None)
        sh["pos"] = P(bspec)
    if cfg.vision_tokens and shape.kind != "decode":
        sh["vision_embeds"] = P(bspec, None, None)
        sh["mrope_pos"] = P(None, bspec, None)
    if cfg.is_encdec and shape.kind != "decode":
        sh["frames"] = P(bspec, None, None)
    return sh


def cache_spec(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               *, seq_axis: Any = "model") -> PyTree:
    """PartitionSpec tree matching ``transformer.cache_template``.

    Attention KV: (layers, B, C, KV, Dh) — batch over ("pod","data") when it
    divides, cache sequence over ``seq_axis`` (flash-decode); falls back per
    dim when not divisible.  Mamba state: (layers, B, H, N, P) — batch +
    inner heads over ``model``.
    """
    from repro.models.transformer import cache_template

    sizes = mesh_axis_sizes(mesh)
    ba = batch_axes(mesh)
    nb = int(np.prod([sizes[a] for a in ba]))
    bspec = ba if shape.global_batch % nb == 0 else None
    m = sizes.get("model", 1)

    def spec_for(path, leaf: jax.ShapeDtypeStruct) -> P:
        key = path[-1].key  # dict key within a slot cache
        shp = leaf.shape
        if key in ("k", "v", "xk", "xv"):  # (L, B, C, KV, Dh)
            seq = seq_axis if seq_axis and shp[2] % max(m, 1) == 0 else None
            return P(None, bspec, seq, None, None)
        if key == "ssm":  # (L, B, H, N, P)
            h = "model" if shp[2] % m == 0 else None
            return P(None, bspec, h, None, None)
        if key == "conv":  # (L, B, K-1, conv_ch)
            c = "model" if shp[3] % m == 0 else None
            return P(None, bspec, None, c)
        raise KeyError(key)

    tmpl = cache_template(cfg, shape.global_batch, shape.seq_len)
    return jax.tree_util.tree_map_with_path(spec_for, tmpl)
