from repro.imc.tech import TECH, TechParams  # noqa: F401
from repro.imc.cost import (  # noqa: F401
    DesignArrays,
    evaluate_designs,
    evaluate_one,
)
