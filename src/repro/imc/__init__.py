# Version string of the IMC cost model's MATH (term structure, constants
# baked into the formulas — not TechParams, which travel per request).
# Bump on any change that can move a result bit for identical inputs; the
# service result cache (serve.cache.request_key) keys on it, so persisted
# entries from an older model can never be served against a newer one.
COST_MODEL_VERSION = "2"

from repro.imc.tech import TECH, TechParams  # noqa: F401
from repro.imc.cost import (  # noqa: F401
    DesignArrays,
    design_valid,
    evaluate_designs,
    evaluate_one,
)

# NOTE: repro.imc.tables (the factorized grid-table cost model) is imported
# lazily by its users, never here: tables depends on repro.core.space for
# the grid definitions and space depends on this package — importing it at
# package-init time would re-enter a partially-initialized module.
