"""Analytical IMC chip performance model — pure JAX, fully vectorized.

Evaluates a *population* of chip designs against a *set* of workloads in one
tensor program (CIMLoop/NeuroSim-class estimates, closed form):

    E (P, W) pJ,   L (P, W) ns,   A (P,) mm^2,   fits (P, W),   valid (P,)

Architecture (paper Fig. 1): chip = ``G_per_chip`` tile groups + global
buffer; each group has one shared router serving ``T_per_router`` tiles;
each tile has ``C_per_tile`` crossbars (rows x cols RRAM cells) with ADCs
(8-bit, 8:1 column mux), drivers and IO buffers.  Weight-stationary mapping:
every layer's weights are pinned; a design *fails* a workload when the
crossbar demand exceeds chip capacity (the paper's "failed designs").

Model structure (what scales with what):
  * crossbar demand:  ceil(K/rows) * ceil(N*cpw/cols) * groups   per layer,
    cpw = ceil(weight_bits / bits_cell)
  * compute latency:  M * input_bits * adc_share * T_cycle    (bit-serial
    inputs, ADC column mux serializes readout), layers sequential
  * comm latency:     activation bytes through G routers, flit_bytes/cycle
  * GLB:              per-layer working set beyond GLB spills to DRAM
  * V/f coupling:     T_cycle >= t_min(V_op) (alpha-power law) else invalid;
    cell read energy ~ V^2 * G_avg * T_cycle
  * energy:           cells + ADC + DAC + routers + buffers + DRAM spill
                      + leakage(Area) * latency
  * area:             full provisioned capacity (crossbars+ADCs+drivers)
                      + routers + tile buffers + GLB + 10% overhead

All `ceil`s are `jnp` ops — a GA generation (eval -> select -> SBX ->
mutate) is a single XLA program; the population axis shards over the mesh
``data`` axis for pod-scale DSE (see ``repro.core.distributed``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.imc.tech import TECH, TechParams
from repro.workloads.pack import WorkloadSet


class DesignArrays(NamedTuple):
    """Decoded designs, each field (P,) float32/int32."""

    rows: jnp.ndarray
    cols: jnp.ndarray
    c_per_tile: jnp.ndarray
    t_per_router: jnp.ndarray
    g_per_chip: jnp.ndarray
    v_op: jnp.ndarray
    bits_cell: jnp.ndarray
    t_cycle_ns: jnp.ndarray
    glb_mb: jnp.ndarray


class EvalResult(NamedTuple):
    energy_pj: jnp.ndarray  # (P, W)
    latency_ns: jnp.ndarray  # (P, W)
    area_mm2: jnp.ndarray  # (P,)
    fits: jnp.ndarray  # (P, W) bool — workload weights resident on chip
    valid: jnp.ndarray  # (P,) bool — design self-consistent (V/f)
    util: jnp.ndarray  # (P, W) crossbar-capacity utilization


def _ceil_div(a, b):
    return jnp.ceil(a / b)


def design_valid(d: DesignArrays, tech: TechParams = TECH) -> jnp.ndarray:
    """V/f self-consistency (P,): alpha-power-law minimum cycle at V_op."""
    k = (tech.v_nominal - tech.v_th) ** tech.alpha_power / tech.v_nominal
    t_min = k * d.v_op / (d.v_op - tech.v_th) ** tech.alpha_power
    return d.t_cycle_ns >= t_min


def area_mm2(d: DesignArrays, tech: TechParams = TECH) -> jnp.ndarray:
    """Provisioned chip area (independent of workload)."""
    n_tiles = d.g_per_chip * d.t_per_router
    n_xbars = n_tiles * d.c_per_tile
    xbar = (
        d.rows * d.cols * tech.cell_area_mm2
        + d.rows * tech.driver_area_mm2_per_row
        + (d.cols / tech.adc_share) * tech.adc_area_mm2
    )
    tile_buf = tech.tile_buf_kb / 1024.0 * tech.sram_area_mm2_per_mb
    a = (
        n_xbars * xbar
        + n_tiles * tile_buf
        + d.g_per_chip * tech.router_area_mm2
        + d.glb_mb * tech.sram_area_mm2_per_mb
    )
    return a * 1.10  # global wiring/pads overhead


def evaluate_designs(
    d: DesignArrays, ws: WorkloadSet, tech: TechParams = TECH
) -> EvalResult:
    """Vectorized evaluation: designs (P,) x workloads (W, L, 6)."""
    return evaluate_designs_arrays(d, ws.feats, ws.mask, tech)


def evaluate_designs_arrays(
    d: DesignArrays, feats: jnp.ndarray, mask: jnp.ndarray, tech: TechParams = TECH
) -> EvalResult:
    """Same as ``evaluate_designs`` but on raw (feats (W, L, 6), mask (W, L))
    tensors, so workload sets can be traced arguments — the batched search
    path (``core.search.batched_search``) vmaps over a leading batch axis of
    these and the jit cache is keyed only on shapes, not WorkloadSet objects."""
    M, K, N, A_in, A_out, G = [feats[..., i] for i in range(6)]
    maskf = mask.astype(jnp.float32)

    # broadcast designs to (P, 1, 1) against layers (1, W, L)
    def b(x):
        return x[:, None, None].astype(jnp.float32)

    rows, cols = b(d.rows), b(d.cols)
    v_op, bits = b(d.v_op), b(d.bits_cell)
    t_cyc = b(d.t_cycle_ns)
    glb_bytes = b(d.glb_mb) * (1 << 20)

    Ml, Kl, Nl, Gl = M[None], K[None], N[None], G[None]
    Ain, Aout = A_in[None], A_out[None]
    mk = maskf[None]

    cpw = _ceil_div(jnp.float32(tech.weight_bits), bits)
    xb_layer = _ceil_div(Kl, rows) * _ceil_div(Nl * cpw, cols) * Gl  # (P,W,L)
    demand = (xb_layer * mk).sum(-1)  # (P, W)
    capacity = (d.g_per_chip * d.t_per_router * d.c_per_tile).astype(jnp.float32)
    fits = demand <= capacity[:, None]
    util = demand / capacity[:, None]

    # ---------------- latency ------------------------------------------------
    phases = jnp.float32(tech.input_bits)
    cyc_per_vec = phases * tech.adc_share
    l_comp = (Ml * cyc_per_vec * t_cyc * mk).sum(-1)  # (P, W) ns

    bytes_layer = Ain + Aout  # 8-bit activations = 1 B each
    router_bw = b(d.g_per_chip) * tech.router_flit_bytes  # bytes / cycle
    l_comm = (bytes_layer / router_bw * t_cyc * mk).sum(-1)

    spill = jnp.maximum(bytes_layer - glb_bytes, 0.0)
    l_dram = (spill * mk).sum(-1) / tech.dram_bw_bytes_per_ns

    latency = l_comp + l_comm + l_dram  # (P, W)

    # ---------------- energy -------------------------------------------------
    e_cell = v_op**2 * tech.g_avg_s * t_cyc * 1e3  # pJ per cell per phase
    cells = Kl * (Nl * cpw) * Gl  # active cells per presentation
    e_analog = (Ml * phases * cells * e_cell * mk).sum(-1)

    n_col_splits = _ceil_div(Nl * cpw, cols)
    n_row_splits = _ceil_div(Kl, rows)
    convs = Ml * phases * (Nl * cpw) * Gl  # ADC conversions (per col result)
    e_adc = (convs * tech.adc_energy_pj * mk).sum(-1)
    drives = Ml * phases * Kl * n_col_splits * Gl
    e_dac = (drives * tech.dac_energy_pj * mk).sum(-1)

    e_route = (bytes_layer * tech.router_energy_pj_per_byte * mk).sum(-1)
    e_buf = (
        bytes_layer
        * (tech.tile_buf_energy_pj_per_byte + tech.glb_energy_pj_per_byte)
        * mk
    ).sum(-1)
    e_dram = (spill * tech.dram_energy_pj_per_byte * mk).sum(-1)

    area = area_mm2(d, tech)  # (P,)
    # 1 mW x 1 ns = 1e-3 W x 1e-9 s = 1e-12 J = 1 pJ -> direct product is pJ
    e_leak = tech.leak_mw_per_mm2 * area[:, None] * latency

    energy = e_analog + e_adc + e_dac + e_route + e_buf + e_dram + e_leak

    valid = design_valid(d, tech)

    return EvalResult(
        energy_pj=energy,
        latency_ns=latency,
        area_mm2=area,
        fits=fits,
        valid=valid,
        util=util,
    )


def evaluate_one(design: Dict[str, float], ws: WorkloadSet, tech: TechParams = TECH) -> EvalResult:
    d = DesignArrays(**{k: jnp.asarray([v], jnp.float32) for k, v in design.items()})
    return evaluate_designs(d, ws, tech)
