"""Factorized IMC cost model: per-workload grid tables -> O(W) gathers.

``imc.cost.evaluate_designs_arrays`` re-reduces the full (P, W, L) layer
tensor on every call even though the search space is a tiny discrete grid
(``core.space``: 5 rows x 5 cols x 4 bits_cell, 10 GLB sizes) and every
layer-sum in the model is either design-independent or separable through a
handful of grid-indexed ceil terms.  This module reduces the layer axis
ONCE per workload into sufficient statistics:

  demand[w, r, c, b] = sum_l ceil(K/rows_r) * ceil(N*cpw_b/cols_c) * G     (R, C, Bc)
  dac[w, c, b]       = sum_l M * K * ceil(N*cpw_b/cols_c) * G              (C, Bc)
  spill[w, g]        = sum_l max(bytes_l - glb_g, 0)                       (Gn,)
  sum_m, sum_bytes, sum_mkng, sum_mng                                      scalars

(each masked by the layer mask), after which scoring a design is O(W)
table gathers at its ``space.decode_indices`` grid indices plus ~20 scalar
flops — independent of workload depth L.  Term structure mirrors
``evaluate_designs_arrays`` exactly; the dense path stays the oracle
(parity asserted in tests/test_tables.py and test_properties.py).

Tables are plain pytrees (NamedTuple of arrays), so they travel as traced
``ctx`` through the cached GA jits (``core.search`` ``backend="table"``),
vmap over a leading batch axis (``build_tables_batched``) and shard over
the ``search`` mesh axis like any other batched leaf
(``core.distributed.place_batched``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import space
from repro.imc.cost import EvalResult, area_mm2, design_valid
from repro.imc.tech import TECH, TechParams

# grid-index columns of a decoded (P, 9) index matrix (space.FIELDS order)
_I_ROWS = space.FIELDS.index("rows")
_I_COLS = space.FIELDS.index("cols")
_I_BITS = space.FIELDS.index("bits_cell")
_I_GLB = space.FIELDS.index("glb_mb")


class WorkloadTables(NamedTuple):
    """Per-workload sufficient statistics; every field has leading dim W
    (or (B, W) when built batched)."""

    demand: jnp.ndarray  # (W, R, C, Bc) crossbar demand per (rows, cols, bits)
    dac: jnp.ndarray  # (W, C, Bc)  sum M*K*ceil(N*cpw/cols)*G
    spill: jnp.ndarray  # (W, Gn)   sum max(bytes_l - glb, 0)
    sum_m: jnp.ndarray  # (W,)      sum M
    sum_bytes: jnp.ndarray  # (W,)  sum (A_in + A_out)
    sum_mkng: jnp.ndarray  # (W,)   sum M*K*N*G
    sum_mng: jnp.ndarray  # (W,)    sum M*N*G


def _build(feats: jnp.ndarray, mask: jnp.ndarray, tech: TechParams) -> WorkloadTables:
    """feats (W, L, 6), mask (W, L) -> tables.  Pure jnp; jit/vmap friendly."""
    M, K, N, A_in, A_out, G = [feats[..., i].astype(jnp.float32) for i in range(6)]
    mk = mask.astype(jnp.float32)

    rows_g = jnp.asarray(space.SPACE["rows"])  # (R,)
    cols_g = jnp.asarray(space.SPACE["cols"])  # (C,)
    bits_g = jnp.asarray(space.SPACE["bits_cell"])  # (Bc,)
    glb_g = jnp.asarray(space.SPACE["glb_mb"]) * jnp.float32(1 << 20)  # (Gn,) bytes

    cpw = jnp.ceil(jnp.float32(tech.weight_bits) / bits_g)  # (Bc,)
    row_splits = jnp.ceil(K[..., None] / rows_g)  # (W, L, R)
    col_splits = jnp.ceil(N[..., None, None] * cpw / cols_g[:, None])  # (W, L, C, Bc)

    gm = G * mk  # (W, L)
    demand = (
        row_splits[..., :, None, None] * col_splits[..., None, :, :]
        * gm[..., None, None, None]
    ).sum(-4)  # (W, R, C, Bc)
    dac = ((M * K * gm)[..., None, None] * col_splits).sum(-3)  # (W, C, Bc)

    bytes_l = A_in + A_out
    spill = (jnp.maximum(bytes_l[..., None] - glb_g, 0.0) * mk[..., None]).sum(-2)

    return WorkloadTables(
        demand=demand,
        dac=dac,
        spill=spill,
        sum_m=(M * mk).sum(-1),
        sum_bytes=(bytes_l * mk).sum(-1),
        sum_mkng=(M * K * N * G * mk).sum(-1),
        sum_mng=(M * N * G * mk).sum(-1),
    )


@partial(jax.jit, static_argnames=("tech",))
def build_tables_arrays(
    feats: jnp.ndarray, mask: jnp.ndarray, tech: TechParams = TECH
) -> WorkloadTables:
    """One workload set: feats (W, L, 6), mask (W, L) -> W-leading tables."""
    return _build(feats, mask, tech)


@partial(jax.jit, static_argnames=("tech",))
def build_tables_batched(
    feats: jnp.ndarray, mask: jnp.ndarray, tech: TechParams = TECH
) -> WorkloadTables:
    """Batched workload sets: feats (B, W, L, 6), mask (B, W, L) -> tables
    with a leading B axis on every leaf (one slice per batched search)."""
    return jax.vmap(lambda f, m: _build(f, m, tech))(feats, mask)


def table_bytes(tables: WorkloadTables) -> int:
    """Total table footprint in bytes (all leaves, any batch shape).

    The factorized backend trades workload-depth independence for a
    grid-resident memory cost: every leaf scales with the demand-grid
    density (``demand`` is (W, R, C, Bc), so a ``configure_grid(d)``
    densification multiplies it by ~d^3).  This is the number to weigh
    against the per-generation gather cost when picking a grid density —
    see benchmarks/README.md ("Fused generation kernel and grid
    density")."""
    return int(sum(leaf.size * leaf.dtype.itemsize for leaf in tables))


def grid_table_shape() -> dict:
    """Per-axis sizes of the ACTIVE grid that table leaves index over —
    the density characterization key (R, C, Bc, Gn)."""
    return {
        "rows": len(space.SPACE["rows"]),
        "cols": len(space.SPACE["cols"]),
        "bits_cell": len(space.SPACE["bits_cell"]),
        "glb_mb": len(space.SPACE["glb_mb"]),
    }


def evaluate_designs_tables(
    idx: jnp.ndarray, tables: WorkloadTables, tech: TechParams = TECH
) -> EvalResult:
    """Score designs given as (P, 9) integer grid indices
    (``space.decode_indices``) against precomputed tables — no layer axis
    anywhere: per design it is 3 table gathers + scalar algebra."""
    d = space.designs_from_indices(idx)
    ri, ci = idx[:, _I_ROWS], idx[:, _I_COLS]
    bi, gi = idx[:, _I_BITS], idx[:, _I_GLB]

    demand = tables.demand[:, ri, ci, bi].T  # (P, W)
    dac_t = tables.dac[:, ci, bi].T  # (P, W)
    spill = tables.spill[:, gi].T  # (P, W)

    capacity = (d.g_per_chip * d.t_per_router * d.c_per_tile).astype(jnp.float32)
    fits = demand <= capacity[:, None]
    util = demand / capacity[:, None]

    # design-side coefficients, (P, 1) against workload scalars (1, W)
    t_cyc = d.t_cycle_ns[:, None]
    phases = jnp.float32(tech.input_bits)
    cpw = jnp.ceil(jnp.float32(tech.weight_bits) / d.bits_cell)[:, None]

    # ---------------- latency ------------------------------------------------
    l_comp = tables.sum_m[None, :] * (phases * tech.adc_share) * t_cyc
    l_comm = (
        tables.sum_bytes[None, :]
        / (d.g_per_chip[:, None] * tech.router_flit_bytes)
        * t_cyc
    )
    l_dram = spill / tech.dram_bw_bytes_per_ns
    latency = l_comp + l_comm + l_dram  # (P, W)

    # ---------------- energy -------------------------------------------------
    e_cell = (d.v_op**2 * tech.g_avg_s * d.t_cycle_ns * 1e3)[:, None]
    e_analog = tables.sum_mkng[None, :] * phases * cpw * e_cell
    e_adc = tables.sum_mng[None, :] * phases * cpw * tech.adc_energy_pj
    e_dac = dac_t * phases * tech.dac_energy_pj
    e_route = tables.sum_bytes[None, :] * tech.router_energy_pj_per_byte
    e_buf = tables.sum_bytes[None, :] * (
        tech.tile_buf_energy_pj_per_byte + tech.glb_energy_pj_per_byte
    )
    e_dram = spill * tech.dram_energy_pj_per_byte

    area = area_mm2(d, tech)  # (P,)
    e_leak = tech.leak_mw_per_mm2 * area[:, None] * latency
    energy = e_analog + e_adc + e_dac + e_route + e_buf + e_dram + e_leak

    return EvalResult(
        energy_pj=energy,
        latency_ns=latency,
        area_mm2=area,
        fits=fits,
        valid=design_valid(d, tech),
        util=util,
    )


def evaluate_genomes_tables(
    genomes: jnp.ndarray, tables: WorkloadTables, tech: TechParams = TECH
) -> EvalResult:
    """Convenience: (P, n) genomes in [0, 1) -> table-path EvalResult."""
    return evaluate_designs_tables(space.decode_indices(genomes), tables, tech)
