"""Technology constants for the IMC analytical cost model.

32 nm CMOS + RRAM devices following the paper's stated stack (Sec. III-B):
RRAM from NeuroSim [27] (Lu et al., Frontiers in AI 4, 2021), ISAAC-style
tile/router hierarchy [28], CIMLoop/Accelergy-class component energies
[29][31].  Each constant cites its source class; the *structure* of the
model (what scales with what) is what reproduces the paper's phenomena —
fit failures, V/f coupling, area/energy/latency trade-offs.

Units: J, s, m^2 are avoided — we use pJ, ns, mm^2 consistently.
"""
from __future__ import annotations

from typing import NamedTuple


class TechParams(NamedTuple):
    # ---- RRAM device (NeuroSim [27]: HfO2 RRAM, 1T1R) ----------------------
    r_on_ohm: float = 6.0e3          # LRS resistance
    r_off_ohm: float = 1.0e5         # HRS resistance
    cell_area_f2: float = 12.0       # 1T1R cell, in F^2
    feature_nm: float = 32.0         # CMOS node

    # ---- data / precision (paper Sec. IV) -----------------------------------
    weight_bits: int = 8             # 8-bit quantized weights
    input_bits: int = 8              # 8-bit inputs, bit-serial 1b DAC
    adc_bits: int = 8                # fixed 8-bit ADC

    # ---- peripheral circuits (ISAAC [28] / NeuroSim scaled to 32nm) --------
    adc_energy_pj: float = 2.0       # 8-bit SAR conversion
    adc_area_mm2: float = 3.0e-3     # 8-bit SAR @32nm
    adc_share: int = 32              # columns muxed per ADC (32:1, NeuroSim-style)
    dac_energy_pj: float = 0.05      # 1-bit row driver per row per phase
    driver_area_mm2_per_row: float = 2.0e-6

    # ---- interconnect (ISAAC-style shared routers) --------------------------
    router_energy_pj_per_byte: float = 1.6   # ~0.1 pJ/bit/hop x 2 hops
    router_area_mm2: float = 0.05
    router_flit_bytes: float = 4.0           # bytes moved per router per cycle

    # ---- buffers (CACTI-class SRAM @32nm) -----------------------------------
    tile_buf_energy_pj_per_byte: float = 1.0
    glb_energy_pj_per_byte: float = 3.0
    sram_area_mm2_per_mb: float = 1.4
    tile_buf_kb: float = 8.0                 # per-tile IO buffer

    # ---- off-chip (LPDDR4-class) --------------------------------------------
    dram_energy_pj_per_byte: float = 32.0
    dram_bw_bytes_per_ns: float = 25.6       # 25.6 GB/s

    # ---- leakage --------------------------------------------------------------
    leak_mw_per_mm2: float = 5.0

    # ---- voltage/frequency coupling ------------------------------------------
    # alpha-power delay model: t_min(V) = K * V / (V - Vth)^alpha, normalized
    # so that t_min(0.9 V) = 1.0 ns  (i.e. 1 GHz max at nominal voltage).
    v_nominal: float = 0.9
    v_th: float = 0.35
    alpha_power: float = 1.3

    # derived -----------------------------------------------------------------
    @property
    def g_avg_s(self) -> float:
        """Average cell conductance (Siemens): mid between LRS/HRS."""
        return 0.5 * (1.0 / self.r_on_ohm + 1.0 / self.r_off_ohm)

    @property
    def cell_area_mm2(self) -> float:
        f_m = self.feature_nm * 1e-9
        return self.cell_area_f2 * (f_m ** 2) * 1e6  # m^2 -> mm^2

    def t_min_ns(self, v: float) -> float:
        """Minimum cycle time at operating voltage v (alpha-power law)."""
        k = 1.0 * (self.v_nominal - self.v_th) ** self.alpha_power / self.v_nominal
        return k * v / (v - self.v_th) ** self.alpha_power

    def cell_read_energy_pj(self, v: float, t_pulse_ns: float) -> float:
        """E = V^2 * G * t per active cell per 1-bit phase (pJ)."""
        return (v ** 2) * self.g_avg_s * t_pulse_ns * 1e3  # V^2*S*ns = 1e-9 J*1e3->pJ? see note

    # NOTE on units: V^2 [V^2] * G [S] * t [ns=1e-9 s] = 1e-9 J = 1 nJ*.. ->
    # V^2*G*t_ns gives nJ*1e-0... concretely 0.81 * 1.77e-4 * 1.0 = 1.43e-4 nJ
    # = 0.143 pJ; the *1e3 factor converts (V^2 * S * ns) -> pJ.


TECH = TechParams()
