"""Version shims shared by the Pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # fail loudly at import, not at kernel launch
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version"
    )
