"""Pallas TPU kernel: blockwise online-softmax attention (GQA, causal, SWA).

Tiling (HW-codesign for the MXU + VMEM hierarchy):

  * grid = (B, H, Sq/TQ, Skv/TK); the KV axis is the innermost
    ("arbitrary") dim — the (m, l, acc) online-softmax state lives in VMEM
    scratch and persists across KV steps of one (b, h, q-tile),
  * q/k/v blocks are (TQ, D) / (TK, D) MXU-aligned tiles (TQ = TK = 128,
    D padded to a multiple of 128 by the wrapper),
  * GQA is pure indexing: the kv BlockSpec maps query head h to kv head
    h // (H // KV) — no repeat/copy of K/V in HBM or VMEM,
  * causal/window masking is computed from block-relative iotas; fully
    masked KV blocks still iterate (grid is static) but their contribution
    is exp(-inf) = 0.

The output block writes once, on the last KV step: out = acc / l.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # (1, 1, TQ, D)
    k_ref,  # (1, 1, TK, D)
    v_ref,  # (1, 1, TK, D)
    o_ref,  # (1, 1, TQ, D)
    m_ref,  # VMEM (TQ, 128) running max
    l_ref,  # VMEM (TQ, 128) running sum-exp
    acc_ref,  # VMEM (TQ, D) weighted accumulator
    *,
    scale: float,
    causal: bool,
    window: int,
    n_kv: int,
    block_q: int,
    block_k: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (TQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (TK, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TQ, TK)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    kv_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones_like(s, jnp.bool_)
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]  # (TQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # (TQ, TK)
    corr = jnp.exp(m_prev - m_new)  # (TQ, 1)
    l_new = l_ref[:, :1] * corr + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (TQ, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _done():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, KV, Skv, D)
    v: jnp.ndarray,  # (B, KV, Skv, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Head-major layouts; wrapper in ops.py does transposes/padding."""
    B, H, Sq, D = q.shape
    _, KV, Skv, _ = k.shape
    assert H % KV == 0
    g = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    n_kv = Skv // block_k
    grid = (B, H, Sq // block_q, n_kv)

    kernel = functools.partial(
        _attn_kernel,
        scale=D ** -0.5 if scale is None else scale,
        causal=causal,
        window=window,
        n_kv=n_kv,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
