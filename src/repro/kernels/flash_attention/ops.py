"""Jitted wrapper: (B, S, H, D)-convention flash attention via Pallas.

Handles layout (seq-major -> head-major), D-padding to the 128-lane MXU
width, and Sq/Skv padding to block multiples; drop-in for
``repro.models.attention.flash_attention``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


@partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KV, D)
    v: jnp.ndarray,  # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))
    sq_pad = -(-Sq // bq) * bq
    skv_pad = -(-Skv // bk) * bk
    d_pad = -(-D // 128) * 128 if D > 8 else D

    qh = jnp.moveaxis(q, 2, 1)  # (B, H, Sq, D)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    qh = jnp.pad(qh, ((0, 0), (0, 0), (0, sq_pad - Sq), (0, d_pad - D)))
    kh = jnp.pad(kh, ((0, 0), (0, 0), (0, skv_pad - Skv), (0, d_pad - D)))
    vh = jnp.pad(vh, ((0, 0), (0, 0), (0, skv_pad - Skv), (0, d_pad - D)))
    # padded KV rows must never win the softmax: push them outside the
    # causal horizon by masking via an effective window?  Simpler: padded
    # keys have k = 0 -> score 0, which CAN beat real scores.  Mask them
    # by position: padded kv positions are >= Skv; for causal attention
    # q_pos < Skv + q_offset keeps them masked only if q_pos < kv_pos —
    # true whenever Sq <= Skv (our use).  For non-causal (encoder), rely
    # on explicit masking below via window trick — instead we handle it
    # by setting padded K rows to a large negative projection surrogate:
    if skv_pad != Skv and not causal:
        # make padded keys unreachable: give them +inf-free mask by zero v
        # and -inf-like scores via k filled with 0 and an additive bias is
        # not expressible post-hoc; instead fall back to causal=False safe
        # path: set padded k rows far along D so dot stays 0, then subtract
        # via q_offset-independent positional mask inside the kernel using
        # window: not applicable -> use exact-length call instead.
        raise ValueError(
            "non-causal pallas path requires Skv to be a multiple of block_k"
        )

    out = flash_attention_pallas(
        qh, kh, vh,
        causal=causal, window=window, q_offset=q_offset,
        block_q=bq, block_k=bk, interpret=interpret,
        scale=D ** -0.5,  # true head dim, not the lane-padded one
    )
    out = out[:, :, :Sq, :D]
    return jnp.moveaxis(out, 1, 2)  # (B, Sq, H, D)
