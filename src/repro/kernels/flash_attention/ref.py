"""Pure-jnp oracle for the flash-attention kernel.

Re-exports the model's unchunked O(S^2) reference — the kernel must match
this math exactly (same masking semantics: causal + sliding window + GQA).
"""
from __future__ import annotations

from repro.models.attention import attention_reference  # noqa: F401
