from repro.kernels.ga_gen_step.kernel import default_interpret, ga_gen_step_pallas
from repro.kernels.ga_gen_step.ops import make_kernel_gen_step

__all__ = ["default_interpret", "ga_gen_step_pallas", "make_kernel_gen_step"]
