"""Pallas kernel: one WHOLE GA generation per launch (table backend).

The fused lax path (``core.ga._make_gen_step(fused=True)``) still round-
trips the (2P, n) offspring block and the survival keys through HBM
between the XLA ops of a generation.  This kernel keeps the entire
generation — tournament selection, SBX, polynomial mutation, the
factorized-table cost model with the indexed objective, and (mu+lambda)
survival — resident in VMEM and writes only the new population, its
scores, and the history row.

Bit-parity with the lax path is a design constraint, achieved by using
only exactly-representable re-expressions of the lax ops:

  * gathers become masked where-selects / one-hot contractions — exact
    because exactly one position is selected and ``0 * finite = 0``,
    ``0 + v = v``; score gathers use where-select (never multiply) so
    +inf infeasible scores survive untouched,
  * table lookups at ``decode_indices`` grid points become one-hot
    matmuls against the flattened tables (finite values -> exact),
  * the survival sort becomes a bitonic compare-exchange network over
    the same unique (total-order-int32, index) key pairs the lax sort
    uses; unique keys mean ANY correct sort produces the identical
    permutation.  Partner access ``i ^ j`` is a pure reshape + flip
    (TPU-expressible: no dynamic gathers anywhere in the network),
  * every cost-model line mirrors ``imc.tables.evaluate_designs_tables``
    / ``imc.cost.area_mm2`` / ``design_valid`` / the indexed objective
    op-for-op.

Tested in interpret mode against the lax generation step
(tests/test_fused_gen.py); compiled lowering targets TPU hosts.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import space
from repro.imc.tech import TECH, TechParams


def default_interpret() -> bool:
    """Interpret the kernel unless the default backend is a real TPU (same
    policy as ``kernels.imc_eval``): TPU hosts get the Mosaic kernel with
    no flag, CPU/GPU hosts (this container, CI) run the interpreter."""
    return jax.default_backend() != "tpu"


def _next_pow2(x: int) -> int:
    n = 1
    while n < x:
        n *= 2
    return n


def _sel_vals(idx, vec, size):
    """``vec[idx]`` as a masked where-select (no multiply: +inf survives)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], size), 1)
    eq = idx[:, None] == iota
    return jnp.where(eq, vec[None, :], 0.0).sum(axis=1)


def _sel_rows(idx, mat, size):
    """``mat[idx]`` (rows) as a masked where-select."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], size), 1)
    eq = idx[:, None] == iota
    return jnp.where(eq[:, :, None], mat[None, :, :], 0.0).sum(axis=1)


def _pow_recip_eta1(x, eta):
    if eta == 3.0:
        return jnp.sqrt(jnp.sqrt(x))
    return x ** (1.0 / (eta + 1.0))


def _pow_eta1(x, eta):
    if eta == 3.0:
        x2 = x * x
        return x2 * x2
    return x ** (eta + 1.0)


def _bitonic_sort(key, idx, val, N):
    """Ascending bitonic network on unique (key, idx) int32 pairs, carrying
    ``val``.  Partner ``i ^ j`` is computed by reshape + flip — no gathers;
    the stage masks come from a traced iota (pallas kernels cannot capture
    array constants)."""
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)[0]

    def xor_swap(x, j):
        return jnp.flip(x.reshape(N // (2 * j), 2, j), axis=1).reshape(N)

    k = 2
    while k <= N:
        up = (pos & k) == 0  # ascending block mask (static stage bit)
        j = k // 2
        while j >= 1:
            is_lo = (pos & j) == 0  # bit j clear: lower partner
            kp, ip, vp = xor_swap(key, j), xor_swap(idx, j), xor_swap(val, j)
            gt = (key > kp) | ((key == kp) & (idx > ip))
            # unique pairs: my-pair < partner-pair <=> ~gt
            take = jnp.where(is_lo == up, gt, ~gt)
            key = jnp.where(take, kp, key)
            idx = jnp.where(take, ip, idx)
            val = jnp.where(take, vp, val)
            j //= 2
        k *= 2
    return key, idx, val


def _gen_kernel(
    pop_ref,  # (P, n) current population
    scores_ref,  # (1, P)
    u_ref,  # (1, TOT) this generation's uniform block
    demand_ref,  # (W, R*C*Bc) flattened demand table
    dac_ref,  # (W, C*Bc)
    spill_ref,  # (W, Gn)
    sums_ref,  # (4, W) sum_m / sum_bytes / sum_mkng / sum_mng
    grids_ref,  # (n, Gmax) grid values, zero-padded per row
    kind_ref,  # (1, 1) int32 objective kind index
    area_ref,  # (1, 1) float32 area constraint
    new_pop_ref,  # (P, n) out
    new_scores_ref,  # (1, P) out
    children_ref,  # (P, n) out (history row)
    child_scores_ref,  # (1, P) out
    *,
    tech: TechParams,
    grid_sizes: Tuple[int, ...],
    pop_size: int,
    n_genes: int,
    sbx_prob: float,
    sbx_eta: float,
    mut_eta: float,
):
    P, n = pop_size, n_genes
    mut_prob = 1.0 / n
    n_pairs = (P + 1) // 2
    n_contest = 2 * n_pairs
    o_t = 2 * n_contest
    o_u = o_t + n_pairs * n
    o_p = o_u + n_pairs
    o_g = o_p + n_pairs * n
    o_mu = o_g + P * n
    o_md = o_mu + P * n

    pop = pop_ref[...]
    scores = scores_ref[0, :]
    u = u_ref[0, :]

    # ---- binary tournament (one-hot select, never a dynamic gather)
    ti = (u[:o_t] * P).astype(jnp.int32)
    ca, cb = ti[:n_contest], ti[n_contest:o_t]
    parents = jnp.where(_sel_vals(ca, scores, P) <= _sel_vals(cb, scores, P),
                        ca, cb)
    p1 = _sel_rows(parents[:n_pairs], pop, P)
    p2 = _sel_rows(parents[n_pairs:], pop, P)

    # ---- SBX
    ub = u[o_t:o_u].reshape(n_pairs, n)
    beta = jnp.where(
        ub <= 0.5,
        _pow_recip_eta1(2.0 * ub, sbx_eta),
        _pow_recip_eta1(1.0 / (2.0 * (1.0 - ub)), sbx_eta),
    )
    c1 = 0.5 * ((1 + beta) * p1 + (1 - beta) * p2)
    c2 = 0.5 * ((1 - beta) * p1 + (1 + beta) * p2)
    do_pair = u[o_u:o_p].reshape(n_pairs, 1) < sbx_prob
    do_gene = u[o_p:o_g].reshape(n_pairs, n) < 0.5
    use = do_pair & do_gene
    c1 = jnp.clip(jnp.where(use, c1, p1), 0.0, 1.0 - 1e-7)
    c2 = jnp.clip(jnp.where(use, c2, p2), 0.0, 1.0 - 1e-7)
    children = jnp.concatenate([c1, c2], axis=0)[:P]

    # ---- polynomial mutation
    um = u[o_g:o_mu].reshape(P, n)
    lo, hi = children, 1.0 - children
    d1 = _pow_recip_eta1(
        2 * um + (1 - 2 * um) * _pow_eta1(1 - lo, mut_eta), mut_eta) - 1
    d2 = 1 - _pow_recip_eta1(
        2 * (1 - um) + (2 * um - 1) * _pow_eta1(1 - hi, mut_eta), mut_eta)
    delta = jnp.where(um <= 0.5, d1, d2)
    do = u[o_mu:o_md].reshape(P, n) < mut_prob
    children = jnp.clip(
        jnp.where(do, children + delta, children), 0.0, 1.0 - 1e-7)

    # ---- decode + grid-value lookup (one-hot; grid constants are finite)
    i_rows = space.FIELDS.index("rows")
    i_cols = space.FIELDS.index("cols")
    i_bits = space.FIELDS.index("bits_cell")
    i_glb = space.FIELDS.index("glb_mb")
    idxs, vals = [], []
    for j, nj in enumerate(grid_sizes):
        ij = jnp.clip((children[:, j] * nj).astype(jnp.int32), 0, nj - 1)
        idxs.append(ij)
        vals.append(_sel_vals(ij, grids_ref[j, :nj], nj))
    d = dict(zip(space.FIELDS, vals))

    # ---- table gathers as one-hot matmuls against the flattened tables
    R, C = grid_sizes[i_rows], grid_sizes[i_cols]
    Bc, Gn = grid_sizes[i_bits], grid_sizes[i_glb]
    ri, ci, bi, gi = idxs[i_rows], idxs[i_cols], idxs[i_bits], idxs[i_glb]
    fi = (ri * C + ci) * Bc + bi  # row-major (R, C, Bc) flat index
    iota_rcb = jax.lax.broadcasted_iota(jnp.int32, (P, R * C * Bc), 1)
    oh_rcb = (fi[:, None] == iota_rcb).astype(jnp.float32)
    demand = oh_rcb @ demand_ref[...].T  # (P, W)
    fj = ci * Bc + bi
    iota_cb = jax.lax.broadcasted_iota(jnp.int32, (P, C * Bc), 1)
    oh_cb = (fj[:, None] == iota_cb).astype(jnp.float32)
    dac_t = oh_cb @ dac_ref[...].T  # (P, W)
    iota_g = jax.lax.broadcasted_iota(jnp.int32, (P, Gn), 1)
    oh_g = (gi[:, None] == iota_g).astype(jnp.float32)
    spill = oh_g @ spill_ref[...].T  # (P, W)

    sums = sums_ref[...]
    sum_m, sum_bytes = sums[0], sums[1]
    sum_mkng, sum_mng = sums[2], sums[3]

    # ---- cost model: op-for-op imc.tables.evaluate_designs_tables
    capacity = (d["g_per_chip"] * d["t_per_router"] * d["c_per_tile"]).astype(
        jnp.float32)
    fits = demand <= capacity[:, None]

    t_cyc = d["t_cycle_ns"][:, None]
    phases = jnp.float32(tech.input_bits)
    cpw = jnp.ceil(jnp.float32(tech.weight_bits) / d["bits_cell"])[:, None]

    l_comp = sum_m[None, :] * (phases * tech.adc_share) * t_cyc
    l_comm = (sum_bytes[None, :]
              / (d["g_per_chip"][:, None] * tech.router_flit_bytes) * t_cyc)
    l_dram = spill / tech.dram_bw_bytes_per_ns
    latency = l_comp + l_comm + l_dram

    e_cell = (d["v_op"] ** 2 * tech.g_avg_s * d["t_cycle_ns"] * 1e3)[:, None]
    e_analog = sum_mkng[None, :] * phases * cpw * e_cell
    e_adc = sum_mng[None, :] * phases * cpw * tech.adc_energy_pj
    e_dac = dac_t * phases * tech.dac_energy_pj
    e_route = sum_bytes[None, :] * tech.router_energy_pj_per_byte
    e_buf = sum_bytes[None, :] * (
        tech.tile_buf_energy_pj_per_byte + tech.glb_energy_pj_per_byte)
    e_dram = spill * tech.dram_energy_pj_per_byte

    # area_mm2, inlined
    n_tiles = d["g_per_chip"] * d["t_per_router"]
    n_xbars = n_tiles * d["c_per_tile"]
    xbar = (d["rows"] * d["cols"] * tech.cell_area_mm2
            + d["rows"] * tech.driver_area_mm2_per_row
            + (d["cols"] / tech.adc_share) * tech.adc_area_mm2)
    tile_buf = tech.tile_buf_kb / 1024.0 * tech.sram_area_mm2_per_mb
    area = (n_xbars * xbar + n_tiles * tile_buf
            + d["g_per_chip"] * tech.router_area_mm2
            + d["glb_mb"] * tech.sram_area_mm2_per_mb) * 1.10

    e_leak = tech.leak_mw_per_mm2 * area[:, None] * latency
    energy = e_analog + e_adc + e_dac + e_route + e_buf + e_dram + e_leak

    # design_valid, inlined
    kv = (tech.v_nominal - tech.v_th) ** tech.alpha_power / tech.v_nominal
    t_min = kv * d["v_op"] / (d["v_op"] - tech.v_th) ** tech.alpha_power
    valid = d["t_cycle_ns"] >= t_min

    # ---- indexed objective (where-chain == trailing-axis stack + gather)
    e = energy.max(axis=-1)
    l = latency.max(axis=-1)
    kind = kind_ref[0, 0]
    s = jnp.where(kind == 0, e * l * area,
                  jnp.where(kind == 1, e * l, jnp.where(kind == 2, e, l)))
    feasible = fits.all(axis=-1) & valid & (area <= area_ref[0, 0])
    child_scores = jnp.where(feasible, s, jnp.float32(jnp.inf))

    # ---- (mu + lambda) survival: bitonic network on total-order keys
    allg = jnp.concatenate([pop, children], axis=0)
    alls = jnp.concatenate([scores, child_scores], axis=0)
    bits = jax.lax.bitcast_convert_type(alls.astype(jnp.float32), jnp.int32)
    okey = jnp.where(bits < 0, -(bits & jnp.int32(0x7FFFFFFF)), bits)
    N = _next_pow2(2 * P)
    iota2p = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)[0]
    key_pad = jnp.concatenate(
        [okey, jnp.full((N - 2 * P,), jnp.int32(2**31 - 1))])
    val_pad = jnp.concatenate([alls, jnp.zeros((N - 2 * P,), jnp.float32)])
    _, sidx, sval = _bitonic_sort(key_pad, iota2p, val_pad, N)
    new_pop_ref[...] = _sel_rows(sidx[:P], allg, 2 * P)
    new_scores_ref[0, :] = sval[:P]
    children_ref[...] = children
    child_scores_ref[0, :] = child_scores


def ga_gen_step_pallas(
    pop: jnp.ndarray,  # (P, n)
    scores: jnp.ndarray,  # (P,)
    u: jnp.ndarray,  # (TOT,) pre-drawn uniforms
    tables,  # imc.tables.WorkloadTables (W-leading leaves)
    kind: jnp.ndarray,  # () int32
    area_constr: jnp.ndarray,  # () float32
    *,
    tech: TechParams = TECH,
    sbx_prob: float,
    sbx_eta: float,
    mut_eta: float,
    interpret: Optional[bool] = None,
):
    """One generation in one kernel launch.  Returns
    ``(new_pop, new_scores, children, child_scores)`` bit-identical to the
    fused lax generation step fed the same uniform block."""
    if interpret is None:
        interpret = default_interpret()
    P, n = pop.shape
    W = tables.demand.shape[0]
    grids = [np.asarray(space.SPACE[f], np.float32) for f in space.FIELDS]
    grid_sizes = tuple(len(g) for g in grids)
    gmax = max(grid_sizes)
    grids_pad = np.zeros((n, gmax), np.float32)
    for j, g in enumerate(grids):
        grids_pad[j, : len(g)] = g
    demand2 = tables.demand.reshape(W, -1)
    dac2 = tables.dac.reshape(W, -1)
    sums = jnp.stack(
        [tables.sum_m, tables.sum_bytes, tables.sum_mkng, tables.sum_mng])
    kernel = functools.partial(
        _gen_kernel, tech=tech, grid_sizes=grid_sizes, pop_size=P, n_genes=n,
        sbx_prob=sbx_prob, sbx_eta=sbx_eta, mut_eta=mut_eta,
    )
    out_shape = [
        jax.ShapeDtypeStruct((P, n), jnp.float32),
        jax.ShapeDtypeStruct((1, P), jnp.float32),
        jax.ShapeDtypeStruct((P, n), jnp.float32),
        jax.ShapeDtypeStruct((1, P), jnp.float32),
    ]
    new_pop, new_scores, children, child_scores = pl.pallas_call(
        kernel, out_shape=out_shape, interpret=interpret,
    )(
        pop.astype(jnp.float32),
        scores.astype(jnp.float32)[None, :],
        u.astype(jnp.float32)[None, :],
        demand2, dac2, tables.spill, sums,
        jnp.asarray(grids_pad),
        kind.astype(jnp.int32).reshape(1, 1),
        area_constr.astype(jnp.float32).reshape(1, 1),
    )
    return new_pop, new_scores[0], children, child_scores[0]
