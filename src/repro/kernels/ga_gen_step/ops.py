"""Drop-in generation-step factory for ``core.ga._make_gen_step``.

``make_kernel_gen_step`` returns a ``gen(carry, k)`` with the exact
contract of the lax generation body (same one-uniform-block RNG layout,
same ``((new_pop, new_scores), (children, child_scores))`` outputs), or
``None`` when the eval context is not the table+indexed-objective shape
the kernel understands — the caller then falls back to the lax path.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ga_gen_step.kernel import ga_gen_step_pallas


def make_kernel_gen_step(
    eval_fn,
    ctx,
    *,
    pop_size: int,
    n_genes: int,
    sbx_prob: float,
    sbx_eta: float,
    mut_eta: float,
    interpret: Optional[bool] = None,
) -> Optional[Callable]:
    """Build a whole-generation kernel step, or return ``None`` when the
    (eval_fn, ctx) pair is not the table-backend indexed-objective form.

    The engine marks its table+indexed eval closures with a
    ``gen_kernel_tech`` attribute (the TechParams baked into the tables);
    anything else — dense backends, custom objective callables, ad-hoc
    eval functions in tests — is out of kernel scope by construction.
    """
    tech = getattr(eval_fn, "gen_kernel_tech", None)
    if tech is None:
        return None
    if not (isinstance(ctx, tuple) and len(ctx) >= 3):
        return None
    tables, kind, area = ctx[0], ctx[-2], ctx[-1]

    P, n = pop_size, n_genes
    n_pairs = (P + 1) // 2
    n_contest = 2 * n_pairs
    tot = 2 * n_contest + n_pairs * n + n_pairs + n_pairs * n + 2 * P * n

    def gen(carry, k):
        pop, scores = carry
        u = jax.random.uniform(k, (tot,))
        new_pop, new_scores, children, child_scores = ga_gen_step_pallas(
            pop, scores, u, tables,
            jnp.asarray(kind), jnp.asarray(area),
            tech=tech, sbx_prob=sbx_prob, sbx_eta=sbx_eta, mut_eta=mut_eta,
            interpret=interpret,
        )
        return (new_pop, new_scores), (children, child_scores)

    return gen
