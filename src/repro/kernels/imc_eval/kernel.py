"""Pallas TPU kernel: IMC design-space population evaluation.

The paper's hot loop — evaluate a population of chip designs against a
whole SET of workloads' layer tables — as a VMEM-tiled 3-D grid in ONE
kernel launch:

  * designs live on the LANE axis (tile 128, the VPU vector width),
  * layers live on the SUBLANE axis (tile 8),
  * workloads are a middle grid axis (W is small; each (p, w) cell owns
    one row of the (W, P) accumulators),
  * grid = (P // 128, W, L // 8); the layer axis is the innermost
    ("arbitrary") grid dim so each (design-tile, workload)'s partial sums
    accumulate in-place in the output block across layer steps,
  * all tech constants are compile-time Python floats (baked into the
    kernel body; nothing but the design/layer tiles touches VMEM).

Layout choices (HW-codesign): every per-(design, layer) term is an
(8, 128) outer-product-style vector op — sublane-broadcast of the layer
feature column against the lane vector of design parameters.  This is the
TPU-native shape of the paper's evaluator: no MXU needed (no matmuls),
pure 8x128 VPU tiles, one pass over HBM for all W layer tables.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.imc.tech import TECH, TechParams
from repro.kernels._compat import CompilerParams as _CompilerParams

LANE = 128  # designs per tile (lane axis)
SUB = 8  # layers per tile (sublane axis)


def default_interpret() -> bool:
    """Interpret the kernel unless the default backend is a real TPU, so
    TPU runs get the Mosaic-compiled kernel with no flag and CPU/GPU hosts
    (this container, CI) keep working via the interpreter."""
    return jax.default_backend() != "tpu"


def _eval_kernel(
    feats_ref,  # (1, 6, SUB)   this workload's layer-features tile
    mask_ref,  # (1, 1, SUB)
    d_ref,  # (9, LANE)  design params tile (param-major)
    energy_ref,  # (1, LANE)  accumulated outputs, one (w, p) row each
    latency_ref,  # (1, LANE)
    demand_ref,  # (1, LANE)
    *,
    tech: TechParams,
):
    li = pl.program_id(2)  # layer-tile index (innermost, sequential)

    d = d_ref[...]  # (9, LANE)
    rows, cols = d[0:1], d[1:2]  # (1, LANE)
    g_chip, v_op, bits = d[4:5], d[5:6], d[6:7]
    t_cyc, glb_mb = d[7:8], d[8:9]

    f = feats_ref[0]  # (6, SUB)
    mk = mask_ref[0].astype(jnp.float32)  # (1, SUB)

    # (SUB, 1) feature columns x (1, LANE) design rows -> (SUB, LANE) tiles
    def col(i):
        return f[i : i + 1, :].T  # (SUB, 1)

    M, K, N, Ain, Aout, G = (col(i) for i in range(6))
    mkc = mk.T  # (SUB, 1)

    phases = jnp.float32(tech.input_bits)
    cpw = jnp.ceil(jnp.float32(tech.weight_bits) / bits)  # (1, LANE)
    ncol = jnp.ceil(N * cpw / cols)  # (SUB, LANE)
    nrow = jnp.ceil(K / rows)
    xb = nrow * ncol * G
    demand = (xb * mkc).sum(axis=0, keepdims=True)  # (1, LANE)

    bytes_l = Ain + Aout
    l_comp = M * (phases * tech.adc_share) * t_cyc
    l_comm = bytes_l / (g_chip * tech.router_flit_bytes) * t_cyc
    spill = jnp.maximum(bytes_l - glb_mb * float(1 << 20), 0.0)
    l_dram = spill * (1.0 / tech.dram_bw_bytes_per_ns)
    latency = ((l_comp + l_comm + l_dram) * mkc).sum(axis=0, keepdims=True)

    e_cell = v_op * v_op * (tech.g_avg_s * 1e3) * t_cyc  # (1, LANE)
    e_analog = M * phases * (K * (N * cpw) * G) * e_cell
    e_adc = M * phases * (N * cpw) * G * tech.adc_energy_pj
    e_dac = M * phases * K * ncol * G * tech.dac_energy_pj
    e_route = bytes_l * tech.router_energy_pj_per_byte
    e_buf = bytes_l * (
        tech.tile_buf_energy_pj_per_byte + tech.glb_energy_pj_per_byte
    )
    e_dram = spill * tech.dram_energy_pj_per_byte
    energy = (
        (e_analog + e_adc + e_dac + e_route + e_buf + e_dram) * mkc
    ).sum(axis=0, keepdims=True)

    @pl.when(li == 0)
    def _init():
        energy_ref[...] = energy
        latency_ref[...] = latency
        demand_ref[...] = demand

    @pl.when(li > 0)
    def _acc():
        energy_ref[...] += energy
        latency_ref[...] += latency
        demand_ref[...] += demand


def imc_eval_pallas_multi(
    designs: jnp.ndarray,  # (P, 9)
    feats: jnp.ndarray,  # (W, L, 6)
    mask: jnp.ndarray,  # (W, L)
    *,
    tech: TechParams = TECH,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pad, tile and launch ONCE for the whole workload set.

    Returns (energy, latency, demand), each (W, P)."""
    if interpret is None:
        interpret = default_interpret()
    P = designs.shape[0]
    W, L = feats.shape[0], feats.shape[1]
    Pp = -(-P // LANE) * LANE
    Lp = -(-L // SUB) * SUB

    dT = jnp.zeros((9, Pp), jnp.float32)
    dT = dT.at[:, :P].set(designs.T.astype(jnp.float32))
    # padded designs keep zeros -> guard divisions: set rows/cols/bits/g to 1
    if Pp != P:
        ones = jnp.ones((9, Pp - P), jnp.float32)
        dT = dT.at[:, P:].set(ones)
    fT = jnp.zeros((W, 6, Lp), jnp.float32)
    fT = fT.at[:, :, :L].set(jnp.transpose(feats, (0, 2, 1)).astype(jnp.float32))
    mk = jnp.zeros((W, 1, Lp), jnp.float32)
    mk = mk.at[:, 0, :L].set(mask.astype(jnp.float32))

    grid = (Pp // LANE, W, Lp // SUB)
    out_shape = [jax.ShapeDtypeStruct((W, Pp), jnp.float32)] * 3
    out_spec = pl.BlockSpec((1, LANE), lambda p, w, l: (w, p))
    energy, latency, demand = pl.pallas_call(
        functools.partial(_eval_kernel, tech=tech),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 6, SUB), lambda p, w, l: (w, 0, l)),
            pl.BlockSpec((1, 1, SUB), lambda p, w, l: (w, 0, l)),
            pl.BlockSpec((9, LANE), lambda p, w, l: (0, p)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(fT, mk, dT)
    return energy[:, :P], latency[:, :P], demand[:, :P]


def imc_eval_pallas(
    designs: jnp.ndarray,  # (P, 9)
    feats: jnp.ndarray,  # (L, 6)
    mask: jnp.ndarray,  # (L,)
    *,
    tech: TechParams = TECH,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-workload convenience wrapper.  Returns (P,) each."""
    e, l, x = imc_eval_pallas_multi(
        designs, feats[None], mask[None], tech=tech, interpret=interpret
    )
    return e[0], l[0], x[0]
