"""Jitted wrapper: full EvalResult via the Pallas imc_eval kernel.

Drop-in for ``repro.imc.cost.evaluate_designs`` — the per-(design, layer)
sums run in the kernel (one launch per workload; W is small), the design-
global terms (area, leakage, V/f validity, fits) are tiny jnp epilogues.

``backend="jnp"`` selects the pure-jnp oracle path (identical math); tests
assert allclose between the two across shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.imc.cost import DesignArrays, EvalResult, area_mm2
from repro.imc.tech import TECH, TechParams
from repro.kernels.imc_eval import ref as ref_mod
from repro.kernels.imc_eval.kernel import imc_eval_pallas
from repro.workloads.pack import WorkloadSet


def evaluate_designs_kernel(
    d: DesignArrays,
    ws: WorkloadSet,
    tech: TechParams = TECH,
    *,
    backend: Literal["pallas", "jnp"] = "pallas",
    interpret: bool = True,
) -> EvalResult:
    designs = jnp.stack(list(d), axis=1).astype(jnp.float32)  # (P, 9)
    P, W = designs.shape[0], ws.n

    energies, latencies, demands = [], [], []
    for w in range(W):
        feats, mask = ws.feats[w], ws.mask[w]
        if backend == "pallas":
            e, l, x = imc_eval_pallas(designs, feats, mask, tech=tech, interpret=interpret)
        else:
            e, l, x = ref_mod.eval_one_workload(designs, feats, mask, tech)
        energies.append(e)
        latencies.append(l)
        demands.append(x)
    energy = jnp.stack(energies, axis=1)  # (P, W)
    latency = jnp.stack(latencies, axis=1)
    demand = jnp.stack(demands, axis=1)

    area = area_mm2(d, tech)  # (P,)
    energy = energy + tech.leak_mw_per_mm2 * area[:, None] * latency

    capacity = (d.g_per_chip * d.t_per_router * d.c_per_tile).astype(jnp.float32)
    fits = demand <= capacity[:, None]
    util = demand / capacity[:, None]

    k = (tech.v_nominal - tech.v_th) ** tech.alpha_power / tech.v_nominal
    t_min = k * d.v_op / (d.v_op - tech.v_th) ** tech.alpha_power
    valid = d.t_cycle_ns >= t_min

    return EvalResult(
        energy_pj=energy,
        latency_ns=latency,
        area_mm2=area,
        fits=fits,
        valid=valid,
        util=util,
    )
