"""Jitted wrapper: full EvalResult via the Pallas imc_eval kernel.

Drop-in for ``repro.imc.cost.evaluate_designs`` — the per-(design, layer,
workload) sums run in the kernel as ONE ``pallas_call`` over a 3-D
(P-tiles x W x L-tiles) grid writing (W, P) accumulators; the design-
global terms (area, leakage, V/f validity, fits) are tiny jnp epilogues
that fuse into the surrounding jit (e.g. the GA's objective reduction).

``backend="jnp"`` selects the pure-jnp oracle path (identical math); tests
assert allclose between the two across shape/dtype sweeps, and that the
multi-workload path issues exactly one kernel launch.

``interpret=None`` (the default) auto-detects the platform: the kernel is
COMPILED on TPU backends and interpreted elsewhere (CPU/GPU hosts, CI) —
so real-TPU runs get the Mosaic-compiled kernel without any flag.
"""
from __future__ import annotations

from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.imc.cost import DesignArrays, EvalResult, area_mm2, design_valid
from repro.imc.tech import TECH, TechParams
from repro.kernels.imc_eval import ref as ref_mod
from repro.kernels.imc_eval.kernel import default_interpret, imc_eval_pallas_multi
from repro.workloads.pack import WorkloadSet


def evaluate_designs_kernel_arrays(
    d: DesignArrays,
    feats: jnp.ndarray,  # (W, L, 6)
    mask: jnp.ndarray,  # (W, L)
    tech: TechParams = TECH,
    *,
    backend: Literal["pallas", "jnp"] = "pallas",
    interpret: Optional[bool] = None,
) -> EvalResult:
    if interpret is None:
        interpret = default_interpret()
    designs = jnp.stack(list(d), axis=1).astype(jnp.float32)  # (P, 9)

    if backend == "pallas":
        e, l, x = imc_eval_pallas_multi(
            designs, feats, mask, tech=tech, interpret=interpret
        )  # (W, P) each, one launch
    else:
        e, l, x = jax.vmap(
            lambda f, m: ref_mod.eval_one_workload(designs, f, m, tech)
        )(feats, mask)
    energy = e.T  # (P, W)
    latency = l.T
    demand = x.T

    area = area_mm2(d, tech)  # (P,)
    energy = energy + tech.leak_mw_per_mm2 * area[:, None] * latency

    capacity = (d.g_per_chip * d.t_per_router * d.c_per_tile).astype(jnp.float32)
    fits = demand <= capacity[:, None]
    util = demand / capacity[:, None]

    valid = design_valid(d, tech)

    return EvalResult(
        energy_pj=energy,
        latency_ns=latency,
        area_mm2=area,
        fits=fits,
        valid=valid,
        util=util,
    )


def evaluate_designs_kernel(
    d: DesignArrays,
    ws: WorkloadSet,
    tech: TechParams = TECH,
    *,
    backend: Literal["pallas", "jnp"] = "pallas",
    interpret: Optional[bool] = None,
) -> EvalResult:
    return evaluate_designs_kernel_arrays(
        d, ws.feats, ws.mask, tech, backend=backend, interpret=interpret
    )
