"""Pure-jnp oracle for the IMC population-evaluation kernel.

Per-(design, layer) closed-form cost terms for ONE workload, identical in
math to ``repro.imc.cost.evaluate_designs`` (asserted by tests), but
expressed as the (designs x layers) outer grid the Pallas kernel tiles:

    energy (P,), latency (P,), demand (P,)  =  sum over (masked) layers.

The leakage term (area x latency) and the fits/valid verdicts are design-
global and stay outside the kernel (see ``ops.py``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.imc.tech import TECH, TechParams


def eval_one_workload(
    designs: jnp.ndarray,  # (P, 9) decoded design values (space.FIELDS order)
    feats: jnp.ndarray,  # (L, 6) layer features (M, K, N, A_in, A_out, G)
    mask: jnp.ndarray,  # (L,) validity
    tech: TechParams = TECH,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (energy_pj (P,), latency_ns (P,), xbar_demand (P,))."""
    rows, cols, _cpt, _tpr, g_chip, v_op, bits, t_cyc, glb_mb = [
        designs[:, i][:, None] for i in range(9)
    ]  # (P, 1) each
    M, K, N, Ain, Aout, G = [feats[None, :, i] for i in range(6)]  # (1, L)
    mk = mask[None, :].astype(jnp.float32)

    phases = jnp.float32(tech.input_bits)
    cpw = jnp.ceil(jnp.float32(tech.weight_bits) / bits)
    ncol = jnp.ceil(N * cpw / cols)
    nrow = jnp.ceil(K / rows)
    xb = nrow * ncol * G  # (P, L)
    demand = (xb * mk).sum(-1)

    bytes_l = Ain + Aout
    l_comp = M * phases * tech.adc_share * t_cyc
    l_comm = bytes_l / (g_chip * tech.router_flit_bytes) * t_cyc
    spill = jnp.maximum(bytes_l - glb_mb * (1 << 20), 0.0)
    l_dram = spill / tech.dram_bw_bytes_per_ns
    latency = ((l_comp + l_comm + l_dram) * mk).sum(-1)

    e_cell = v_op**2 * tech.g_avg_s * t_cyc * 1e3
    cells = K * (N * cpw) * G
    e_analog = M * phases * cells * e_cell
    e_adc = M * phases * (N * cpw) * G * tech.adc_energy_pj
    e_dac = M * phases * K * ncol * G * tech.dac_energy_pj
    e_route = bytes_l * tech.router_energy_pj_per_byte
    e_buf = bytes_l * (tech.tile_buf_energy_pj_per_byte + tech.glb_energy_pj_per_byte)
    e_dram = spill * tech.dram_energy_pj_per_byte
    energy = ((e_analog + e_adc + e_dac + e_route + e_buf + e_dram) * mk).sum(-1)

    return energy, latency, demand
