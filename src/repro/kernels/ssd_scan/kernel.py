"""Pallas TPU kernel: Mamba-2 SSD chunked scan (single-group, per-head).

Tiling (HW-codesign): the SSD chunk algorithm maps onto the MXU as three
(Q x Q)/(Q x N)/(N x P) matmuls per chunk with a tiny sequential state
carry — exactly the structure TPUs like: big systolic contractions inside
a chunk, one (N, P) VMEM-resident state across chunks.

  * grid = (B*H, S/Q); the chunk axis is the innermost ("arbitrary") dim,
    the (N, P) state persists in VMEM scratch across chunk steps,
  * per chunk and head:   scores = C @ B^T          (Q x N @ N x Q -> MXU)
                          y_intra = (M * scores) @ (x * dt)
                          y_inter = exp(cum) * (C @ h)
                          h       = exp(total) * h + (B * w dt)^T @ x
    with M the causal intra-chunk decay matrix from cumulative log-decay,
  * B/C inputs are group-shared (G=1, mamba2/jamba): their BlockSpec maps
    (b*H + h) -> b — no repeat in HBM,
  * the final state is written once on the last chunk (decode handoff).

Q (chunk) = 128 rows, N (state) = lane-padded to 128; P (head dim) = 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(
    x_ref,  # (1, Q, P)  head inputs
    dt_ref,  # (1, Q, 1)  per-head step sizes (softplus'd)
    a_ref,  # (1, 1, 1)   per-head decay A (negative)
    b_ref,  # (1, Q, N)
    c_ref,  # (1, Q, N)
    y_ref,  # (1, Q, P)
    hout_ref,  # (1, N, P) final state (written at last chunk)
    h_ref,  # VMEM (N, P) carried state
    *,
    n_chunks: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, 1)
    A = a_ref[0, 0, 0].astype(jnp.float32)  # scalar
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    a = dt * A  # (Q, 1) log-decay per step (<= 0)
    cum = jnp.cumsum(a, axis=0)  # (Q, 1) inclusive
    total = cum[-1:, :]  # (1, 1)

    # intra-chunk: M[i, j] = exp(cum_i - cum_j) for j <= i
    diff = cum - cum.T  # (Q, Q)
    Q = diff.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) C_i . B_j
    xdt = x * dt  # (Q, P)
    y_intra = jax.lax.dot_general(
        M * scores, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)

    # inter-chunk: y_inter = exp(cum) * (C @ h_in)
    h_in = h_ref[...]
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        Cm, h_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(total) * h_in + (B * (w * dt))^T @ x
    w = jnp.exp(total - cum)  # (Q, 1)
    S_c = jax.lax.dot_general(
        Bm * (w * dt), x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (N, P)
    h_ref[...] = jnp.exp(total) * h_in + S_c

    @pl.when(ci == n_chunks - 1)
    def _done():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    A: jnp.ndarray,  # (H,) negative decay
    Bm: jnp.ndarray,  # (B, S, N)  single group
    Cm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y (B,S,H,P), final state (B,H,N,P)).  G=1 layout."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    BH = B * H
    xh = x.transpose(0, 2, 1, 3).reshape(BH, S, P)
    dth = dt.transpose(0, 2, 1).reshape(BH, S, 1)
    ah = jnp.broadcast_to(A[None, :], (B, H)).reshape(BH, 1, 1)

    grid = (BH, nc)
    y, hfin = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c, H=H: (i // H, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c, H=H: (i // H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, N, P), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xh, dth, ah, Bm, Cm)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    hfin = hfin.reshape(B, H, N, P)
    return y, hfin
