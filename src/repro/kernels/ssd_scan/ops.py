"""Jitted wrapper for the SSD Pallas kernel — drop-in for ``ref.ssd_chunked``
(G=1; grouped inputs are expanded by the caller when G > 1, though every
assigned SSM/hybrid arch uses a single B/C group).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    assert Bm.shape[2] == 1, "pallas SSD path is written for G=1 (our archs)"
    y, h = ssd_scan_pallas(
        x, dt, A, Bm[:, :, 0], Cm[:, :, 0], chunk=chunk, interpret=interpret
    )
    return y, h
