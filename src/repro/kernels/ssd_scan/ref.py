"""Pure-jnp oracles for the Mamba-2 SSD (state-space dual) scan.

Recurrence (per batch b, head h; scalar decay per head):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T      h: (N, P)
    y_t = C_t^T h_t                                          y: (P,)

Two references:
  * ``ssd_sequential`` — direct ``lax.scan`` over time (slow, exact oracle).
  * ``ssd_chunked``    — the SSD chunked algorithm [arXiv:2405.21060 §6]:
      intra-chunk quadratic term + inter-chunk state pass.  This is the math
      the Pallas kernel implements; it is also the portable model fast path.

Shapes: x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N) with H % G == 0.
Returns (y (B,S,H,P), final_state (B,H,N,P)).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils.unroll import maybe_scan


def _expand_groups(Bm: jax.Array, H: int) -> jax.Array:
    """(B,S,G,N) -> (B,S,H,N) by repeating each group over its heads."""
    Bsz, S, G, N = Bm.shape
    rep = H // G
    return jnp.repeat(Bm, rep, axis=2)


def ssd_sequential(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Bh = _expand_groups(Bm.astype(jnp.float32), H)
    Ch = _expand_groups(Cm.astype(jnp.float32), H)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((B, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        xt, dtt, Bt, Ct = xf[:, t], dtf[:, t], Bh[:, t], Ch[:, t]
        decay = jnp.exp(dtt * Af)[..., None, None]  # (B,H,1,1)
        h = h * decay + jnp.einsum("bhn,bhp->bhnp", Bt * dtt[..., None], xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    y = ys.swapaxes(0, 1)  # (B,S,H,P)
    return y.astype(x.dtype), h


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    h0: Optional[jax.Array] = None,
    *,
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    Q = chunk

    xf = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, Q, H)
    Af = A.astype(jnp.float32)
    Bh = _expand_groups(Bm.astype(jnp.float32), H).reshape(B, nc, Q, H, N)
    Ch = _expand_groups(Cm.astype(jnp.float32), H).reshape(B, nc, Q, H, N)

    a = dtf * Af  # (B,nc,Q,H) log-decay per step (<= 0)
    cum = jnp.cumsum(a, axis=2)  # alpha_i within chunk (inclusive)
    total = cum[:, :, -1]  # (B,nc,H)

    # --- intra-chunk (quadratic within chunk) --------------------------------
    # M[i,j] = exp(alpha_i - alpha_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    li = jnp.arange(Q)
    causal = (li[:, None] >= li[None, :])[None, None, ..., None]
    # mask BEFORE the exp: for j > i, diff > 0 can overflow exp and the
    # where-cotangent turns inf * 0 into NaN.  exp(-inf) = 0 with zero grad.
    M = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)  # C_i . B_j
    xdt = xf * dtf[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M * scores, xdt)

    # --- chunk summaries → inter-chunk recurrence ----------------------------
    # state contribution of chunk c: sum_j exp(total - alpha_j) dt_j B_j x_j^T
    w = jnp.exp(total[:, :, None] - cum)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcjhn,bcjhp->bchnp", Bh * (w * dtf)[..., None], xf)

    h_init = jnp.zeros((B, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def carry(h, c):
        h_out = h  # state entering chunk c
        h = h * jnp.exp(total[:, c])[..., None, None] + S_c[:, c]
        return h, h_out

    h_final, h_in = maybe_scan(carry, h_init, jnp.arange(nc))
    h_in = h_in.swapaxes(0, 1)  # (B,nc,H,N,P) state entering each chunk

    # y_inter[i] = exp(alpha_i) * C_i . h_in
    y_inter = jnp.einsum(
        "bcihn,bchnp->bcihp", Ch * jnp.exp(cum)[..., None], h_in
    )

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    h: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One token: x (B,H,P); dt (B,H); Bm/Cm (B,G,N); h (B,H,N,P)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))[..., None, None]
    h = h.astype(jnp.float32) * decay + jnp.einsum(
        "bhn,bhp->bhnp", Bh * dtf[..., None], x.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    return y.astype(x.dtype), h
