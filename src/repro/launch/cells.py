"""(architecture x input-shape) cells: abstract inputs + step builders.

A *cell* is one assigned (arch, shape) pair.  For each cell this module
provides

* ``input_specs``      — ``ShapeDtypeStruct`` stand-ins for every input
                          (weak-type correct, shardable, zero allocation),
* ``input_pspecs``     — matching ``PartitionSpec``s for a mesh,
* ``abstract_state``   — param (and opt/cache) structs,
* ``build_step``       — the jittable step function + donate/static info,

used identically by the dry-run launcher, the roofline pass and the tests
(tests call the same builders on reduced configs with real arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ALL_SHAPES, ModelConfig, ShapeSpec, get_config, list_configs
from repro.distributed.sharding import cache_spec, input_sharding, params_sharding
from repro.models import transformer
from repro.models.common import param_structs
from repro.optim import AdamWState
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Cell:
    cfg: ModelConfig
    shape: ShapeSpec

    @property
    def name(self) -> str:
        return f"{self.cfg.name}/{self.shape.name}"


def all_cells(arch: Optional[str] = None, shape: Optional[str] = None) -> List[Cell]:
    """Every runnable (arch x shape) cell, honouring documented skips."""
    cells = []
    for a in list_configs() if arch is None else [arch]:
        cfg = get_config(a)
        for s in cfg.supported_shapes():
            if shape is not None and s.name != shape:
                continue
            cells.append(Cell(cfg, s))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    out = []
    for a in list_configs():
        cfg = get_config(a)
        for s, why in cfg.shape_skips():
            out.append((a, s, why))
    return out


# ---------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for one cell (the ``batch`` argument of the step)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["inputs"] = sds((B, S), i32)
        out["targets"] = sds((B, S), i32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), i32)
    else:  # decode: one new token against a cache of S
        out["token"] = sds((B, 1), i32)
        out["pos"] = sds((B,), i32)  # per-slot positions (continuous batching)
    if cfg.vision_tokens and shape.kind != "decode":
        out["vision_embeds"] = sds((B, cfg.vision_tokens, cfg.d_model), bf16)
        out["mrope_pos"] = sds((3, B, S), i32)
    if cfg.is_encdec and shape.kind != "decode":
        out["frames"] = sds((B, S, cfg.d_model), bf16)
    return out


def input_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Dict[str, P]:
    return input_sharding(cfg, shape, mesh)


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, key: jax.Array) -> Dict[str, jax.Array]:
    """Real (random) arrays matching ``input_specs`` — smoke tests."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if s.dtype == jnp.int32 and name != "pos":
            out[name] = jax.random.randint(k, s.shape, 0, min(cfg.vocab_size, 1000), jnp.int32)
        elif name == "pos":
            out[name] = jnp.full(s.shape, shape.seq_len - 1, jnp.int32)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * 0.02
    if "mrope_pos" in out:
        B, S = shape.global_batch, shape.seq_len
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
        out["mrope_pos"] = pos
    return out


# ------------------------------------------------------------- abstract state
def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    return param_structs(transformer.param_template(cfg), dtype)


def abstract_opt_state(cfg: ModelConfig) -> AdamWState:
    p = abstract_params(cfg, jnp.float32)
    zeros = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(lambda s: s, zeros),
    )


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> PyTree:
    return transformer.cache_template(cfg, shape.global_batch, shape.seq_len, dtype)


# ------------------------------------------------------------------ the steps
@dataclasses.dataclass
class StepBundle:
    """Everything needed to jit/lower one cell."""

    fn: Callable  # the step function
    args: Tuple  # abstract arguments (ShapeDtypeStructs)
    in_shardings: Tuple  # matching PartitionSpec trees
    out_shardings: Any
    donate_argnums: Tuple[int, ...]


ACCUM_BY_ARCH = {
    # chosen per the memory dry-runs (EXPERIMENTS.md §Dry-run): activation
    # memory scales ~1/accum; the big/MoE archs need deeper microbatching
    "qwen2-72b": 4,
    "jamba-v0.1-52b": 8,
    "qwen3-moe-235b-a22b": 8,
    "gemma-7b": 4,
    "whisper-medium": 4,
    "yi-9b": 4,
}


def default_accum(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Microbatching policy: divide train-step activation memory to fit the
    16 GiB HBM budget at 4k x 256; inference steps never accumulate."""
    if shape.kind != "train":
        return 1
    return ACCUM_BY_ARCH.get(cfg.name, 2)


def build_step(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh: Mesh,
    *,
    remat: bool = True,
    accum: Optional[int] = None,
    sharding_overrides: Optional[Dict[str, Any]] = None,
    seq_axis: Any = "model",
) -> StepBundle:
    """Build the (abstract) step for a cell on a mesh.

    train   -> step(params, opt_state, batch)
    prefill -> step(params, batch) -> (logits, cache)
    decode  -> step(params, cache, batch) -> (logits, cache)
    """
    if accum is None:
        accum = default_accum(cfg, shape)
    tmpl = transformer.param_template(cfg)
    pspec = jax.tree.map(
        lambda s: s.spec, params_sharding(cfg, mesh, tmpl, sharding_overrides)
    )
    params = abstract_params(cfg)
    bspecs = input_pspecs(cfg, shape, mesh)
    batch = input_specs(cfg, shape)

    if shape.kind == "train":
        step = make_train_step(cfg, remat=remat, accum=accum)
        opt = abstract_opt_state(cfg)
        opt_spec = AdamWState(step=P(), mu=pspec, nu=jax.tree.map(lambda s: s, pspec))
        return StepBundle(
            fn=step,
            args=(params, opt, batch),
            in_shardings=(pspec, opt_spec, bspecs),
            out_shardings=(pspec, opt_spec, None),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        cspec = cache_spec(cfg, shape, mesh, seq_axis=seq_axis)
        return StepBundle(
            fn=step,
            args=(params, batch),
            in_shardings=(pspec, bspecs),
            out_shardings=(None, cspec),
            donate_argnums=(),
        )

    # decode
    step = make_decode_step(cfg)
    cache = abstract_cache(cfg, shape)
    cspec = cache_spec(cfg, shape, mesh, seq_axis=seq_axis)
    return StepBundle(
        fn=step,
        args=(params, cache, batch),
        in_shardings=(pspec, cspec, bspecs),
        out_shardings=(None, cspec),
        donate_argnums=(1,),
    )
