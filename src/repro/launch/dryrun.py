import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Multi-pod dry-run launcher.

For every assigned (architecture x input-shape) cell, on the single-pod
(16x16) and multi-pod (2x16x16) production meshes:

    jit(step, in_shardings, out_shardings).lower(*abstract args).compile()

must succeed.  We record memory_analysis (fits), cost_analysis (FLOPs /
bytes -> roofline terms), and the collective schedule parsed from the
optimized HLO, into ``experiments/dryrun/<mesh>/<cell>.json``.

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
    python -m repro.launch.dryrun --paper          # DSE generation dry-run
    python -m repro.launch.dryrun --paper --search-mesh 64x8
                       # fleet DSE dry-run: 64 searches x 8-way population
                       # sharding on a 2-D (search, data) mesh
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import hlo as hlo_lib
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs.base import SHAPES_BY_NAME, get_config, list_configs
from repro.distributed import ctx as dist_ctx
from repro.distributed.sharding import make_rules
from repro.launch.cells import Cell, all_cells, build_step, skipped_cells
from repro.launch.mesh import describe, make_production_mesh, make_search_mesh

RESULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _named(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree (None leaves pass through)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _compile_cell(cfg, shape, mesh, build_kwargs):
    bundle = build_step(cfg, shape, mesh, **(build_kwargs or {}))
    rules = make_rules(mesh)
    with dist_ctx.use_rules(mesh, rules):
        jitted = jax.jit(
            bundle.fn,
            in_shardings=_named(mesh, bundle.in_shardings),
            out_shardings=_named(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums,
        )
        lowered = jitted.lower(*bundle.args)
        compiled = lowered.compile()
    return lowered, compiled


def _raw_costs(compiled):
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = hlo_lib.collective_stats(text)
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll,
        text,
    )


def scan_corrected_costs(cell: Cell, mesh, full_costs, build_kwargs=None):
    """Correct XLA cost_analysis' while-loop undercount.

    HloCostAnalysis counts a ``while`` body ONCE regardless of trip count
    (a scanned N-layer model reports ~1 layer of FLOPs, bytes and
    collectives).  We therefore lower SMALL variants of the same cell with
    every scan unrolled (``utils.unroll``) — straight-line HLO where the
    cost analysis is exact — and extrapolate linearly.

    Cost structure (exact for homogeneous block stacks, which all ours
    are): f(nb, acc) = c0 + nb*p + acc*m + acc*nb*b, where
        c0 = per-step fixed cost (optimizer scalars etc.)
        p  = per-block parameter/optimizer cost
        m  = per-microbatch fixed cost (embedding + loss)
        b  = per-(microbatch x block) compute cost.
    Four unrolled compiles at (nb, acc) in {1,2}^2 identify all four terms;
    inference cells (acc == 1 always) use the two-point (nb) form.
    """
    from repro.utils.unroll import unroll_scans

    cfg, shape = cell.cfg, cell.shape
    nb = cfg.n_blocks
    bk = dict(build_kwargs or {})
    if shape.kind == "train" and bk.get("accum") is None:
        from repro.launch.cells import default_accum

        bk["accum"] = default_accum(cfg, shape)
    acc_real = bk.get("accum", 1)

    def variant(blocks):
        kw = {"n_layers": cfg.period * blocks, "name": f"{cfg.name}-nb{blocks}"}
        if cfg.is_encdec:
            kw["encoder_layers"] = blocks
        return dataclasses.replace(cfg, **kw)

    def costs(blocks, acc):
        kw = dict(bk)
        if shape.kind == "train":
            kw["accum"] = acc
        with unroll_scans():
            _, c = _compile_cell(variant(blocks), shape, mesh, kw)
        f, b, coll, _ = _raw_costs(c)
        return np.asarray([f, b, float(coll.total_bytes)])

    if shape.kind != "train" or acc_real == 1:
        v1 = costs(1, 1)
        if nb == 1:
            f, b, x = v1
            return f, b, int(x), True
        v2 = costs(2, 1)
        body = np.maximum(v2 - v1, 0.0)
        f, b, x = v1 + (nb - 1) * body
        return f, b, int(x), True

    f11 = costs(1, 1)
    f21 = costs(2, 1)
    f12 = costs(1, 2)
    f22 = costs(2, 2)
    b = np.maximum(f22 - f21 - f12 + f11, 0.0)  # per-(microbatch, block)
    p = np.maximum(f21 - f11 - b, 0.0)  # per-block fixed
    m = np.maximum(f12 - f11 - b, 0.0)  # per-microbatch fixed
    c0 = np.maximum(f11 - p - m - b, 0.0)
    tot = c0 + nb * p + acc_real * m + acc_real * nb * b
    return tot[0], tot[1], int(tot[2]), True


def dryrun_cell(
    cell: Cell,
    mesh,
    *,
    save: bool = True,
    keep_hlo: bool = False,
    build_kwargs: Optional[Dict[str, Any]] = None,
    correct: bool = True,
) -> Dict[str, Any]:
    """Lower + compile one cell on one mesh; return the record dict.

    ``correct=False`` skips the unrolled-variant cost extrapolation (2 extra
    compiles) — used for the multi-pod pass, which proves compile/shard
    coherence; the roofline table reads the single-pod records.
    """
    cfg, shape = cell.cfg, cell.shape
    mesh_name = describe(mesh)
    t0 = time.time()
    lowered, compiled = _compile_cell(cfg, shape, mesh, build_kwargs)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll, text = _raw_costs(compiled)
    census = hlo_lib.op_census(text)
    top_coll = hlo_lib.largest_collectives(text)
    if correct:
        flops, bytes_acc, coll_bytes, corrected = scan_corrected_costs(
            cell, mesh, (flops_raw, bytes_raw, coll, text), build_kwargs
        )
    else:
        flops, bytes_acc, coll_bytes, corrected = (
            flops_raw, bytes_raw, coll.total_bytes, False,
        )
    coll_c = dataclasses.replace(coll, total_bytes=coll_bytes)

    chips = int(np.prod(mesh.devices.shape))
    mfl = model_flops(cfg, shape)
    per_dev_mem = (
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    rf = roofline_terms(
        cell=cell.name,
        mesh_name=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=bytes_acc,
        coll=coll_c,
        model_flops_global=mfl,
        mem_per_device=per_dev_mem,
    )

    rec = {
        "cell": cell.name,
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": mesh_name,
        "chips": chips,
        "ok": True,
        "compile_s": round(t_compile, 2),
        "scan_corrected": corrected,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "per_device_bytes": per_dev_mem,
            "per_device_gb": round(per_dev_mem / 2**30, 3),
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_per_device": bytes_acc,
            "flops_per_device_raw": flops_raw,
            "bytes_per_device_raw": bytes_raw,
            "model_flops_global": mfl,
        },
        "collectives": {
            "total_bytes": coll_bytes,
            "total_bytes_raw": coll.total_bytes,
            "by_kind": coll.by_kind,
            "counts": coll.counts,
            "largest": top_coll,
        },
        "roofline": {
            "t_compute_s": rf.t_compute,
            "t_memory_s": rf.t_memory,
            "t_collective_s": rf.t_collective,
            "bottleneck": rf.bottleneck,
            "useful_ratio": rf.useful_ratio,
            "peak_fraction": rf.peak_fraction,
        },
        "op_census_top": census.most_common(12),
    }
    if keep_hlo:
        rec["hlo_text"] = text
    if save:
        out = RESULT_DIR / mesh_name
        out.mkdir(parents=True, exist_ok=True)
        with open(out / f"{cfg.name}__{shape.name}.json", "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def dryrun_paper_search(mesh, *, pop_size: int = 4096, save: bool = True) -> Dict[str, Any]:
    """Dry-run one GA generation of the paper's DSE, population sharded
    over the mesh data axes (the pod-scale search the paper couldn't do)."""
    import jax.numpy as jnp

    from repro.core import space
    from repro.core.distributed import sharded_eval_fn
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    eval_fn = sharded_eval_fn(mesh, ws, "ela", 150.0)
    genomes = jax.ShapeDtypeStruct((pop_size, space.N_GENES), jnp.float32)
    lowered = eval_fn.lower(genomes)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = hlo_lib.collective_stats(text)
    rec = {
        "cell": f"paper-dse/pop{pop_size}",
        "mesh": describe(mesh),
        "ok": True,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll.total_bytes,
    }
    if save:
        out = RESULT_DIR / describe(mesh)
        out.mkdir(parents=True, exist_ok=True)
        with open(out / f"paper-dse__pop{pop_size}.json", "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def dryrun_paper_search_batched(
    mesh, *, searches: Optional[int] = None, pop_size: int = 1024,
    save: bool = True, backend: str = "jnp",
) -> Dict[str, Any]:
    """Dry-run the FLEET DSE eval: B independent searches' populations,
    batch axis on the ``search`` mesh axis, population axis on ``data``
    (``core.distributed.sharded_batched_eval_fn``) — the pod-fleet layout
    behind ``batched_search(..., mesh=...)``.  ``backend="table"`` lowers
    the factorized-table evaluator instead: its traced ctx is the
    ``imc.tables.WorkloadTables`` pytree (search-sharded like any other
    batched leaf), so the compiled program has no layer axis at all."""
    import jax.numpy as jnp

    from repro.core import space
    from repro.core.distributed import sharded_batched_eval_fn
    from repro.launch.mesh import mesh_axis_sizes
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    B = searches or mesh_axis_sizes(mesh).get("search", 1)
    eval_fn = sharded_batched_eval_fn(mesh, "ela", 150.0, backend=backend)
    genomes = jax.ShapeDtypeStruct((B, pop_size, space.N_GENES), jnp.float32)
    if backend == "table":
        tables = ws.tables()
        ctx = (
            jax.tree_util.tree_map(
                lambda t: jax.ShapeDtypeStruct((B,) + t.shape, t.dtype), tables
            ),
        )
    else:
        ctx = (
            jax.ShapeDtypeStruct((B,) + ws.feats.shape, ws.feats.dtype),
            jax.ShapeDtypeStruct((B,) + ws.mask.shape, ws.mask.dtype),
        )
    compiled = eval_fn.lower(genomes, ctx).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    coll = hlo_lib.collective_stats(compiled.as_text())
    rec = {
        "cell": f"paper-dse-fleet/b{B}xpop{pop_size}/{backend}",
        "mesh": describe(mesh),
        "ok": True,
        "searches": B,
        "backend": backend,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll.total_bytes,
    }
    if save:
        out = RESULT_DIR / describe(mesh)
        out.mkdir(parents=True, exist_ok=True)
        tag = "" if backend == "jnp" else f"__{backend}"
        with open(out / f"paper-dse-fleet__b{B}xpop{pop_size}{tag}.json", "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument(
        "--search-mesh", default=None, metavar="SxP",
        help="(search, population) mesh, e.g. 64x8: dry-run the fleet DSE "
             "layout instead of the production meshes (implies --paper)",
    )
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--paper", action="store_true", help="dry-run the DSE eval")
    ap.add_argument(
        "--backend", default="jnp", choices=["jnp", "pallas", "table"],
        help="cost-model backend for the --search-mesh fleet dry-run "
             "(table = factorized grid-table evaluator)",
    )
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument(
        "--no-correction", action="store_true",
        help="skip unrolled cost extrapolation (multi-pod compile-proof pass)",
    )
    args = ap.parse_args(argv)

    if args.search_mesh:
        s, p = (int(v) for v in args.search_mesh.lower().split("x"))
        mesh = make_search_mesh(s, p)
        rec = dryrun_paper_search_batched(
            mesh, save=not args.no_save, backend=args.backend
        )
        print(f"[paper-dse-fleet {describe(mesh)}] ok "
              f"searches={rec['searches']} backend={rec['backend']} "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"coll={rec['collective_bytes']/1e6:.0f}MB")
        return 0

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single-pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi-pod", make_production_mesh(multi_pod=True)))

    if args.paper:
        for label, mesh in meshes:
            rec = dryrun_paper_search(mesh, save=not args.no_save)
            print(f"[paper-dse {label}] ok  flops/dev={rec['flops_per_device']:.3e}")
        return 0

    cells = all_cells(args.arch, args.shape)
    if not cells:
        print("no cells selected", file=sys.stderr)
        return 2

    failures = []
    for label, mesh in meshes:
        for cell in cells:
            tag = f"[{cell.name} @ {label}]"
            try:
                rec = dryrun_cell(
                    cell, mesh, save=not args.no_save,
                    correct=not args.no_correction,
                )
                r = rec["roofline"]
                print(
                    f"{tag} OK mem/dev={rec['memory']['per_device_gb']:.2f}GB "
                    f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                    f"coll={rec['collectives']['total_bytes']/1e6:.0f}MB "
                    f"bottleneck={r['bottleneck']} "
                    f"(compile {rec['compile_s']:.0f}s)",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — report, continue, fail at end
                failures.append((cell.name, label, repr(e)))
                print(f"{tag} FAIL {e!r}", flush=True)
                traceback.print_exc()

    skips = skipped_cells()
    if skips:
        print("\nintentional skips (DESIGN.md §Arch-applicability):")
        for a, s, why in skips:
            print(f"  {a} x {s}: {why}")

    if failures:
        print(f"\n{len(failures)} FAILURES", file=sys.stderr)
        return 1
    print(f"\nall {len(cells)} cells x {len(meshes)} meshes OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
