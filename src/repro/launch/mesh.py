"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run launcher must set
``xla_force_host_platform_device_count`` before any jax initialization).

Mesh axes:
  * ``pod``   — slow DCN-class axis between pods (multi-pod only).  Only the
                gradient all-reduce (optionally compressed) crosses it.
  * ``data``  — intra-pod FSDP/ZeRO + batch parallelism.
  * ``model`` — Megatron-style tensor/expert/sequence parallelism.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh over however many devices exist (tests, elasticity)."""
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the devices actually present (CPU tests: 1 device)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def describe(mesh: Mesh) -> str:
    return "x".join(
        f"{a}={s}" for a, s in zip(mesh.axis_names, mesh.devices.shape)
    )
