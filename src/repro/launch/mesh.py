"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run launcher must set
``xla_force_host_platform_device_count`` before any jax initialization).

Mesh axes:
  * ``search`` — whole-search data parallelism: the leading batch axis of
                the vmapped DSE stack (``core.search.batched_search`` /
                ``core.ga.run_ga_batched``) shards over it — one mesh slice
                per independent GA (seed or workload set).
  * ``pod``   — slow DCN-class axis between pods (multi-pod only).  Only the
                gradient all-reduce (optionally compressed) crosses it.
  * ``data``  — intra-pod FSDP/ZeRO + batch parallelism; the DSE population
                axis shards over it (``core.distributed``).
  * ``model`` — Megatron-style tensor/expert/sequence parallelism.

``make_search_mesh`` builds the 2-D ``(search, data)`` layout used by the
sharded search drivers; every constructor here degrades gracefully when the
host exposes fewer devices than requested (axis sizes clamp to the device
budget, down to 1 on a single-device host), so tests and benches run
unchanged from laptops to pods.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False, searches: int = 1) -> Mesh:
    """16x16 pod (or 2x16x16 multi-pod) mesh; ``searches > 1`` prepends a
    ``search`` axis for fleet-scale DSE (searches x 16 x 16 devices)."""
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes: Tuple[str, ...] = ("pod", "data", "model") if multi_pod else ("data", "model")
    if searches > 1:
        shape = (searches,) + shape
        axes = ("search",) + axes
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh over however many devices exist (tests, elasticity)."""
    return jax.make_mesh(shape, axes)


def _fit_axis(requested: int, remaining: int) -> int:
    """Axis size clamped to the remaining device budget — the graceful-
    degradation rule shared by every mesh constructor.  Non-divisor sizes
    are fine (the constructors slice exactly ``prod(shape)`` devices), so a
    request is honored verbatim whenever it fits."""
    return max(1, min(int(requested), remaining))


def make_test_mesh(data: int = 1, model: int = 1, search: int = 1) -> Mesh:
    """Tiny mesh over the devices actually present.

    Axis sizes clamp to the device budget (down to 1) instead of asserting,
    so a ``search=8`` request degrades to ``search=1`` on a single-device
    CPU host and the same test runs on the fake-8-device CI leg unchanged.
    Returns a ``(search, data, model)`` mesh when ``search`` is requested
    (> 1), else the historical ``(data, model)`` layout.
    """
    n = len(jax.devices())
    sizes = {}
    remaining = n
    for name, req in (("search", search), ("data", data), ("model", model)):
        sizes[name] = _fit_axis(req, remaining)
        remaining //= sizes[name]
    if search > 1:
        shape = (sizes["search"], sizes["data"], sizes["model"])
        axes: Tuple[str, ...] = ("search", "data", "model")
    else:
        shape = (sizes["data"], sizes["model"])
        axes = ("data", "model")
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def make_search_mesh(
    searches: Optional[int] = None, pop: Optional[int] = None
) -> Mesh:
    """2-D ``(search, data)`` mesh for the sharded batched search stack.

    ``searches`` shards the leading batch axis (independent GAs), ``pop``
    shards each GA's population.  Defaults: all devices on ``search``
    (``pop=1``) — hundreds of independent searches per launch is the
    fleet-scale win (ROADMAP).  Sizes clamp to the available devices.
    """
    n = len(jax.devices())
    if searches is None and pop is None:
        searches, pop = n, 1
    elif searches is None:
        pop = _fit_axis(pop, n)
        searches = n // pop
    elif pop is None:
        searches = _fit_axis(searches, n)
        pop = n // searches
    else:
        searches = _fit_axis(searches, n)
        pop = _fit_axis(pop, n // searches)
    devs = np.asarray(jax.devices()[: searches * pop]).reshape(searches, pop)
    return Mesh(devs, ("search", "data"))


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    """``{axis_name: size}`` in mesh order (invariant-checked in tests)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def describe(mesh: Mesh) -> str:
    return "x".join(
        f"{a}={s}" for a, s in zip(mesh.axis_names, mesh.devices.shape)
    )
