import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Roofline report + perf-iteration driver.

    python -m repro.launch.roofline --report          # table from dry-run records
    python -m repro.launch.roofline --hillclimb CELL  # re-lower a cell with a
                                                      # named variant set

Reads experiments/dryrun/<mesh>/<cell>.json (written by launch/dryrun.py)
and emits the §Roofline markdown table; the hillclimb mode lowers a cell
under named optimization variants and prints the before/after terms.
"""

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional

RESULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
SINGLE_POD = "data=16xmodel=16"

HEADER = (
    "| cell | t_compute (ms) | t_memory (ms) | t_collective (ms) | bottleneck "
    "| mem/dev (GiB) | useful 6ND/HLO | roofline frac |\n"
    "|---|---|---|---|---|---|---|---|"
)


def load_records(mesh: str = SINGLE_POD) -> List[Dict[str, Any]]:
    out = []
    d = RESULT_DIR / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        if p.name.startswith("paper-dse"):
            continue
        with open(p) as f:
            out.append(json.load(f))
    return out


def row(rec: Dict[str, Any]) -> str:
    r = rec["roofline"]
    return (
        f"| {rec['cell']} | {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
        f"| {r['t_collective_s']*1e3:.2f} | {r['bottleneck']} "
        f"| {rec['memory']['per_device_gb']:.2f} | {r['useful_ratio']:.2f} "
        f"| {r['peak_fraction']:.1%} |"
    )


def report(mesh: str = SINGLE_POD) -> str:
    recs = load_records(mesh)
    lines = [HEADER] + [row(r) for r in recs]
    return "\n".join(lines)


# ------------------------------------------------------------------ hillclimb
VARIANTS: Dict[str, Dict[str, Any]] = {
    # name -> build_step kwargs overrides
    "baseline": {},
    "accum4": {"accum": 4},
    "accum8": {"accum": 8},
    "no-seq-parallel": {"sharding_overrides": {"seq": None}},
    "no-fsdp": {"sharding_overrides": {"embed": None}},
    "fsdp-2d": {"sharding_overrides": {"embed": ("data",)}},
    "seq-over-data": {"seq_axis": "data"},
    "cache-seq-2d": {"seq_axis": ("data", "model")},
    "no-remat": {"remat": False},
}


def hillclimb(cell_name: str, variants: List[str], correct: bool = True):
    from repro.configs.base import SHAPES_BY_NAME, get_config
    from repro.launch.cells import Cell
    from repro.launch.dryrun import dryrun_cell
    from repro.launch.mesh import make_production_mesh

    arch, shape = cell_name.split("/")
    cell = Cell(get_config(arch), SHAPES_BY_NAME[shape])
    mesh = make_production_mesh()
    out = []
    for v in variants:
        kw = VARIANTS[v]
        try:
            rec = dryrun_cell(cell, mesh, save=False, build_kwargs=kw, correct=correct)
            r = rec["roofline"]
            print(
                f"[{cell_name} :: {v}] comp={r['t_compute_s']*1e3:.2f}ms "
                f"mem={r['t_memory_s']*1e3:.2f}ms coll={r['t_collective_s']*1e3:.2f}ms "
                f"bottleneck={r['bottleneck']} mem/dev={rec['memory']['per_device_gb']:.2f}GiB",
                flush=True,
            )
            out.append((v, rec))
        except Exception as e:  # noqa: BLE001
            print(f"[{cell_name} :: {v}] FAIL {e!r}", flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--mesh", default=SINGLE_POD)
    ap.add_argument("--hillclimb", default=None, help="arch/shape cell name")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--no-correction", action="store_true")
    args = ap.parse_args(argv)

    if args.report:
        print(report(args.mesh))
        return 0
    if args.hillclimb:
        hillclimb(
            args.hillclimb, args.variants.split(","),
            correct=not args.no_correction,
        )
        return 0
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
