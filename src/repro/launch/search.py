"""Paper driver: joint hardware-workload search CLI.

    python -m repro.launch.search --workloads vgg16,resnet18,alexnet,mobilenetv3 \
        --objective ela --area 150 --pop 40 --gens 10 --seeds 1

Joint (the paper's method) vs separate (per-workload baseline) searches,
cross-rescoring, and LM-workload search (beyond paper: the assigned
architectures exported as IMC workloads):

    python -m repro.launch.search --lm-workloads llama3.2-1b,mixtral-8x7b \
        --mode decode

``--search-mesh SxP`` lays the batched programs out over a 2-D
(search, population) device mesh (on CPU-only hosts export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first; real
multi-chip hosts need nothing).  Scores are unchanged — it only scales
how many searches run in parallel.  ``--backend table`` evaluates through
the factorized per-workload grid tables (``imc.tables``): throughput
independent of layer count, which is what makes deep ``--lm-workloads``
tables free at search time.

``--serve N`` runs the DSE service instead: N heterogeneous requests
(cycling workload subsets x objectives x seeds over the selected
workload set) are submitted to the continuous-batching queue
(``serve.dse.DSEService``) and drained slot-packed through the shared
search engine — the per-request best designs stream as each launch
lands, followed by a requests/s + latency-percentile summary:

    python -m repro.launch.search --serve 256 --backend table

``--serve-policy priority|edf`` schedules the queue by request priority
(0 = most urgent, wait-time aging) or earliest absolute deadline, and
``--serve-async`` drains through the threaded ``AsyncDSEService`` front
end (``submit`` returns futures; requests join the next launch without
blocking the current one):

    python -m repro.launch.search --serve 256 --backend table \
        --serve-policy priority --serve-async

Robustness knobs (anytime fault-tolerant DSE): ``--segment-gens K``
runs every search as segments of K generations — bit-identical to the
single launch, but a fault loses at most one segment — and
``--checkpoint-dir DIR`` persists segment boundaries so a killed run
resumes from the newest committed state.  Under ``--serve``,
``--retry-attempts``/``--retry-backoff`` arm the deterministic
retry-with-backoff lane (failed chunks re-plan each member in isolation,
quarantining persistent offenders) and ``--partial-results`` resolves
quarantined / past-deadline requests with their best-so-far anytime
result instead of dropping them:

    python -m repro.launch.search --serve 64 --backend table \
        --segment-gens 2 --retry-attempts 3 --partial-results

``--pipelined`` turns on transfer-thin pipelined execution: the GA
program computes its own top-k epilogue on device (only the per-request
top-k genomes/scores and the convergence curve cross the wire;
``result.ga`` is ``None``) and, under ``--serve``, the drain
double-buffers launches — dispatch plan i+1, then harvest plan i — so
host finalize overlaps device compute.  Results are bit-identical; the
summary prints the dispatch->harvest gap, device-idle estimate and
harvested bytes next to the cache hit rate.

``--result-cache DIR`` arms the fingerprint-keyed result cache
(``serve.cache.ResultCache``, disk tier under DIR): a request whose
``request_key`` was answered before — this process or any earlier one
over the same DIR — resolves at submit with zero GA launches, bit
identical to a fresh search.  ``--stream-progress`` prints each
request's improving best-so-far after every guarded GA segment (implies
segmented execution; 2-generation segments unless ``--segment-gens`` /
``--checkpoint-dir`` already chose a boundary):

    python -m repro.launch.search --serve 64 --backend table \
        --result-cache /tmp/dse-cache --stream-progress
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import space
from repro.core.search import (
    joint_search_batched,
    rescore_designs,
    seed_population,
    separate_search,
)
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.lm import lm_workload
from repro.workloads.pack import WorkloadSet, pack_workloads


def build_workloads(args) -> WorkloadSet:
    named = []
    if args.workloads:
        for n in args.workloads.split(","):
            named.append((n, cnn_workload(n)))
    if args.lm_workloads:
        for n in args.lm_workloads.split(","):
            cfg = get_config(n)
            named.append((n, lm_workload(cfg, mode=args.mode, seq=args.seq)))
    if not named:
        named = [(n, cnn_workload(n)) for n in PAPER_WORKLOADS]
    return pack_workloads(named)


def _fmt(v, spec: str = ".2f") -> str:
    """Format a possibly-``None`` stats percentile (empty window)."""
    return "n/a" if v is None else f"{v:{spec}}"


def build_engine(args, mesh, result_cache=None):
    """A configured ``SearchEngine`` when any robustness knob is set
    (segmented execution, checkpoint/resume), else ``None`` (the drivers
    fall back to the shared default engine; under ``--serve`` the
    service then builds its own engine around ``result_cache``)."""
    if not (args.segment_gens or args.checkpoint_dir):
        return None
    from repro.core.engine import SearchEngine

    # checkpointing only happens at segment boundaries, so a checkpoint
    # dir without an explicit segment length gets 1-generation segments
    return SearchEngine(
        mesh=mesh,
        segment_gens=args.segment_gens or (1 if args.checkpoint_dir else None),
        segment_retries=args.segment_retries,
        checkpoint_dir=args.checkpoint_dir or None,
        result_cache=result_cache,
        pipelined=args.pipelined,
    )


def serve(args, ws: WorkloadSet, mesh) -> int:
    """``--serve N``: drain N mixed requests through the DSE service.
    ``--serve-policy`` picks the scheduling policy (mixed priorities /
    deadlines are cycled into the request mix so the policy has work to
    do); ``--serve-async`` drains through the threaded
    ``AsyncDSEService`` front end instead of the synchronous queue.
    ``--retry-attempts``/``--retry-backoff`` arm the retry-with-backoff
    lane and ``--partial-results`` the anytime graceful-degradation path
    (quarantined / past-deadline requests resolve with their best-so-far
    instead of nothing)."""
    from repro.serve.dse import (
        AsyncDSEService,
        DSEService,
        RetryPolicy,
        paper_request_mix,
    )

    cache = None
    if args.result_cache:
        from repro.serve.cache import ResultCache

        cache = ResultCache(disk_dir=args.result_cache)
        print(f"[serve] result cache armed ({len(cache.disk_keys())} "
              f"entries on disk under {args.result_cache})")
    if args.stream_progress and not (args.segment_gens or args.checkpoint_dir):
        # streaming needs segment boundaries to emit at; segmented
        # execution is bit-identical to single-shot, so defaulting one
        # in changes no result
        args.segment_gens = 2
        print("[serve] --stream-progress: defaulting --segment-gens 2")
    engine = build_engine(args, mesh, result_cache=cache)
    on_progress = None
    if args.stream_progress:
        def on_progress(rid, snap):
            best = (f"{snap.top_scores[0]:.4g}" if len(snap.top_scores)
                    else "infeasible")
            print(f"[serve] rid {rid} partial @gen {snap.generations}: "
                  f"best-so-far {best}")
    retry = None
    if args.retry_attempts > 1:
        retry = RetryPolicy(max_attempts=args.retry_attempts,
                            backoff_s=args.retry_backoff)
    svc_kw = dict(engine=engine, mesh=mesh, policy=args.serve_policy,
                  retry=retry, partial_results=args.partial_results,
                  result_cache=cache,
                  pipelined=args.pipelined or None)
    mix_kw = {}
    if args.serve_policy == "priority":
        mix_kw["priorities"] = [3, 0, 1, 2]
    elif args.serve_policy == "edf":
        mix_kw["deadlines_s"] = [5.0, 60.0, 30.0, None]
    reqs = paper_request_mix(
        ws, args.serve, backend=args.backend, pop_size=args.pop,
        generations=args.gens, area_constr=args.area, **mix_kw,
    )
    results = {}
    t0 = time.time()
    if args.serve_async:
        with AsyncDSEService(**svc_kw) as svc:
            futs = [svc.submit(r, on_progress=on_progress) for r in reqs]
            print(f"[serve] {args.serve} heterogeneous requests submitted "
                  f"async (policy={args.serve_policy}, "
                  f"backend={args.backend}, "
                  f"slots={svc.service.engine.max_slots})")
            for fut in futs:
                res = fut.result()
                results[fut.rid] = res
                best = (f"{res.top_scores[0]:.4g}" if len(res.top_scores)
                        else "infeasible")
                print(f"[serve] rid {fut.rid}: {res.objective} on "
                      f"{','.join(res.workload_names)} -> best={best}")
        stats = svc.stats
    else:
        svc = DSEService(**svc_kw)
        rids = [svc.submit(r, on_progress=on_progress) for r in reqs]
        print(f"[serve] {args.serve} heterogeneous requests queued "
              f"(policy={args.serve_policy}, backend={args.backend}, "
              f"slots={svc.engine.max_slots})")
        # cache hits resolved AT submit — they never reach the queue, so
        # the stream below won't yield them
        for rid in rids:
            res = svc.results.get(rid)
            if res is not None:
                results[rid] = res
                best = (f"{res.top_scores[0]:.4g}" if len(res.top_scores)
                        else "infeasible")
                print(f"[serve] rid {rid}: {res.objective} on "
                      f"{','.join(res.workload_names)} -> best={best} "
                      f"(cache hit)")
        for rid, res in svc.stream():
            results[rid] = res
            best = (f"{res.top_scores[0]:.4g}" if len(res.top_scores)
                    else "infeasible")
            print(f"[serve] rid {rid}: {res.objective} on "
                  f"{','.join(res.workload_names)} -> best={best}")
        stats = svc.stats
    dt = time.time() - t0
    n_evald = args.serve * args.pop * (args.gens + 1)
    print(f"[serve] drained {len(results)} requests in {dt:.1f}s "
          f"({len(results)/dt:.1f} req/s, {n_evald/dt:.0f} designs/s, "
          f"{stats.launches} launches, wait p50/p99 "
          f"{_fmt(stats.wait_p(50))}/{_fmt(stats.wait_p(99))}s, "
          f"latency p50/p99 {_fmt(stats.latency_p(50))}/"
          f"{_fmt(stats.latency_p(99))}s, "
          f"{stats.deadline_misses} deadline misses)")
    print(f"[serve] faults: {stats.failures} failures, {stats.retries} "
          f"retries, {stats.partials} partials, {stats.abandoned} abandoned")
    eng = svc.service.engine if args.serve_async else svc.engine
    print(f"[serve] overlap: pipelined={'on' if args.pipelined else 'off'}, "
          f"dispatch->harvest gap p50 "
          f"{_fmt(stats.dispatch_gap_p(50), '.4f')}s, device idle "
          f"{stats.device_idle_s:.3f}s, "
          f"{getattr(eng, 'transfer_bytes', 0)} bytes harvested over "
          f"{getattr(eng, 'launches', 0)} engine launches")
    if cache is not None:
        print(f"[serve] cache: {stats.cache_hits} submit hits / "
              f"{stats.cache_misses} misses this drain "
              f"(hit rate {stats.cache_hit_rate():.1%}); tiers: "
              f"{cache.stats.summary()}")
    if args.out:
        payload = [
            {
                "rid": rid,
                "objective": res.objective,
                "workloads": list(res.workload_names),
                "best": float(res.top_scores[0]) if len(res.top_scores) else None,
                "best_design": res.top_designs[0] if res.top_designs else None,
            }
            for rid, res in sorted(results.items())
        ]
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[serve] wrote {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workloads", default="", help="CNN names, comma-sep")
    ap.add_argument("--lm-workloads", default="", help="assigned arch ids")
    ap.add_argument("--mode", default="decode", choices=["decode", "prefill"])
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument(
        "--objective", default="ela",
        help="scalar objective family (ela/edp/e/l) or 'pareto' for "
             "NSGA-II front search: the result holds the --pareto-k best "
             "non-dominated designs in crowded order with their per-member "
             "(E, L, A) objective vectors",
    )
    ap.add_argument(
        "--pareto-k", type=int, default=10, metavar="K",
        help="--objective pareto: how many front members to return "
             "(crowded order, decoded-cell-deduped)",
    )
    ap.add_argument(
        "--backend", default="jnp", choices=["jnp", "pallas", "table"],
        help="cost-model evaluation backend: dense jnp oracle, the Pallas "
             "TPU kernel, or precomputed per-workload grid tables "
             "(layer-depth-independent eval; see imc/tables.py)",
    )
    ap.add_argument("--area", type=float, default=150.0)
    ap.add_argument("--pop", type=int, default=40)
    ap.add_argument("--gens", type=int, default=10)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--separate", action="store_true", help="also run per-workload baselines")
    ap.add_argument(
        "--search-mesh", default=None, metavar="SxP",
        help="(search, population) mesh, e.g. 8x1 — shard the batched "
             "programs over the visible devices",
    )
    ap.add_argument(
        "--serve", type=int, default=0, metavar="N",
        help="run the continuous-batching DSE service on N heterogeneous "
             "requests (mixed workload subsets / objectives / seeds) "
             "instead of the one-off joint search",
    )
    ap.add_argument(
        "--serve-policy", default="fifo", choices=["fifo", "priority", "edf"],
        help="--serve scheduling policy; priority/edf cycle mixed "
             "priorities / deadlines into the request mix",
    )
    ap.add_argument(
        "--serve-async", action="store_true",
        help="drain --serve through the threaded AsyncDSEService front "
             "end (submit returns futures) instead of the sync queue",
    )
    ap.add_argument(
        "--pipelined", action="store_true",
        help="transfer-thin pipelined execution: on-device top-k epilogue "
             "(only (top_k, n) genomes + scores + the convergence curve "
             "cross the wire; result.ga is None) and, under --serve, a "
             "double-buffered dispatch/harvest drain that overlaps host "
             "finalize with device compute — bit-identical results",
    )
    ap.add_argument(
        "--segment-gens", type=int, default=0, metavar="K",
        help="run each search as ceil(gens/K) segments of K generations "
             "(bit-identical to single-shot) so faults lose at most one "
             "segment of work; 0 = single-shot",
    )
    ap.add_argument(
        "--segment-retries", type=int, default=1,
        help="per-segment retry budget from the last good GA state "
             "before the engine gives up with an EngineFault",
    )
    ap.add_argument(
        "--checkpoint-dir", default="", metavar="DIR",
        help="persist segment boundaries under DIR; a re-run of the same "
             "plan resumes from the latest checkpoint (implies segmented "
             "execution, 1-generation segments if --segment-gens unset)",
    )
    ap.add_argument(
        "--retry-attempts", type=int, default=0, metavar="N",
        help="--serve: total launch attempts per request before it is "
             "abandoned (failed chunks re-plan each member in isolation, "
             "quarantining persistent offenders); <2 disables the retry "
             "lane",
    )
    ap.add_argument(
        "--retry-backoff", type=float, default=0.5, metavar="S",
        help="--serve: base retry backoff in seconds (exponential, "
             "deterministically jittered per rid)",
    )
    ap.add_argument(
        "--partial-results", action="store_true",
        help="--serve: resolve quarantined / past-deadline requests with "
             "their best-so-far anytime result (partial=True) instead of "
             "dropping them",
    )
    ap.add_argument(
        "--result-cache", default="", metavar="DIR",
        help="--serve: arm the fingerprint-keyed result cache with a disk "
             "tier under DIR — a request answered before (this process or "
             "any earlier one over DIR) resolves at submit with zero GA "
             "launches, bit-identical to a fresh search",
    )
    ap.add_argument(
        "--stream-progress", action="store_true",
        help="--serve: print each request's improving best-so-far after "
             "every guarded GA segment (implies segmented execution; "
             "defaults --segment-gens 2 if no boundary was chosen)",
    )
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    mesh = None
    if args.search_mesh:
        from repro.launch.mesh import describe, make_search_mesh

        s, p = (int(v) for v in args.search_mesh.lower().split("x"))
        mesh = make_search_mesh(s, p)
        print(f"[search] mesh: {describe(mesh)} ({jax.device_count()} devices)")

    ws = build_workloads(args)
    print(f"[search] workloads: {ws.names} (L_max={ws.feats.shape[1]})")

    if args.serve:
        return serve(args, ws, mesh)

    if args.seeds < 1:
        ap.error("--seeds must be >= 1")
    # all seeds' joint searches run as ONE vmapped XLA program
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(args.seeds)])
    engine = build_engine(args, mesh)
    t0 = time.time()
    ress = joint_search_batched(
        keys, ws,
        objective=args.objective, area_constr=args.area,
        pop_size=args.pop, generations=args.gens,
        pareto_k=args.pareto_k,
        mesh=mesh, backend=args.backend, engine=engine,
        pipelined=args.pipelined or None,
    )
    dt_all = time.time() - t0
    n_evald = args.seeds * args.pop * (args.gens + 1)
    print(f"[search] {args.seeds} seed(s) in {dt_all:.1f}s "
          f"({n_evald/dt_all:.0f} designs/s vs paper's ~0.03/s)")

    results = []
    for seed, res in enumerate(ress):
        dt = dt_all / args.seeds
        best = f"{res.top_scores[0]:.4g}" if len(res.top_scores) else "infeasible"
        print(f"[search] seed {seed}: best={best}")
        if res.top_designs:
            print(f"         best design: {res.top_designs[0]}")
        entry = {
            "seed": seed,
            "joint_best": float(res.top_scores[0]) if len(res.top_scores) else None,
            "joint_top10": [float(s) for s in res.top_scores],
            "best_design": res.top_designs[0] if res.top_designs else None,
            "convergence": [float(c) for c in res.convergence],
            "wall_s": dt,
        }
        if res.objective_vectors is not None:
            # pareto mode: the k front members' (E, L, A) trade-off triples
            entry["pareto_front"] = [
                {"E_pj": float(v[0]), "L_ns": float(v[1]), "A_mm2": float(v[2])}
                for v in res.objective_vectors
            ]
            for j, v in enumerate(res.objective_vectors):
                print(f"         front[{j}]: E={v[0]:.4g}pJ L={v[1]:.4g}ns "
                      f"A={v[2]:.4g}mm2")
        if args.separate:
            key2 = jax.random.PRNGKey(seed + 1000)
            sep = separate_search(
                key2, ws,
                objective=args.objective, area_constr=args.area,
                pop_size=args.pop, generations=args.gens,
                mesh=mesh, backend=args.backend, engine=engine,
                pipelined=args.pipelined or None,
            )
            cross = {}
            for name, r in sep.items():
                if len(r.top_genomes):
                    s_all, res_all = rescore_designs(
                        r.top_genomes, ws,
                        objective=args.objective, area_constr=args.area,
                    )
                    failed = float(np.mean(~np.isfinite(s_all)))
                else:
                    failed = 1.0
                cross[name] = {
                    "own_best": float(r.top_scores[0]) if len(r.top_scores) else None,
                    "failed_frac_on_all": failed,
                }
            entry["separate"] = cross
            print(f"         separate: {json.dumps(cross)}")
        results.append(entry)

    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[search] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
