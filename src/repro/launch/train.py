"""Training launcher: end-to-end driver with fault tolerance.

    python -m repro.launch.train --arch llama3.2-1b --steps 300 \
        --d-model 256 --layers 4 --seq 256 --batch 8   # reduced CPU run

Production behaviors demonstrated end-to-end (and unit-tested):
  * pjit/GSPMD sharded step over an arbitrary (data, model) mesh,
  * atomic sharded checkpoints every N steps + AUTO-RESUME (restart the
    same command; it continues from the newest committed step, replaying
    the data stream deterministically),
  * elastic restart: ``restore_resharded`` re-lays a checkpoint onto a
    different mesh shape (``--elastic-from``),
  * async dispatch + double-buffered host data loading (the host never
    blocks the device step on input),
  * optional cross-pod int8+error-feedback gradient compression
    (``--compress-pod-grads``) — wired when the mesh has a "pod" axis.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro.configs.base import ShapeSpec, get_config
from repro.data.pipeline import DataState, make_batch_fn, prefetch_iter
from repro.distributed import ctx as dist_ctx
from repro.distributed.sharding import make_rules, params_sharding
from repro.launch.mesh import make_test_mesh
from repro.models import transformer
from repro.optim import AdamWState, adamw_init
from repro.train.step import make_train_step


def build_state(cfg, mesh, key):
    tmpl = transformer.param_template(cfg)
    shard_tree = params_sharding(cfg, mesh, tmpl)
    params = jax.jit(
        lambda k: transformer.init(cfg, k),
        out_shardings=shard_tree,
    )(key)
    opt = adamw_init(params)
    return params, opt, shard_tree


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=0, help="reduce: override width")
    ap.add_argument("--layers", type=int, default=0, help="reduce: override depth")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="mesh data-axis size")
    ap.add_argument("--model", type=int, default=1, help="mesh model-axis size")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.d_model or args.layers:
        cfg = cfg.reduced(
            **({"d_model": args.d_model} if args.d_model else {}),
            **({"n_layers": args.layers} if args.layers else {}),
        )
    mesh = make_test_mesh(args.data, args.model)
    rules = make_rules(mesh)

    key = jax.random.PRNGKey(args.seed)
    params, opt, shard_tree = build_state(cfg, mesh, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")

    step_fn = make_train_step(
        cfg, peak_lr=args.lr, total_steps=args.steps, accum=args.accum,
        warmup_steps=max(args.steps // 20, 5),
    )
    opt_shard = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=shard_tree,
        nu=jax.tree.map(lambda s: s, shard_tree),
    )
    jstep = jax.jit(
        step_fn,
        in_shardings=(shard_tree, opt_shard, None),
        out_shardings=(shard_tree, opt_shard, None),
        donate_argnums=(0, 1),
    )

    extras = {}
    if cfg.vision_tokens:
        extras["vision_embeds"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.vision_tokens, cfg.d_model), jnp.float32
        )
        extras["mrope_pos"] = jax.ShapeDtypeStruct((3, args.batch, args.seq), jnp.int32)
    if cfg.is_encdec:
        extras["frames"] = jax.ShapeDtypeStruct(
            (args.batch, args.seq, cfg.d_model), jnp.float32
        )
    batch_fn = make_batch_fn(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed, extras=extras
    )

    start = 0
    ckpt_dir = Path(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
        (params, opt), start = ckpt_lib.restore_resharded(
            ckpt_dir, (params, opt),
            (shard_tree, opt_shard),
        )
        print(f"[train] auto-resumed from step {start}")

    t0 = time.time()
    losses = []
    with dist_ctx.use_rules(mesh, rules):
        it = prefetch_iter(batch_fn, start)
        for i, (step_idx, batch) in enumerate(it):
            if step_idx >= args.steps:
                break
            params, opt, metrics = jstep(params, opt, batch)
            if step_idx % args.log_every == 0 or step_idx == args.steps - 1:
                loss = float(metrics["loss"])  # sync point
                losses.append(loss)
                dt = time.time() - t0
                print(f"[train] step {step_idx:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({dt:.1f}s)", flush=True)
            if ckpt_dir and step_idx > start and step_idx % args.ckpt_every == 0:
                ckpt_lib.save(ckpt_dir, step_idx, (params, opt))
                print(f"[train] checkpoint @ {step_idx}")
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, args.steps, (params, opt))
    if len(losses) >= 2:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({'DOWN' if losses[-1] < losses[0] else 'FLAT'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
