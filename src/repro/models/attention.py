"""Attention: chunked online-softmax (flash-style) in pure jnp.

This is the *portable* implementation used for training / prefill lowering on
every backend (the O(S^2) score matrix never materializes — memory is bounded
by one (Sq, chunk) block).  On real TPUs the Pallas kernel
``repro.kernels.flash_attention`` is a drop-in replacement (same math,
validated against this code in interpret mode).

Shapes follow the (B, S, H, D) convention with grouped KV heads:
q: (B, Sq, H, D);  k, v: (B, Skv, KV, D);  H % KV == 0.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.utils.unroll import MAX_UNROLL, maybe_scan, unrolling

NEG_INF = -1e30


def _mask(q_pos, kv_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - kv_pos[None, :] < window
    return m


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked attention with online softmax over KV blocks.

    ``q_offset`` shifts query positions (queries are at absolute positions
    ``q_offset + [0..Sq)`` while keys are at ``[0..Skv)``) — used when a
    query block attends into a longer KV (e.g. chunked prefill).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    chunk = min(chunk, Skv)
    if unrolling() and Skv // chunk > MAX_UNROLL:
        # cost-analysis lowering: widen chunks so the scan fully unrolls
        # (n_chunks is a memory knob, not semantics; nothing executes here)
        chunk = -(-Skv // MAX_UNROLL)
    assert Skv % chunk == 0, (Skv, chunk)
    n_chunks = Skv // chunk
    scale = D ** -0.5

    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KV, G, D)
    # GSPMD hint: keep batch sharded through the chunk scan (the carry inits
    # below are fresh constants — without hints the loop can resolve to a
    # batch-replicated schedule that blows memory by the data-axis size).
    qf = constrain(qf, ("batch", None, None, None, None))
    q_pos = q_offset + jnp.arange(Sq)

    # scan carries running (max, sumexp, weighted-acc)
    def body(carry, ck):
        m_prev, l_prev, acc = carry
        kc, vc, start = ck  # (B, C, KV, D), (B, C, KV, D), scalar
        kv_pos = start + jnp.arange(chunk)
        # scores: (B, KV, G, Sq, C)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kc.astype(jnp.float32))
        msk = _mask(q_pos, kv_pos, causal, window)  # (Sq, C)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    kc = k.reshape(B, n_chunks, chunk, KV, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, KV, D).swapaxes(0, 1)
    starts = jnp.arange(n_chunks) * chunk
    bkgs = (None, "batch", None, None, None)
    kc = constrain(kc, bkgs)
    vc = constrain(vc, bkgs)
    init = (
        constrain(jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32), ("batch", None, None, None)),
        constrain(jnp.zeros((B, KV, G, Sq), jnp.float32), ("batch", None, None, None)),
        constrain(jnp.zeros((B, KV, G, Sq, D), jnp.float32), ("batch", None, None, None, None)),
    )
    (m, l, acc), _ = maybe_scan(body, init, (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, Sq, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Unchunked O(S^2) oracle (tests only)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qf = (q * D ** -0.5).astype(jnp.float32).reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf, k.astype(jnp.float32))
    msk = _mask(q_offset + jnp.arange(Sq), jnp.arange(Skv), causal, window)
    s = jnp.where(msk[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    window: int = 0,
    valid_len=None,
) -> jax.Array:
    """Single-token decode: q (B, 1, H, D) against a full cache (B, S, KV, D).

    The softmax reduction runs over the (possibly sequence-sharded) cache —
    under GSPMD this lowers to flash-decode-style partial softmax + combine
    collectives on the sharded axis.

    ``valid_len`` (scalar or (B,)) masks cache rows ``>= valid_len``
    (unwritten ring slots during early decode, per sequence); ``window``
    masks a linear-layout cache to the trailing window (tests / non-ring
    callers).
    """
    B, Sq, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    # keep the CACHE operands in their storage dtype and accumulate in f32
    # on the MXU (preferred_element_type).  An explicit .astype(f32) on the
    # cache gets HOISTED out of the decode block-scan by XLA — materializing
    # a full f32 copy of the stacked KV cache (2x cache memory).
    qf = (q * D ** -0.5).astype(k_cache.dtype).reshape(B, Sq, KV, G, D)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qf, k_cache, preferred_element_type=jnp.float32
    )
    pos = jnp.arange(S)
    if window > 0:
        ok = pos >= (S - window)  # query sits at position S-1
        s = jnp.where(ok[None, None, None, None], s, NEG_INF)
    if valid_len is not None:
        vl = jnp.broadcast_to(jnp.asarray(valid_len), (B,))
        ok = pos[None, :] < vl[:, None]  # (B, S)
        s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)
