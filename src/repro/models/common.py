"""Shared model building blocks + declarative parameter system.

Parameters are declared as a nested dict of :class:`ParamDecl` (shape, logical
dim names, init scale).  The same template materializes three ways:

* ``init_params``    — real arrays (seeded, for training / smoke tests)
* ``param_structs``  — ``ShapeDtypeStruct`` tree (dry-run: no allocation)
* ``param_specs``    — ``PartitionSpec`` tree via logical→mesh rules

so the model code, the launcher and the sharding rules can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # logical name per dim (None = replicated)
    scale: float = 1.0  # stddev multiplier on fan-in init; 0 -> zeros; -1 -> ones
    # alternative whole-tuple layout used when any *primary* named dim fails
    # mesh divisibility (e.g. EP layout -> expert-TP layout for MoE weights
    # whose expert count does not divide the model axis)
    alt_logical: Optional[Tuple[Optional[str], ...]] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)
        if self.alt_logical is not None:
            assert len(self.shape) == len(self.alt_logical)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def tree_map_decl(f: Callable[[ParamDecl], Any], tree: PyTree) -> PyTree:
    return jax.tree.map(f, tree, is_leaf=is_decl)


def init_params(template: PyTree, key: jax.Array, dtype=jnp.float32) -> PyTree:
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.scale == 0.0:
            out.append(jnp.zeros(d.shape, dtype))
        elif d.scale == -1.0:
            out.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / (fan_in ** 0.5)
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def param_structs(template: PyTree, dtype=jnp.float32) -> PyTree:
    return tree_map_decl(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), template)


def param_specs(template: PyTree, rules: Dict[str, Any]) -> PyTree:
    """Map logical dim names to mesh axes.  A rule value may be None, a str
    axis, or a tuple of axes.  Dims whose size does not divide the mesh-axis
    product fall back to replicated (safe for odd head counts, small experts).
    """
    mesh_sizes = rules.get("_mesh_sizes", {})

    def axis_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh_sizes.get(a, 1)
            return n
        return mesh_sizes.get(ax, 1)

    def flat_axes(ax):
        return tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)

    def spec_for(shape, logical):
        spec = []
        used: set = set()
        all_ok = True
        for size, name in zip(shape, logical):
            ax = rules.get(name) if name else None
            if ax is None:
                spec.append(None)
                continue
            n = axis_size(ax)
            if n <= 1 or size % n != 0 or any(a in used for a in flat_axes(ax)):
                spec.append(None)
                all_ok = False
                continue
            used.update(flat_axes(ax))
            spec.append(ax)
        return P(*spec), all_ok

    def one(d: ParamDecl):
        spec, ok = spec_for(d.shape, d.logical)
        if not ok and d.alt_logical is not None:
            spec, _ = spec_for(d.shape, d.alt_logical)
        return spec

    return tree_map_decl(one, template)


# --------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """(t, h, w) half-dim sections; qwen2-vl uses (16, 24, 24) for D=128."""
    half = head_dim // 2
    t = half // 4
    rem = half - t
    return (t, rem // 2, rem - rem // 2)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): positions (3, ..., S) for (t, h, w) axes,
    each rotating its own section of the head dim."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)  # (half,)
    secs = mrope_sections(d)
    # section id per frequency index
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(secs)]
    )  # (half,)
    # select, per frequency, the (t|h|w) position stream: (half, ..., S)
    pos = jnp.moveaxis(positions.astype(jnp.float32)[sec_id], 0, -1)  # (..., S, half)
    ang = pos[..., None, :] * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (seq, d_model)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------------ MLP acts
def glu_act(name: str, gate: jax.Array, up: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(gate) * up
    if name == "gelu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(name)
