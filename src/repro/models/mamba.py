"""Mamba-2 (SSD) mixer block: in_proj -> causal depthwise conv -> SSD -> gate.

Portable path uses the chunked jnp SSD from ``kernels/ssd_scan/ref.py``;
on TPU the Pallas kernel (``kernels/ssd_scan/ops.py``) is the fast path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ref as ssd
from repro.distributed.ctx import constrain
from repro.models.common import rms_norm


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, K-1, conv_ch) trailing conv inputs
    ssm: jax.Array  # (B, H, N, P) state


def _dims(cfg):
    d_inner = cfg.d_inner
    G, N = cfg.ssm_groups, cfg.ssm_state
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return d_inner, G, N, H, Pd, conv_ch, d_in_proj


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel K (shift-sum form, K unrolled)."""
    K = w.shape[0]
    out = jnp.zeros_like(xBC)
    S = xBC.shape[1]
    for k in range(K):
        shift = K - 1 - k
        seg = jnp.pad(xBC, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        out = out + seg * w[k]
    return out + b


def mamba_mixer(
    cfg,
    p,
    x: jax.Array,
    cache: Optional[MambaCache] = None,
    *,
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[MambaCache]]:
    """x: (B, S, d_model).  Full-sequence form (train / prefill)."""
    B, S, d = x.shape
    d_inner, G, N, H, Pd, conv_ch, _ = _dims(cfg)

    w_in = constrain(p["w_in"].astype(x.dtype), (None, "ssm_inner"))
    zxbcdt = x @ w_in
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    xBC_raw = xBC

    xBC = _causal_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, Pd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)

    y, h = ssd.ssd_chunked(xs, dt, A, Bm, Cm, chunk=min(128, S))
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)
    out = y @ constrain(p["w_out"].astype(y.dtype), ("ssm_inner", None))

    new_cache = None
    if return_cache:
        K = cfg.ssm_conv
        # trailing K-1 *pre-activation* conv inputs
        conv_tail = xBC_raw[:, -(K - 1) :, :]
        new_cache = MambaCache(conv=conv_tail, ssm=h)
    return out, new_cache


def mamba_decode(
    cfg, p, x: jax.Array, cache: MambaCache
) -> Tuple[jax.Array, MambaCache]:
    """x: (B, 1, d_model); single-token step with carried conv + ssm state."""
    B, _, d = x.shape
    d_inner, G, N, H, Pd, conv_ch, _ = _dims(cfg)

    w_in = constrain(p["w_in"].astype(x.dtype), (None, "ssm_inner"))
    zxbcdt = x[:, 0] @ w_in  # (B, d_in_proj)
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)

    # conv over (cached K-1 inputs + current); compute in x dtype, keep the
    # cache's own dtype stable (scan carry requires it)
    w, b = p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype)
    K = cfg.ssm_conv
    window = jnp.concatenate(
        [cache.conv.astype(x.dtype), xBC[:, None, :]], axis=1
    )  # (B,K,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + b
    xBC_a = jax.nn.silu(conv_out)

    xs, Bm, Cm = jnp.split(xBC_a, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, H, Pd)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, h = ssd.ssd_decode_step(xs, dtf, A, Bm, Cm, cache.ssm)
    y = y + xs * p["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(B, d_inner)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)
    w_out = constrain(p["w_out"].astype(y.dtype), ("ssm_inner", None))
    out = (y @ w_out)[:, None, :]

    new_cache = MambaCache(conv=window[:, 1:].astype(cache.conv.dtype), ssm=h)
    return out, new_cache
