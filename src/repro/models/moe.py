"""Mixture-of-Experts FFN with capacity-based scatter dispatch (GShard-style).

Static shapes throughout (SPMD-safe): tokens are routed into a per-sequence
``(B, E, C, d)`` buffer via scatter-add, experts run as one batched einsum
over stacked weights ``(E, d, f)``, and results gather back.  Tokens beyond
an expert's capacity ``C = ceil(S * topk * capacity_factor / E)`` are dropped
(standard GShard semantics); the router aux loss keeps load balanced.

Sharding: the expert dim of the stacked weights carries logical name
"experts" — the rules map it to the `model` axis when divisible (true EP,
GSPMD inserts the token all-to-all) and fall back to expert-TP (shard the
"moe_ff" dim) otherwise (e.g. mixtral's 8 experts on a 16-way model axis).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain, logical_axis_size
from repro.models.common import glu_act


def moe_capacity(seq: int, n_experts: int, topk: int, capacity_factor: float) -> int:
    c = int(-(-seq * topk * capacity_factor // n_experts))  # ceil
    return max(1, min(c, seq * topk))


def moe_ffn(
    x: jax.Array,
    router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    topk: int,
    capacity_factor: float,
    act: str = "silu",
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d); router: (d, E); w_*: (E, d, f) / (E, f, d).

    Returns (output (B, S, d), aux load-balance loss (scalar)).
    """
    B, S, d = x.shape
    E = router.shape[-1]
    C = moe_capacity(S, E, topk, capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    topw, topi = jax.lax.top_k(probs, topk)  # (B, S, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) entry within its expert queue, in seq order
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)  # (B, S, k, E)
    flat = onehot.reshape(B, S * topk, E)
    before = jnp.cumsum(flat, axis=1) - flat
    pos = (before * flat).sum(-1)  # (B, S*k)
    eid = topi.reshape(B, S * topk)
    w = topw.reshape(B, S * topk)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1)

    # dispatch: scatter tokens into (B, E, C, d)
    xk = jnp.repeat(x, topk, axis=1)  # (B, S*k, d) — entry (t, j) adjacent
    xk = constrain(xk, ("batch", "seq", None))
    contrib = xk * keep[..., None].astype(x.dtype)
    contrib = constrain(contrib, ("batch", "seq", None))

    # dispatch via vmap so the scatter keeps an explicit batch dim — GSPMD
    # partitions batched scatters along it; flat advanced indexing would
    # fold batch into the index space and force replication.
    def _scatter_one(c_s, e_s, p_s):
        return jnp.zeros((E, C, d), x.dtype).at[e_s, p_s].add(c_s)

    buf = jax.vmap(_scatter_one)(contrib, eid, pos_c)
    # EP hint: shard the dispatch buffer's expert dim over the model axis
    # (when divisible) — the scatter above then lowers to the token
    # all-to-all and the expert einsums stay local.  No-op off-mesh.
    buf = constrain(buf, ("batch", "experts", None, None))

    # expert FFN (batched over E): SwiGLU/GeGLU.  In the EP layout, cast
    # the expert weights to the compute dtype and constrain the casted copy
    # to the gathered layout (experts sharded, hidden replicated) — the
    # all-gather then moves bf16, not the f32 masters.  In the expert-TP
    # fallback (E does not divide the model axis) the weights stay in their
    # storage layout: TP compute needs no gather at all.
    ep_active = E % max(logical_axis_size("experts"), 1) == 0

    def _compute_copy(w):
        w = w.astype(buf.dtype)
        return constrain(w, ("experts", None, None)) if ep_active else w

    g = jnp.einsum("becd,edf->becf", buf, _compute_copy(w_gate))
    u = jnp.einsum("becd,edf->becf", buf, _compute_copy(w_up))
    h = glu_act(act, g, u)
    y = jnp.einsum("becf,efd->becd", h, _compute_copy(w_down))
    y = constrain(y, ("batch", "experts", None, None))

    # combine: batched gather back + weight
    yk = jax.vmap(lambda y_s, e_s, p_s: y_s[e_s, p_s])(y, eid, pos_c)
    yk = constrain(yk, ("batch", "seq", None))
    yk = yk * (w * keep).astype(y.dtype)[..., None]
    out = constrain(yk.reshape(B, S, topk, d).sum(axis=2), ("batch", "seq", None))

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    f_e = onehot.astype(jnp.float32).mean(axis=(0, 1, 2)) * topk  # fraction routed
    p_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    return out, aux
