"""Unified LM assembly for every assigned architecture.

One functional model covers dense / MoE / SSM / hybrid / enc-dec / VLM
families.  Structure:

* a ``ModelConfig.layer_plan()`` gives the repeating *period* of
  (mixer, ffn) sub-layer kinds; parameters for each in-period *slot* are
  stacked over ``n_blocks`` and the stack is traversed with ``lax.scan``
  (small HLO -> fast 512-device dry-run compiles, natural remat unit).
* three entry points:
    - ``forward``      full-sequence (train / loss)
    - ``prefill``      full-sequence returning a decode cache
    - ``decode_step``  single token with carried cache
* caches are plain pytrees so they shard/donate cleanly under pjit.

Everything is pure-jnp (flash-style chunked attention, chunked SSD) so the
same code lowers on CPU, GPU and TPU; Pallas TPU kernels in
``repro.kernels`` are numerically-identical drop-ins (see kernels/README).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.utils.unroll import maybe_scan
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.common import (
    ParamDecl,
    apply_mrope,
    apply_rope,
    glu_act,
    init_params,
    layer_norm,
    param_specs,
    param_structs,
    rms_norm,
    sinusoid_positions,
)

PyTree = Any

# ======================================================================
# parameter templates
# ======================================================================


def _attn_decl(cfg: ModelConfig) -> Dict[str, ParamDecl]:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    decl = {
        "norm_w": ParamDecl((d,), ("embed",), -1.0),
        "wq": ParamDecl((d, H * Dh), ("embed", "heads")),
        "wk": ParamDecl((d, KV * Dh), ("embed", "kv_heads")),
        "wv": ParamDecl((d, KV * Dh), ("embed", "kv_heads")),
        "wo": ParamDecl((H * Dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        decl["bq"] = ParamDecl((H * Dh,), ("heads",), 0.0)
        decl["bk"] = ParamDecl((KV * Dh,), ("kv_heads",), 0.0)
        decl["bv"] = ParamDecl((KV * Dh,), ("kv_heads",), 0.0)
    return decl


def _xattn_decl(cfg: ModelConfig) -> Dict[str, ParamDecl]:
    """Cross-attention (whisper decoder); KV projected from encoder states."""
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "norm_w": ParamDecl((d,), ("embed",), -1.0),
        "wq": ParamDecl((d, H * Dh), ("embed", "heads")),
        "wk": ParamDecl((d, KV * Dh), ("embed", "kv_heads")),
        "wv": ParamDecl((d, KV * Dh), ("embed", "kv_heads")),
        "wo": ParamDecl((H * Dh, d), ("heads", "embed")),
    }


def _mlp_decl(cfg: ModelConfig) -> Dict[str, ParamDecl]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm_w": ParamDecl((d,), ("embed",), -1.0),
        "w_gate": ParamDecl((d, f), ("embed", "ff")),
        "w_up": ParamDecl((d, f), ("embed", "ff")),
        "w_down": ParamDecl((f, d), ("ff", "embed")),
    }


def _moe_decl(cfg: ModelConfig) -> Dict[str, ParamDecl]:
    """Expert weights: EP-primary layout (experts over ``model``, expert
    hidden over ``data``, d_model UNSHARDED — so the expert einsum's
    contraction never fights the batch's data axis).  Falls back to the
    expert-TP layout (hidden over ``model``, d_model over ``data``) when
    the expert count does not divide the model axis (e.g. mixtral 8e/16)."""
    d, f, E = cfg.d_model, cfg.moe_d_ff_, cfg.n_experts
    ep_in = (("experts", None, "moe_ff_ep"), ("experts", "embed", "moe_ff"))
    ep_out = (("experts", "moe_ff_ep", None), ("experts", "moe_ff", "embed"))
    return {
        "norm_w": ParamDecl((d,), ("embed",), -1.0),
        "router": ParamDecl((d, E), ("embed", None)),
        "w_gate": ParamDecl((E, d, f), ep_in[0], alt_logical=ep_in[1]),
        "w_up": ParamDecl((E, d, f), ep_in[0], alt_logical=ep_in[1]),
        "w_down": ParamDecl((E, f, d), ep_out[0], alt_logical=ep_out[1]),
    }


def _mamba_decl(cfg: ModelConfig) -> Dict[str, ParamDecl]:
    d = cfg.d_model
    d_inner, G, N, H, Pd, conv_ch, d_in_proj = mamba_lib._dims(cfg)
    return {
        "norm_w_in": ParamDecl((d,), ("embed",), -1.0),
        "w_in": ParamDecl((d, d_in_proj), ("embed", "ssm_inner")),
        "conv_w": ParamDecl((cfg.ssm_conv, conv_ch), (None, "ssm_inner")),
        "conv_b": ParamDecl((conv_ch,), ("ssm_inner",), 0.0),
        "A_log": ParamDecl((H,), (None,), -1.0),  # init A = -1
        "D": ParamDecl((H,), (None,), -1.0),
        "dt_bias": ParamDecl((H,), (None,), 0.0),
        "norm_w": ParamDecl((d_inner,), ("ssm_inner",), -1.0),
        "w_out": ParamDecl((d_inner, d), ("ssm_inner", "embed")),
    }


_SLOT_DECL = {"attn": _attn_decl, "mamba": _mamba_decl, "mlp": _mlp_decl, "moe": _moe_decl}


def _block_decl(cfg: ModelConfig, *, decoder: bool) -> List[Dict[str, Any]]:
    """Per-slot param decls for one period (mixer+ffn [+cross-attn])."""
    slots = []
    for mixer, ffn in cfg.layer_plan():
        slot: Dict[str, Any] = {"mixer": _SLOT_DECL[mixer](cfg)}
        if decoder and cfg.is_encdec:
            slot["xattn"] = _xattn_decl(cfg)
        if ffn != "none":
            slot["ffn"] = _SLOT_DECL[ffn](cfg)
        slots.append(slot)
    return slots


def _stack(tree: PyTree, n: int) -> PyTree:
    """Add a leading stacked-layers dim to every ParamDecl."""
    return jax.tree.map(
        lambda d: ParamDecl(
            (n,) + d.shape,
            ("layers",) + d.logical,
            d.scale,
            alt_logical=(("layers",) + d.alt_logical) if d.alt_logical else None,
        ),
        tree,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def param_template(cfg: ModelConfig) -> PyTree:
    d, V = cfg.d_model, cfg.vocab_size
    t: Dict[str, Any] = {
        "embed": ParamDecl((V, d), ("vocab", "embed")),
        "blocks": _stack(_block_decl(cfg, decoder=True), cfg.n_blocks),
        "final_norm": ParamDecl((d,), ("embed",), -1.0),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamDecl((d, V), ("embed", "vocab"))
    if cfg.is_encdec:
        # stub frontend: precomputed frame embeddings -> linear proj
        enc_cfg = cfg
        t["encoder"] = {
            "frames_proj": ParamDecl((d, d), ("embed", None)),
            "blocks": _stack(
                [{"mixer": _attn_decl(enc_cfg), "ffn": _mlp_decl(enc_cfg)}],
                cfg.encoder_layers,
            ),
            "final_norm": ParamDecl((d,), ("embed",), -1.0),
        }
    return t


# ======================================================================
# sub-layer application
# ======================================================================



def _wc(p, name, dtype, logical):
    """Weight compute-copy: cast to the compute dtype and constrain to the
    GATHERED layout (FSDP dim replicated, TP dims kept).  The FSDP
    all-gather then moves the bf16 copy instead of the f32 master —
    halving gather traffic and the gathered live buffers.  No-op off-mesh.
    """
    return constrain(p[name].astype(dtype), logical)


def _split_heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


def _rope(cfg: ModelConfig, q, k, positions, mrope_pos):
    if cfg.rope_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = apply_mrope(q, mrope_pos, cfg.rope_theta)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta)
    return q, k


def attn_full(cfg, p, x, *, positions, mrope_pos=None, causal=True, attn_impl="jnp"):
    """Full-sequence self-attention sublayer. Returns (out, (k, v))."""
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    h = rms_norm(x, p["norm_w"], cfg.norm_eps)
    q = h @ _wc(p, "wq", h.dtype, (None, "heads"))
    k = h @ _wc(p, "wk", h.dtype, (None, "kv_heads"))
    v = h @ _wc(p, "wv", h.dtype, (None, "kv_heads"))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = _split_heads(q, H, Dh)
    k = _split_heads(k, KV, Dh)
    v = _split_heads(v, KV, Dh)
    q, k = _rope(cfg, q, k, positions, mrope_pos)
    if attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        o = fa_ops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window
        )
    else:
        o = attn_lib.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window,
            chunk=min(1024, S),
        )
    out = o.reshape(B, S, H * Dh) @ _wc(p, "wo", o.dtype, ("heads", None))
    return x + out, (k, v)


def xattn_full(cfg, p, x, enc_kv):
    """Cross-attention with precomputed encoder (k, v)."""
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k, v = enc_kv
    h = rms_norm(x, p["norm_w"], cfg.norm_eps)
    q = _split_heads(h @ _wc(p, "wq", h.dtype, (None, "heads")), H, Dh)
    o = attn_lib.flash_attention(
        q, k, v, causal=False, chunk=min(1024, k.shape[1])
    )
    return x + o.reshape(B, S, H * Dh) @ _wc(p, "wo", o.dtype, ("heads", None))


def xattn_decode(cfg, p, x, enc_kv):
    B, S1, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k, v = enc_kv
    h = rms_norm(x, p["norm_w"], cfg.norm_eps)
    q = _split_heads(h @ _wc(p, "wq", h.dtype, (None, "heads")), H, Dh)
    o = attn_lib.decode_attention(q, k, v)
    return x + o.reshape(B, S1, H * Dh) @ _wc(p, "wo", o.dtype, ("heads", None))


def _build_xkv(cfg, p, enc_out):
    """Project encoder output to (k, v) for one decoder layer."""
    KV, Dh = cfg.n_kv_heads, cfg.head_dim_
    k = _split_heads(enc_out @ _wc(p, "wk", enc_out.dtype, (None, "kv_heads")), KV, Dh)
    v = _split_heads(enc_out @ _wc(p, "wv", enc_out.dtype, (None, "kv_heads")), KV, Dh)
    return k, v


def attn_decode(cfg, p, x, cache, *, pos, mrope_pos=None):
    """Single-token self-attention against a ring/linear KV cache.

    cache: {"k","v"}: (B, C, KV, Dh).  ``pos`` — absolute position of each
    sequence's new token: scalar or (B,) vector (continuous batching: every
    slot decodes at its own position).  With sliding-window the write index
    wraps (ring buffer); unwritten rows are masked via per-row valid length.
    """
    B, S1, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    C = cache["k"].shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    h = rms_norm(x, p["norm_w"], cfg.norm_eps)
    q = h @ _wc(p, "wq", h.dtype, (None, "heads"))
    k = h @ _wc(p, "wk", h.dtype, (None, "kv_heads"))
    v = h @ _wc(p, "wv", h.dtype, (None, "kv_heads"))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = _split_heads(q, H, Dh)
    k = _split_heads(k, KV, Dh)
    v = _split_heads(v, KV, Dh)
    positions = pos[:, None]  # (B, 1)
    if cfg.rope_type == "mrope":
        mp = (
            jnp.broadcast_to(pos, (3, B))[..., None]
            if mrope_pos is None
            else mrope_pos
        )
        q, k = _rope(cfg, q, k, None, mp)
    else:
        q, k = _rope(cfg, q, k, positions, None)
    widx = jnp.mod(pos, C)  # (B,)

    def upd(c, new):  # per-sequence ring write (batched scatter)
        return jax.vmap(
            lambda cb, nb, w: jax.lax.dynamic_update_slice(cb, nb, (w, 0, 0))
        )(c, new.astype(c.dtype), widx)

    k_cache = upd(cache["k"], k)
    v_cache = upd(cache["v"], v)
    valid = jnp.minimum(pos + 1, C)  # (B,) live cache rows per sequence
    o = attn_lib.decode_attention(q, k_cache, v_cache, valid_len=valid)
    out = o.reshape(B, S1, H * Dh) @ _wc(p, "wo", o.dtype, ("heads", None))
    return x + out, {"k": k_cache, "v": v_cache}


def mlp_sublayer(cfg, p, x):
    h = rms_norm(x, p["norm_w"], cfg.norm_eps)
    g = h @ _wc(p, "w_gate", h.dtype, (None, "ff"))
    u = h @ _wc(p, "w_up", h.dtype, (None, "ff"))
    return x + glu_act(cfg.mlp_act, g, u) @ _wc(p, "w_down", h.dtype, ("ff", None))


def moe_sublayer(cfg, p, x):
    h = rms_norm(x, p["norm_w"], cfg.norm_eps)
    y, aux = moe_lib.moe_ffn(
        h,
        p["router"],
        p["w_gate"],
        p["w_up"],
        p["w_down"],
        topk=cfg.topk,
        capacity_factor=cfg.capacity_factor,
        act=cfg.mlp_act,
    )
    return x + y, aux


def mamba_full(cfg, p, x, *, return_cache=False):
    h = rms_norm(x, p["norm_w_in"], cfg.norm_eps)
    y, cache = mamba_lib.mamba_mixer(cfg, p, h, return_cache=return_cache)
    return x + y, cache


def mamba_decode_sub(cfg, p, x, cache):
    h = rms_norm(x, p["norm_w_in"], cfg.norm_eps)
    y, cache = mamba_lib.mamba_decode(cfg, p, h, cache)
    return x + y, cache


# ======================================================================
# cache templates
# ======================================================================


def cache_template(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16) -> PyTree:
    """ShapeDtypeStruct pytree for the decode cache (stacked over blocks)."""
    KV, Dh = cfg.n_kv_heads, cfg.head_dim_
    d_inner, G, N, H, Pd, conv_ch, _ = mamba_lib._dims(cfg) if cfg.ssm_state else (0,) * 7
    C = cache_len if cfg.sliding_window == 0 else min(cache_len, cfg.sliding_window)
    nb = cfg.n_blocks

    def sds(shape, dt=dtype):
        return jax.ShapeDtypeStruct(shape, dt)

    slots = []
    for mixer, _ in cfg.layer_plan():
        if mixer == "attn":
            slot = {
                "k": sds((nb, batch, C, KV, Dh)),
                "v": sds((nb, batch, C, KV, Dh)),
            }
            if cfg.is_encdec:
                slot["xk"] = sds((nb, batch, cache_len, KV, Dh))
                slot["xv"] = sds((nb, batch, cache_len, KV, Dh))
        else:
            slot = {
                "conv": sds((nb, batch, cfg.ssm_conv - 1, conv_ch)),
                "ssm": sds((nb, batch, H, N, Pd), jnp.float32),
            }
        slots.append(slot)
    return slots


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_template(cfg, batch, cache_len, dtype))


def pad_cache(cfg: ModelConfig, cache: PyTree, capacity: int) -> PyTree:
    """Grow a prefill cache's KV capacity to ``capacity`` rows (serving).

    Prefill returns attention caches of exactly the prompt length.  Decode
    writes token ``pos`` at ring index ``pos % C``, so the capacity must be
    the serving target length, not the prompt length.  Linear-layout caches
    (no SWA, or prompt <= window) zero-pad at the tail: position ``p`` stays
    at index ``p``, and decode's ``valid_len`` masks the unwritten rows.
    SWA ring caches at full window size (C == sliding_window) are returned
    unchanged — the ring invariant ``index = p % window`` already holds and
    MUST NOT be padded.
    """

    # SWA caches never exceed the window: the ring (index = p % W) provides
    # eviction, and decode applies no explicit window mask.  A prompt cache
    # of C <= W rows is linear (p % W == p), so padding it to exactly W
    # preserves the ring invariant.
    target = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity

    def grow(x, axis):
        C = x.shape[axis]
        if C >= target:
            return x
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, target - C)
        return jnp.pad(x, pad)

    def one_slot(slot):
        out = dict(slot)
        for k in ("k", "v"):
            if k in out:
                out[k] = grow(out[k], axis=2)  # (layers, B, C, KV, Dh)
        return out

    return [one_slot(s) for s in cache]


# ======================================================================
# encoder (whisper)
# ======================================================================


def encode(cfg: ModelConfig, params: PyTree, frames: jax.Array) -> jax.Array:
    """frames: (B, S, d_model) stubbed frontend embeddings -> encoder states."""
    enc = params["encoder"]
    B, S, d = frames.shape
    x = frames @ enc["frames_proj"].astype(frames.dtype)
    x = x + sinusoid_positions(S, d).astype(x.dtype)
    positions = jnp.arange(S)

    def body(x, p):
        x = constrain(x, ("batch", "seq", None))
        x, _ = attn_full(cfg, p[0]["mixer"], x, positions=positions, causal=False)
        x = mlp_sublayer(cfg, p[0]["ffn"], x)
        return x, None

    x, _ = maybe_scan(body, x, enc["blocks"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ======================================================================
# full-sequence forward (train / prefill)
# ======================================================================


def _embed(cfg, params, tokens, vision_embeds=None):
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    if cfg.scale_embeds:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.vision_tokens and vision_embeds is not None:
        # VLM: image patch embeddings occupy the first `vision_tokens` slots
        VT = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, VT:]], axis=1)
    if cfg.is_encdec and cfg.rope_type == "none":
        x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    return x


def _logits(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    return x @ w


def forward(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,
    *,
    vision_embeds: Optional[jax.Array] = None,
    mrope_pos: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
    remat: bool = False,
    attn_impl: str = "jnp",
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, moe_aux_loss).

    ``return_hidden=True`` returns the final-norm hidden states instead of
    logits — the loss then runs vocab-sharded chunked cross-entropy without
    ever materializing the (B, S, V) logits (see ``train.step``).
    """
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, vision_embeds)
    positions = jnp.arange(S)
    enc_out = encode(cfg, params, frames) if cfg.is_encdec else None
    plan = cfg.layer_plan()

    def body(carry, slot_params):
        x, aux = carry
        x = constrain(x, ("batch", "seq", None))  # keep batch sharded in-loop
        for i, (mixer, ffn) in enumerate(plan):
            sp = slot_params[i]
            if mixer == "attn":
                x, _ = attn_full(
                    cfg, sp["mixer"], x, positions=positions,
                    mrope_pos=mrope_pos, attn_impl=attn_impl,
                )
                if cfg.is_encdec:
                    xkv = _build_xkv(cfg, sp["xattn"], enc_out)
                    x = xattn_full(cfg, sp["xattn"], x, xkv)
            else:
                x, _ = mamba_full(cfg, sp["mixer"], x)
            if ffn == "mlp":
                x = mlp_sublayer(cfg, sp["ffn"], x)
            elif ffn == "moe":
                x, a = moe_sublayer(cfg, sp["ffn"], x)
                aux = aux + a
        return (x, aux), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux), _ = maybe_scan(body, (x, jnp.float32(0.0)), params["blocks"])
    if return_hidden:
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux
    return _logits(cfg, params, x), aux


def head_weight(cfg: ModelConfig, params: PyTree) -> jax.Array:
    """(d, V) LM-head weight (transposed embedding when tied)."""
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ======================================================================
# prefill: full-sequence + cache construction
# ======================================================================


def prefill(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,
    *,
    vision_embeds: Optional[jax.Array] = None,
    mrope_pos: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
    attn_impl: str = "jnp",
    cache_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, PyTree]:
    """Process the whole prompt; returns (last-token logits, decode cache).

    The cache length equals the prompt length (ring-truncated to the sliding
    window when the arch uses SWA).  enc-dec archs encode ``frames`` and
    store per-layer cross-KV in the cache.
    """
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, vision_embeds)
    positions = jnp.arange(S)
    enc_out = encode(cfg, params, frames) if cfg.is_encdec else None
    plan = cfg.layer_plan()
    W = cfg.sliding_window

    def body(carry, slot_params):
        x, aux = carry
        x = constrain(x, ("batch", "seq", None))
        caches = []
        for i, (mixer, ffn) in enumerate(plan):
            sp = slot_params[i]
            if mixer == "attn":
                x, (k, v) = attn_full(
                    cfg, sp["mixer"], x, positions=positions,
                    mrope_pos=mrope_pos, attn_impl=attn_impl,
                )
                if W and S > W:
                    # keep the trailing window, rolled so that absolute
                    # position p lives at index p % W (ring layout)
                    k, v = k[:, -W:], v[:, -W:]
                    shift = jnp.mod(S - W, W)
                    k = jnp.roll(k, shift, axis=1)
                    v = jnp.roll(v, shift, axis=1)
                slot_cache = {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}
                if cfg.is_encdec:
                    xk, xv = _build_xkv(cfg, sp["xattn"], enc_out)
                    x = xattn_full(cfg, sp["xattn"], x, (xk, xv))
                    slot_cache["xk"] = xk.astype(cache_dtype)
                    slot_cache["xv"] = xv.astype(cache_dtype)
            else:
                x, mc = mamba_full(cfg, sp["mixer"], x, return_cache=True)
                slot_cache = {"conv": mc.conv.astype(cache_dtype), "ssm": mc.ssm}
            if ffn == "mlp":
                x = mlp_sublayer(cfg, sp["ffn"], x)
            elif ffn == "moe":
                x, a = moe_sublayer(cfg, sp["ffn"], x)
                aux = aux + a
            caches.append(slot_cache)
        return (x, aux), caches

    (x, _aux), cache = maybe_scan(body, (x, jnp.float32(0.0)), params["blocks"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits, cache


# ======================================================================
# decode
# ======================================================================


def decode_step(
    cfg: ModelConfig,
    params: PyTree,
    cache: PyTree,
    token: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, PyTree]:
    """One decode step.  token: (B, 1) int32; pos: scalar absolute position.

    Returns (logits (B, 1, V), updated cache).  The cache pytree has the
    same structure/shapes as the input (donation-safe).
    """
    B = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = params["embed"].astype(jnp.bfloat16)[token]
    if cfg.scale_embeds:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.is_encdec and cfg.rope_type == "none":
        # learned/sinusoid positions: add each sequence's pos-th row
        row = _sinusoid_row(pos, cfg.d_model).astype(x.dtype)  # (B, d)
        x = x + row[:, None, :]
    plan = cfg.layer_plan()

    def body(x, xs):
        slot_params, cache_in = xs
        x = constrain(x, ("batch", "seq", None))
        new_caches = []
        for i, (mixer, ffn) in enumerate(plan):
            sp, ci = slot_params[i], cache_in[i]
            if mixer == "attn":
                x, upd = attn_decode(
                    cfg, sp["mixer"], x, {"k": ci["k"], "v": ci["v"]}, pos=pos
                )
                if cfg.is_encdec:
                    x = xattn_decode(cfg, sp["xattn"], x, (ci["xk"], ci["xv"]))
                    upd = dict(upd, xk=ci["xk"], xv=ci["xv"])
            else:
                mc = mamba_lib.MambaCache(conv=ci["conv"], ssm=ci["ssm"])
                x, mc = mamba_decode_sub(cfg, sp["mixer"], x, mc)
                upd = {"conv": mc.conv, "ssm": mc.ssm}
            if ffn == "mlp":
                x = mlp_sublayer(cfg, sp["ffn"], x)
            elif ffn == "moe":
                x, _ = moe_sublayer(cfg, sp["ffn"], x)
            new_caches.append(upd)
        return x, new_caches

    x, new_cache = maybe_scan(body, x, (params["blocks"], cache))
    return _logits(cfg, params, x), new_cache


def _sinusoid_row(pos, d_model: int) -> jax.Array:
    """pos (B,) -> (B, d_model) sinusoid embedding rows."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = pos.astype(jnp.float32)[:, None] * freq[None, :]  # (B, half)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ======================================================================
# convenience: init
# ======================================================================


def init(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> PyTree:
    return init_params(param_template(cfg), key, dtype)


def template_structs(cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    return param_structs(param_template(cfg), dtype)
