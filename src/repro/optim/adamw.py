"""AdamW + cosine schedule + global-norm clipping, pure JAX.

State is a pytree mirroring the params (moments shard identically to their
parameters under pjit — the sharding tree is reused leaf-for-leaf).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    mu: PyTree  # first moment
    nu: PyTree  # second moment


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(
    step: jax.Array,
    *,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    min_ratio: float = 0.1,
) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), grads), g


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
