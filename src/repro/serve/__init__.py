from repro.serve.steps import make_decode_step, make_prefill_step  # noqa: F401


def __getattr__(name):
    # lazy: serve.dse / serve.cache pull in the whole search stack;
    # LM-serving users (serve.engine / serve.steps) shouldn't pay that
    if name in ("AsyncDSEService", "DSEService", "RetryPolicy",
                "ServiceStats", "paper_request_mix"):
        from repro.serve import dse

        return getattr(dse, name)
    if name in ("CacheStats", "ResultCache", "request_key"):
        from repro.serve import cache

        return getattr(cache, name)
    raise AttributeError(name)
