"""Fingerprint-keyed request-level result cache for the DSE service.

Millions of users means massive request overlap, and the cheapest
throughput is not launching at all: ``WorkloadSet.fingerprint()`` already
content-keys table packing, and this module extends the same idea to the
full request — ``request_key`` is a sha256 over EVERYTHING that
determines a search's result bits

    (workload fingerprint, tech constants, objective / exponent weights,
     area constraint, backend, pop size, generations, top_k, pareto_k,
     the raw PRNG key bytes, and any explicit init population)

and deliberately over nothing else: ``priority`` and ``deadline_s`` are
scheduling metadata (they reorder launches, never change a result bit —
the same invariant ``SearchRequest.signature()`` pins for program
shapes), and ``SearchRequest.seed`` enters only through the PRNG key
bytes it derives, so ``seed=3`` and ``key=PRNGKey(3)`` are the SAME
cache entry while an explicit ``key=`` override is its own.

``ResultCache`` maps that key to a finalized ``SearchResult`` through
two tiers:

  * an in-memory LRU front (``capacity`` entries, thread-safe — the
    async service's worker and client threads share one instance), and
  * an optional on-disk tier under ``disk_dir/<request_key>`` reusing
    ``checkpoint.store``'s atomic write/commit-marker/scan machinery: a
    crash mid-write never corrupts an entry, a fresh process over the
    same directory serves bit-identical results, and memory evictions
    never touch disk (the disk tier is the larger, durable one).

Only FULL results are cached: ``partial=True`` snapshots (deadline
sweeps, quarantine, mid-search streams) are anytime views of an
unfinished search, never a request's answer.  ``valid=False`` full-budget
results (every design infeasible) ARE cached — re-searching cannot
un-infeasible them.  Thin full results (``ga=None`` — what the pipelined
engine and pareto requests produce) ARE cached too: they round-trip with
an empty-history marker, so ``pipelined=True`` + ``result_cache``
resolves a resubmitted drain with zero GA launches.

Wired in two places (see ``core.engine.SearchEngine(result_cache=)`` and
``serve.dse.DSEService(result_cache=)``): the engine persists per-request
entries as plans complete — keyed independently of chunk-mates, unlike
the checkpoint tier's ``plan_key`` — and the service resolves hits at
submit, so a repeated request costs zero GA launches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core import space
from repro.core.engine import SearchRequest, SearchResult
from repro.core.ga import GAResult

# fixed leaf layout of one serialized entry: jax.tree flattens dicts in
# sorted-key order, so "arrays" (8 leaves, fixed order) precede "meta".
# Thin (ga=None) and non-pareto entries keep the SAME leaf count with
# empty placeholder arrays — the layout never varies per entry, so
# ``checkpoint.store.restore`` always sees one template.
_ARRAY_FIELDS = 8
_TEMPLATE = {"arrays": [0] * _ARRAY_FIELDS, "meta": 0}
_EMPTY = np.zeros((0,), np.float32)


def request_key(req: SearchRequest) -> str:
    """Content key of one request's RESULT (not its program shape).

    Everything that can change a result bit is hashed; scheduling
    metadata (``priority``, ``deadline_s``) is excluded by design — see
    the module docstring.  ``objective`` is hashed even when
    ``obj_weights`` overrides it (conservative: a spurious miss is
    correct, a spurious hit never is).  Two process-level knobs also
    enter the key because they change result bits for identical request
    fields: ``imc.COST_MODEL_VERSION`` (a persisted disk tier must never
    serve entries computed under an older model's math) and
    ``space.grid_token()`` (the active grid density redefines what a
    genome decodes to)."""
    from repro.imc import COST_MODEL_VERSION

    h = hashlib.sha256()
    h.update(COST_MODEL_VERSION.encode())
    h.update(space.grid_token().encode())
    h.update(req.ws.fingerprint().encode())
    h.update(repr((
        req.objective, req.obj_weights, float(req.area_constr),
        req.backend, int(req.pop_size), int(req.generations),
        int(req.top_k), int(req.pareto_k), req.tech,
    )).encode())
    h.update(np.asarray(req.prng_key()).tobytes())
    if req.init_genomes is not None:
        init = np.ascontiguousarray(np.asarray(req.init_genomes, np.float32))
        h.update(repr(init.shape).encode())
        h.update(init.tobytes())
    return h.hexdigest()


def _encode(res: SearchResult) -> dict:
    """SearchResult -> a pytree of numpy leaves ``checkpoint.store`` can
    write (non-array fields ride as a JSON byte leaf).  Thin results
    (``ga is None`` — the pipelined engine's full answers) serialize
    empty placeholder leaves for the history fields and a ``thin`` meta
    flag, so the leaf layout stays fixed; ``objective_vectors`` (pareto
    fronts) rides the same way behind a ``vectors`` flag."""
    thin = res.ga is None
    vecs = res.objective_vectors
    meta = {
        "workload_names": list(res.workload_names),
        "objective": res.objective,
        "valid": bool(res.valid),
        "generations": int(res.generations),
        "thin": thin,
        "vectors": vecs is not None,
    }
    arrays = [
        _EMPTY if thin else np.asarray(res.ga.genomes),
        _EMPTY if thin else np.asarray(res.ga.scores),
        _EMPTY if thin else np.asarray(res.ga.best_genome),
        _EMPTY if thin else np.asarray(res.ga.best_score),
        np.asarray(res.top_scores), np.asarray(res.top_genomes),
        np.asarray(res.convergence),
        _EMPTY if vecs is None else np.asarray(vecs),
    ]
    blob = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    return {"arrays": arrays, "meta": blob}


def _decode(tree: dict) -> SearchResult:
    meta = json.loads(bytes(np.asarray(tree["meta"]).tobytes()).decode())
    g, s, bg, bs, ts, tg, cv, ov = tree["arrays"]
    ga = (
        None if meta.get("thin")
        else GAResult(genomes=g, scores=s, best_genome=bg, best_score=bs)
    )
    # top_designs are a pure function of top_genomes — recomputed, not
    # serialized, so the dict form can never drift from the arrays
    designs: List[Dict[str, float]] = (
        space.design_dicts_from_indices(space.decode_indices_np(np.asarray(tg)))
        if np.asarray(tg).size else []
    )
    return SearchResult(
        workload_names=tuple(meta["workload_names"]),
        objective=meta["objective"],
        ga=ga,
        top_designs=designs,
        top_scores=np.asarray(ts),
        top_genomes=np.asarray(tg),
        convergence=np.asarray(cv),
        valid=bool(meta["valid"]),
        partial=False,
        generations=int(meta["generations"]),
        objective_vectors=np.asarray(ov) if meta.get("vectors") else None,
    )


@dataclasses.dataclass
class CacheStats:
    hits: int = 0          # memory-tier hits
    disk_hits: int = 0     # disk-tier hits (promoted into memory)
    misses: int = 0
    puts: int = 0
    evictions: int = 0     # memory-tier LRU evictions (disk untouched)

    def hit_rate(self) -> float:
        """Fraction of lookups served from EITHER tier (0.0 when no
        lookups yet — a cold cache reports 0, not NaN)."""
        served = self.hits + self.disk_hits
        total = served + self.misses
        return served / total if total else 0.0

    def summary(self) -> Dict[str, Union[int, float]]:
        out: Dict[str, Union[int, float]] = dataclasses.asdict(self)
        out["hit_rate"] = self.hit_rate()
        return out


class ResultCache:
    """Two-tier (LRU memory + optional disk) ``request_key`` -> finalized
    ``SearchResult`` store.  ``get``/``put`` take a ``SearchRequest`` (or
    a precomputed key string); a disk hit is promoted into the memory
    tier.  Thread-safe; disk writes are atomic (``checkpoint.store``)."""

    def __init__(self, capacity: int = 1024,
                 disk_dir: Optional[Union[str, Path]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.disk_dir = None if disk_dir is None else Path(disk_dir)
        self._mem: "OrderedDict[str, SearchResult]" = OrderedDict()
        self._lock = threading.RLock()
        self.stats = CacheStats()

    # ------------------------------------------------------------------ keys
    @staticmethod
    def key(req: SearchRequest) -> str:
        return request_key(req)

    def _as_key(self, req_or_key: Union[SearchRequest, str]) -> str:
        return req_or_key if isinstance(req_or_key, str) else request_key(req_or_key)

    # ----------------------------------------------------------------- tiers
    def get(self, req_or_key: Union[SearchRequest, str]) -> Optional[SearchResult]:
        key = self._as_key(req_or_key)
        with self._lock:
            hit = self._mem.get(key)
            if hit is not None:
                self._mem.move_to_end(key)
                self.stats.hits += 1
                return hit
            res = self._disk_get(key)
            if res is not None:
                self.stats.disk_hits += 1
                self._mem_put(key, res)  # promote
                return res
            self.stats.misses += 1
            return None

    def put(self, req_or_key: Union[SearchRequest, str],
            res: SearchResult) -> bool:
        """Insert a FULL result; ``partial=True`` snapshots are refused
        (returns False) — an anytime snapshot must never shadow the
        request's real answer.  Thin full results (``ga is None``, the
        pipelined engine's complete answers) ARE cached: their top-k /
        convergence / vector fields are the whole deliverable, and the
        history was never materialized to begin with."""
        if res.partial:
            return False
        key = self._as_key(req_or_key)
        with self._lock:
            self.stats.puts += 1
            self._mem_put(key, res)
            self._disk_put(key, res)
        return True

    def _mem_put(self, key: str, res: SearchResult) -> None:
        self._mem[key] = res
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.stats.evictions += 1

    # ------------------------------------------------------------- disk tier
    def _disk_get(self, key: str) -> Optional[SearchResult]:
        if self.disk_dir is None:
            return None
        from repro.checkpoint import store

        d = self.disk_dir / key
        if store.latest_step(d) is None:
            return None
        tree, _ = store.restore(d, _TEMPLATE)
        return _decode(tree)

    def _disk_put(self, key: str, res: SearchResult) -> None:
        if self.disk_dir is None:
            return
        from repro.checkpoint import store

        d = self.disk_dir / key
        if store.latest_step(d) is not None:
            return  # content-keyed: an existing committed entry is this one
        store.save(d, 0, _encode(res))

    # ------------------------------------------------------------ inspection
    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def __contains__(self, req_or_key) -> bool:
        key = self._as_key(req_or_key)
        with self._lock:
            if key in self._mem:
                return True
        return self._disk_get(key) is not None if self.disk_dir else False

    def mem_keys(self) -> List[str]:
        """Memory-tier keys, LRU-first (next-to-evict first)."""
        with self._lock:
            return list(self._mem)

    def disk_keys(self) -> List[str]:
        """Committed disk-tier keys (``checkpoint.store.scan``)."""
        if self.disk_dir is None:
            return []
        from repro.checkpoint import store

        return store.scan(self.disk_dir)

    def clear(self, *, disk: bool = False) -> None:
        """Drop the memory tier; ``disk=True`` also removes every
        committed disk entry (explicit — eviction never implies it)."""
        with self._lock:
            self._mem.clear()
            if disk and self.disk_dir is not None:
                from repro.checkpoint import store

                for key in store.scan(self.disk_dir):
                    store.clear(self.disk_dir / key)
