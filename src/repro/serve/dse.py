"""DSE-as-a-service: continuous batching of heterogeneous search requests.

The design-search twin of ``serve.engine`` (which continuous-batches LM
prefill/decode into fixed slots): clients ``submit`` ``SearchRequest``s —
any mix of workload sets, objectives, areas, seeds and backends — and the
service drains the queue slot-packed into as few XLA launches as possible
through the shared ``core.engine.SearchEngine``:

  * ``submit``  — enqueue a request, returns a request id.  Table-backend
    requests get their factorized cost tables built (fingerprint-memoized)
    at ingest, the way the LM engine prefills on admission — the drain
    itself then launches only the cached seeding + GA programs.
  * ``step``    — execute ONE plan (one XLA launch) of the current queue;
    finished results free their slots immediately and newly submitted
    requests join the next step's packing.
  * ``drain``   — step until the queue is empty; returns {rid: result}.
  * ``stream``  — generator form of drain: yields (rid, SearchResult) per
    completed plan, so callers consume results while later plans run.

Scheduling is policy-driven (``core.engine.SchedulingPolicy``): ``fifo``
(submit order), ``priority`` (``SearchRequest.priority``, 0 = most
urgent, with wait-time aging so nothing starves) or ``edf``
(``SearchRequest.deadline_s`` seconds-from-submit, converted to an
absolute deadline on the service clock at ingest).  The policy reorders
the queue and the launch order; it never changes which compiled program
a request hits, nor any result bit (every search is self-contained).

``AsyncDSEService`` runs the same service behind a worker thread:
``submit`` returns a ``concurrent.futures.Future`` immediately, requests
submitted while a launch is in flight join the NEXT launch's packing
(the dispatch/complete split below holds the lock only around queue
surgery, never around ``engine.execute``), and an urgent submission
therefore preempts all still-queued work at the next launch boundary.

Because the ``table`` backend's traced ctx is layer-free, requests over
*different* workload sets share one compiled program: 256 mixed requests
(subsets x objectives x seeds) drain through 4 launches of 2 cached
programs, bit-identical to running each request alone
(tests/test_engine.py).  ``mesh=`` lays every launch over the 2-D
(search, population) device mesh.

``ServiceStats`` tracks busy time plus per-request queue-wait and
end-to-end latency samples (the telemetry deadline policies need) and
deadline misses; ``tests/sim_scheduler.py`` drives all of the above
against a virtual clock and a stub engine, so every scheduling claim is
asserted without an XLA launch.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Deque, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import (
    BatchPlan,
    RequestMeta,
    SearchEngine,
    SearchRequest,
    SearchResult,
    get_policy,
    plan_batch,
)
from repro.core.objectives import OBJECTIVES
from repro.workloads.pack import WorkloadSet


def _percentile(samples: Sequence[float], q: float) -> float:
    if not samples:
        return float("nan")
    return float(np.percentile(np.asarray(samples, np.float64), q))


# Per-request samples kept for percentile telemetry: a bounded recent
# window (deque maxlen), so a long-lived service's memory stays O(1) and
# the percentiles describe recent traffic rather than all-time history.
SAMPLE_WINDOW = 4096
LAUNCH_LOG_WINDOW = 4096


@dataclasses.dataclass
class ServiceStats:
    """Running drain telemetry (the bench's requests/s row reads these).

    ``busy_s`` is wall time inside ``engine.execute`` only —
    ``requests_per_s`` is therefore a BUSY throughput, not an end-to-end
    one.  ``wait_samples`` (dispatch - submit) and ``latency_samples``
    (complete - submit) are per-request, on the service clock, bounded
    to the most recent ``SAMPLE_WINDOW`` completions, so
    ``wait_p``/``latency_p`` percentiles describe what clients recently
    experienced; ``deadline_misses`` counts requests completed after
    their absolute deadline (any policy — EDF just minimizes it).
    After an engine failure ``submitted`` stays ahead of ``completed``:
    failed requests are never counted as served."""

    submitted: int = 0
    completed: int = 0
    launches: int = 0
    busy_s: float = 0.0  # wall time spent inside execute()
    deadline_misses: int = 0
    wait_samples: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=SAMPLE_WINDOW))
    latency_samples: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=SAMPLE_WINDOW))

    def requests_per_s(self) -> float:
        return self.completed / self.busy_s if self.busy_s > 0 else 0.0

    def wait_p(self, q: float) -> float:
        """Queue-wait percentile in seconds (q in [0, 100])."""
        return _percentile(self.wait_samples, q)

    def latency_p(self, q: float) -> float:
        """End-to-end (submit -> complete) latency percentile in seconds."""
        return _percentile(self.latency_samples, q)

    def summary(self) -> Dict[str, float]:
        return {
            "requests_per_s": self.requests_per_s(),
            "wait_p50_s": self.wait_p(50), "wait_p99_s": self.wait_p(99),
            "latency_p50_s": self.latency_p(50),
            "latency_p99_s": self.latency_p(99),
            "deadline_misses": self.deadline_misses,
        }


class DSEService:
    """Continuous-batching front end over a ``SearchEngine``.

    ``policy`` is a name (fifo / priority / edf) or a
    ``SchedulingPolicy`` instance; ``clock`` (default ``time.monotonic``)
    is the ONLY time source — submit stamps, waits, deadlines and busy
    time all read it, so a virtual clock makes every scheduling decision
    and every stat deterministic (tests/sim_scheduler.py)."""

    def __init__(
        self,
        *,
        engine: Optional[SearchEngine] = None,
        mesh=None,
        max_slots: int = 64,
        policy="fifo",
        clock=time.monotonic,
    ):
        self.engine = engine or SearchEngine(mesh=mesh, max_slots=max_slots)
        self.policy = get_policy(policy)
        self.clock = clock
        self.queue: List[Tuple[int, SearchRequest]] = []
        self.results: Dict[int, SearchResult] = {}
        self.stats = ServiceStats()
        self.launch_log: List[List[int]] = []  # rids per launch, in order
        self._next_rid = 0
        # per-rid queue facts: submit stamp + absolute deadline (clock() +
        # SearchRequest.deadline_s at ingest) — what the policy keys on
        self._submit_s: Dict[int, float] = {}
        self._deadline_s: Dict[int, Optional[float]] = {}
        # signature -> slot size of the last plan that used it: re-plans
        # (mid-drain submits) round small residues UP to this warm program
        # size instead of compiling an exact-size one
        self._slot_hints: Dict[tuple, int] = {}
        # plans for the current queue snapshot; invalidated on submit so
        # a quiescent drain keeps plan_batch's padded-tail chunking (every
        # chunk of a group = ONE compiled program) instead of re-planning
        # the shrunken residue into a fresh program shape each step
        self._plans_cache: Optional[List[BatchPlan]] = None
        self._snapshot: List[Tuple[int, SearchRequest]] = []

    # ------------------------------------------------------------- admission
    def submit(self, req: SearchRequest) -> int:
        """Enqueue one request; returns its rid.  Validates the request's
        signature eagerly (bad objectives/backends fail at submit, not
        mid-drain) and pre-builds table-backend cost tables so drains only
        launch the cached seeding/GA programs."""
        req.signature()
        if req.backend == "table":
            req.ws.tables(req.tech)  # fingerprint-memoized ingest prefill
        now = self.clock()
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, req))
        self._submit_s[rid] = now
        self._deadline_s[rid] = (
            None if req.deadline_s is None else now + float(req.deadline_s)
        )
        self.stats.submitted += 1
        self._plans_cache = None  # next step re-packs the grown queue
        return rid

    def submit_all(self, reqs: Sequence[SearchRequest]) -> List[int]:
        return [self.submit(r) for r in reqs]

    def pending(self) -> int:
        return len(self.queue)

    # --------------------------------------------------------------- serving
    def _plans(self) -> List[BatchPlan]:
        """Plans over the current queue snapshot, cached across steps: a
        drain executes the ONE padded chunking plan_batch produced (plan
        indices refer to the snapshot), and only a new submission forces
        a re-pack — where the slot hints keep re-planned residues on the
        warm program shapes."""
        if self._plans_cache is None:
            now = self.clock()
            self._snapshot = list(self.queue)
            meta = [
                RequestMeta(
                    seq=rid,
                    priority=int(r.priority),
                    wait_s=now - self._submit_s[rid],
                    deadline_s=self._deadline_s[rid],
                )
                for rid, r in self._snapshot
            ]
            self._plans_cache = plan_batch(
                [r for _, r in self._snapshot],
                max_slots=self.engine.max_slots,
                policy=self.policy,
                meta=meta,
                slot_hints=self._slot_hints,
            )
            for p in self._plans_cache:
                self._slot_hints[p.signature] = p.slots
        return self._plans_cache

    def _dispatch(self) -> Optional[Tuple[BatchPlan, List[int], float]]:
        """Pick the policy's next plan and remove its requests from the
        queue — the admission point: everything still queued after this
        (including anything submitted while the launch runs) is free to
        re-plan.  Returns (plan, rids, dispatch stamp); pure queue
        surgery, no device work, so the async front end holds its lock
        only across this and ``_complete``."""
        if not self.queue:
            return None
        plans = self._plans()
        plan = plans.pop(0)
        if not plans:
            self._plans_cache = None
        rids = [self._snapshot[qi][0] for qi in plan.indices]
        taken = set(rids)
        self.queue = [q for q in self.queue if q[0] not in taken]
        now = self.clock()
        for rid in rids:
            self.stats.wait_samples.append(now - self._submit_s[rid])
        return plan, rids, now

    def _drop_wait_samples(self, n: int) -> None:
        for _ in range(min(n, len(self.stats.wait_samples))):
            self.stats.wait_samples.pop()  # newest = this dispatch's

    def _rollback(self, plan: BatchPlan, rids: List[int]) -> None:
        """Undo a dispatch whose launch failed (sync path): the requests
        return to the queue with their original submit stamps intact —
        ``step()`` stays retryable — and the dispatch's wait samples are
        dropped (the requests were never served)."""
        self._drop_wait_samples(len(rids))
        self.queue = list(zip(rids, plan.requests)) + self.queue
        self._plans_cache = None  # the popped plan list is now stale

    def _abandon(self, rids: List[int]) -> None:
        """Drop failed in-flight requests for good (async path: their
        futures carry the exception): purge per-rid bookkeeping so a
        long-lived worker that survives engine failures leaks nothing
        and keeps wait/latency sample counts consistent."""
        self._drop_wait_samples(len(rids))
        for rid in rids:
            self._submit_s.pop(rid, None)
            self._deadline_s.pop(rid, None)

    def _complete(
        self, rids: List[int], results: Sequence[SearchResult], busy_s: float
    ) -> List[Tuple[int, SearchResult]]:
        """Record one finished launch: results, latency/deadline stats."""
        now = self.clock()
        self.stats.busy_s += busy_s
        self.stats.launches += 1
        self.launch_log.append(list(rids))
        if len(self.launch_log) > LAUNCH_LOG_WINDOW:
            del self.launch_log[: len(self.launch_log) - LAUNCH_LOG_WINDOW]
        done: List[Tuple[int, SearchResult]] = []
        for rid, res in zip(rids, results):
            self.results[rid] = res
            self.stats.latency_samples.append(now - self._submit_s[rid])
            dl = self._deadline_s.pop(rid, None)
            self._submit_s.pop(rid, None)
            if dl is not None and now > dl:
                self.stats.deadline_misses += 1
            done.append((rid, res))
        self.stats.completed += len(done)
        return done

    def step(self) -> List[Tuple[int, SearchResult]]:
        """Run ONE slot-packed launch (the policy's most urgent plan of
        the current queue); returns that plan's (rid, result) pairs.
        Requests submitted while a step runs simply join the next plan."""
        d = self._dispatch()
        if d is None:
            return []
        plan, rids, t0 = d
        try:
            results = self.engine.execute(plan)
        except BaseException:
            self._rollback(plan, rids)  # step() stays retryable
            raise
        return self._complete(rids, results, self.clock() - t0)

    def stream(self) -> Iterator[Tuple[int, SearchResult]]:
        """Drain, yielding each plan's results as soon as its launch
        finishes — callers overlap their own post-processing with the
        remaining launches."""
        while self.queue:
            yield from self.step()

    def drain(self) -> Dict[int, SearchResult]:
        """Run the whole queue; returns {rid: SearchResult} for every
        request ever completed (incl. prior drains)."""
        for _ in self.stream():
            pass
        return self.results


class AsyncDSEService:
    """Non-blocking front end: a worker thread drains a ``DSEService``.

    ``submit`` enqueues and returns a ``concurrent.futures.Future``
    immediately — it never waits on a launch in flight, because the
    worker holds the service lock only around ``_dispatch``/``_complete``
    (queue surgery), never around ``engine.execute``.  A request
    submitted mid-launch therefore joins the NEXT launch's packing, and
    under the priority/edf policies an urgent submission preempts every
    still-queued request at that boundary (the re-plan runs on warm
    program shapes via the service's slot hints — 0 new compiled
    programs).

    Future results are ``SearchResult``s, bit-identical to a synchronous
    ``DSEService`` drain of the same requests: scheduling only reorders
    self-contained searches.  Futures resolve on the worker thread, so a
    done-callback runs BEFORE the next dispatch — a deterministic hook
    for reacting mid-drain (the integration test submits its priority-0
    jump there).  ``paused=True`` admits submissions without launching
    until ``resume()`` — batch admission with a deterministic first plan.
    Use as a context manager, or call ``close()``."""

    def __init__(
        self,
        *,
        engine: Optional[SearchEngine] = None,
        mesh=None,
        max_slots: int = 64,
        policy="fifo",
        clock=time.monotonic,
        paused: bool = False,
    ):
        self.service = DSEService(
            engine=engine, mesh=mesh, max_slots=max_slots, policy=policy,
            clock=clock,
        )
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._run = threading.Event()
        if not paused:
            self._run.set()
        self._futures: Dict[int, Future] = {}
        self._closed = False
        self._worker = threading.Thread(
            target=self._loop, name="dse-service", daemon=True
        )
        self._worker.start()

    @property
    def stats(self) -> ServiceStats:
        return self.service.stats

    @property
    def launch_log(self) -> List[List[int]]:
        return self.service.launch_log

    # ------------------------------------------------------------- admission
    def submit(self, req: SearchRequest) -> Future:
        """Enqueue; returns a Future resolving to the SearchResult.
        Never blocks on device work — at most the queue lock."""
        with self._lock:
            if self._closed:
                raise RuntimeError("AsyncDSEService is closed")
            rid = self.service.submit(req)
            fut: Future = Future()
            fut.rid = rid  # type: ignore[attr-defined]
            self._futures[rid] = fut
            self._idle.clear()
        self._wake.set()
        return fut

    def submit_all(self, reqs: Sequence[SearchRequest]) -> List[Future]:
        return [self.submit(r) for r in reqs]

    def pause(self):
        """Stop launching at the next launch boundary (in-flight work
        finishes); submissions keep queueing."""
        self._run.clear()

    def resume(self):
        self._run.set()
        self._wake.set()

    # --------------------------------------------------------------- serving
    def _loop(self):
        while True:
            self._wake.wait()
            self._run.wait()
            with self._lock:
                if self._closed:
                    return
                d = self.service._dispatch()
                if d is None:
                    self._wake.clear()
                    if not self._futures:
                        self._idle.set()
                    continue
                plan, rids, t0 = d
            # the launch runs WITHOUT the lock: submits land concurrently
            # and join the next dispatch's re-plan
            try:
                results = self.service.engine.execute(plan)
            except BaseException as e:  # noqa: BLE001 — fail the futures, keep serving
                with self._lock:
                    self.service._abandon(rids)
                    failed = [self._futures.pop(rid, None) for rid in rids]
                # exceptions set OUTSIDE the lock: done-callbacks fire on
                # failure too, and they may submit (which takes the lock)
                for f in failed:
                    if f is not None:
                        f.set_exception(e)
                continue
            with self._lock:
                done = self.service._complete(
                    rids, results, self.service.clock() - t0
                )
                futs = [(self._futures.pop(rid, None), res) for rid, res in done]
            # resolve OUTSIDE the lock: done-callbacks may submit
            for f, res in futs:
                if f is not None:
                    f.set_result(res)

    def drain(self, timeout: Optional[float] = None) -> Dict[int, SearchResult]:
        """Block until the queue and all in-flight launches are done;
        returns the service's full {rid: result} map."""
        if not self._idle.wait(timeout):
            raise TimeoutError(
                f"drain timed out with {self.service.pending()} queued"
            )
        return self.service.results

    def close(self):
        """Finish in-flight work, then stop the worker."""
        if self._run.is_set():
            self.drain()
        with self._lock:
            self._closed = True
        self._run.set()
        self._wake.set()
        self._worker.join()
        for f in self._futures.values():  # paused close: never launched
            f.cancel()
        self._futures.clear()

    def __enter__(self) -> "AsyncDSEService":
        return self

    def __exit__(self, *exc):
        self.close()


def paper_request_mix(
    ws: WorkloadSet,
    n: int,
    *,
    backend: str = "table",
    pop_size: int = 40,
    generations: int = 10,
    area_constr: float = 150.0,
    seed0: int = 0,
    priorities: Optional[Sequence[int]] = None,
    deadlines_s: Optional[Sequence[Optional[float]]] = None,
) -> List[SearchRequest]:
    """N heterogeneous requests over ``ws``: cycles through workload
    subsets (full set, singles, pairs) x objective kinds x seeds — the
    service's canonical mixed traffic (bench_dse_service, the CI
    serve-smoke leg, ``launch.search --serve``).  ``priorities`` /
    ``deadlines_s`` cycle the same way, for mixed-priority / EDF
    traffic (the async smoke + scheduler tests)."""
    W = ws.n
    subsets = [tuple(range(W))]
    subsets += [(i,) for i in range(W)]
    subsets += [(i, (i + 1) % W) for i in range(W)] if W > 1 else []
    return [
        SearchRequest(
            ws=ws.subset(list(subsets[i % len(subsets)])),
            objective=OBJECTIVES[i % len(OBJECTIVES)],
            area_constr=area_constr,
            seed=seed0 + i,
            backend=backend,
            pop_size=pop_size,
            generations=generations,
            priority=0 if priorities is None else int(priorities[i % len(priorities)]),
            deadline_s=None if deadlines_s is None
            else deadlines_s[i % len(deadlines_s)],
        )
        for i in range(n)
    ]
