"""DSE-as-a-service: continuous batching of heterogeneous search requests.

The design-search twin of ``serve.engine`` (which continuous-batches LM
prefill/decode into fixed slots): clients ``submit`` ``SearchRequest``s —
any mix of workload sets, objectives, areas, seeds and backends — and the
service drains the queue slot-packed into as few XLA launches as possible
through the shared ``core.engine.SearchEngine``:

  * ``submit``  — enqueue a request, returns a request id.  Table-backend
    requests get their factorized cost tables built (fingerprint-memoized)
    at ingest, the way the LM engine prefills on admission — the drain
    itself then launches only the cached seeding + GA programs.
  * ``step``    — execute ONE plan (one XLA launch) of the current queue;
    finished results free their slots immediately and newly submitted
    requests join the next step's packing.
  * ``drain``   — step until the queue is empty; returns {rid: result}.
  * ``stream``  — generator form of drain: yields (rid, SearchResult) per
    completed plan, so callers consume results while later plans run.

Scheduling is policy-driven (``core.engine.SchedulingPolicy``): ``fifo``
(submit order), ``priority`` (``SearchRequest.priority``, 0 = most
urgent, with wait-time aging so nothing starves) or ``edf``
(``SearchRequest.deadline_s`` seconds-from-submit, converted to an
absolute deadline on the service clock at ingest).  The policy reorders
the queue and the launch order; it never changes which compiled program
a request hits, nor any result bit (every search is self-contained).

``AsyncDSEService`` runs the same service behind a worker thread:
``submit`` returns a ``concurrent.futures.Future`` immediately, requests
submitted while a launch is in flight join the NEXT launch's packing
(the dispatch/complete split below holds the lock only around queue
surgery, never around ``engine.execute``), and an urgent submission
therefore preempts all still-queued work at the next launch boundary.

Because the ``table`` backend's traced ctx is layer-free, requests over
*different* workload sets share one compiled program: 256 mixed requests
(subsets x objectives x seeds) drain through 4 launches of 2 cached
programs, bit-identical to running each request alone
(tests/test_engine.py).  ``mesh=`` lays every launch over the 2-D
(search, population) device mesh.

``ServiceStats`` tracks busy time plus per-request queue-wait and
end-to-end latency samples (the telemetry deadline policies need) and
deadline misses; ``tests/sim_scheduler.py`` drives all of the above
against a virtual clock and a stub engine, so every scheduling claim is
asserted without an XLA launch.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import (
    Callable,
    Deque,
    Dict,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from repro.core.engine import (
    BatchPlan,
    EngineFault,
    RequestMeta,
    SearchEngine,
    SearchRequest,
    SearchResult,
    empty_partial_result,
    get_policy,
    plan_batch,
)
from repro.core.objectives import OBJECTIVES
from repro.workloads.pack import WorkloadSet


def _percentile(samples: Sequence[float], q: float) -> Optional[float]:
    # None, not NaN, on an empty window: NaN is invalid JSON and poisons
    # any bench row serializing a fresh service's summary()
    if not samples:
        return None
    return float(np.percentile(np.asarray(samples, np.float64), q))


# Per-request samples kept for percentile telemetry: a bounded recent
# window (deque maxlen), so a long-lived service's memory stays O(1) and
# the percentiles describe recent traffic rather than all-time history.
SAMPLE_WINDOW = 4096
LAUNCH_LOG_WINDOW = 4096


@dataclasses.dataclass
class ServiceStats:
    """Running drain telemetry (the bench's requests/s row reads these).

    ``busy_s`` is wall time inside ``engine.execute`` only —
    ``requests_per_s`` is therefore a BUSY throughput, not an end-to-end
    one.  ``wait_samples`` (dispatch - submit) and ``latency_samples``
    (complete - submit) are per-request, on the service clock, bounded
    to the most recent ``SAMPLE_WINDOW`` completions, so
    ``wait_p``/``latency_p`` percentiles describe what clients recently
    experienced; ``deadline_misses`` counts requests completed after
    their absolute deadline (any policy — EDF just minimizes it).
    After an engine failure ``submitted`` stays ahead of ``completed``:
    failed requests are never counted as served.

    Fault telemetry: ``failures`` counts failed request-attempts (every
    rid in a failed launch, once per failed attempt), ``retries`` the
    re-queues a ``RetryPolicy`` scheduled, ``partials`` the requests
    resolved with an anytime ``partial=True`` result (quarantine or
    deadline sweep — these DO count as completed), and ``abandoned`` the
    requests dropped for good with no result (no retry policy / retries
    exhausted without partial results).

    ``cache_hits`` counts requests resolved AT SUBMIT from the result
    cache (zero launches; they count as completed with 0 wait/latency);
    ``cache_misses`` the submits that had a cache and missed it.
    ``cache_hit_rate()`` is hits over looked-up submits (0.0 before any
    lookup) — the service-level view of the cache's own
    ``CacheStats.hit_rate()``, which additionally distinguishes the
    memory and disk tiers.

    Launch-overlap telemetry (the pipelined drain's effectiveness):
    ``dispatch_gap_samples`` records, per launch, how long the dispatched
    device work waited before its harvest started (harvest start -
    dispatch end; always 0 on the sequential path, where execute syncs
    inline), and ``device_idle_s`` accumulates an ESTIMATE of wall time
    with nothing in flight between one harvest finishing and the next
    dispatch starting — the overlap win shows up as near-zero idle while
    the gap stays small.

    Percentiles over empty sample windows are ``None`` (a fresh service
    has no telemetry) — never NaN, which is invalid JSON and poisons
    serialized bench rows."""

    submitted: int = 0
    completed: int = 0
    launches: int = 0
    busy_s: float = 0.0  # wall time spent inside execute()
    deadline_misses: int = 0
    failures: int = 0
    retries: int = 0
    partials: int = 0
    abandoned: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    wait_samples: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=SAMPLE_WINDOW))
    latency_samples: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=SAMPLE_WINDOW))
    dispatch_gap_samples: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=SAMPLE_WINDOW))
    device_idle_s: float = 0.0

    def requests_per_s(self) -> float:
        return self.completed / self.busy_s if self.busy_s > 0 else 0.0

    def wait_p(self, q: float) -> Optional[float]:
        """Queue-wait percentile in seconds (q in [0, 100]); ``None``
        when the sample window is empty."""
        return _percentile(self.wait_samples, q)

    def latency_p(self, q: float) -> Optional[float]:
        """End-to-end (submit -> complete) latency percentile in
        seconds; ``None`` when the sample window is empty."""
        return _percentile(self.latency_samples, q)

    def cache_hit_rate(self) -> float:
        """Fraction of cache-looked-up submits resolved at submit (0.0
        before any lookup — a cacheless or cold service reports 0)."""
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def dispatch_gap_p(self, q: float) -> Optional[float]:
        """Dispatch-end -> harvest-start gap percentile in seconds;
        ``None`` before any launch was harvested."""
        return _percentile(self.dispatch_gap_samples, q)

    def summary(self) -> Dict[str, Optional[float]]:
        return {
            "requests_per_s": self.requests_per_s(),
            "wait_p50_s": self.wait_p(50), "wait_p99_s": self.wait_p(99),
            "latency_p50_s": self.latency_p(50),
            "latency_p99_s": self.latency_p(99),
            "deadline_misses": self.deadline_misses,
            "failures": self.failures,
            "retries": self.retries,
            "partials": self.partials,
            "abandoned": self.abandoned,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hit_rate(),
            "dispatch_gap_p50_s": self.dispatch_gap_p(50),
            "device_idle_s": self.device_idle_s,
        }


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter on the SERVICE clock.

    ``max_attempts`` is the TOTAL launch attempts a request gets (so
    ``max_attempts=3`` means the original try plus 2 retries); after the
    n-th failure the retry is scheduled ``delay_s(n, rid)`` seconds out.
    Jitter is a pure hash of (rid, attempt) — no wall-clock entropy — so
    a scripted fault drill replays to the exact same schedule."""

    max_attempts: int = 3
    backoff_s: float = 0.5
    multiplier: float = 2.0
    max_backoff_s: float = 30.0
    jitter: float = 0.1  # +/- fraction of the base delay

    def delay_s(self, attempt: int, rid: int = 0) -> float:
        base = min(self.backoff_s * self.multiplier ** (max(attempt, 1) - 1),
                   self.max_backoff_s)
        if self.jitter <= 0 or base <= 0:
            return base
        u = ((rid * 2654435761 + attempt * 40503) % 4096) / 4096.0
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))


@dataclasses.dataclass
class _Retry:
    """One queued retry: dispatched alone (re-plan isolation) once the
    service clock passes ``not_before``."""

    not_before: float
    rid: int
    req: SearchRequest
    attempts: int  # failed attempts so far


class DSEService:
    """Continuous-batching front end over a ``SearchEngine``.

    ``policy`` is a name (fifo / priority / edf) or a
    ``SchedulingPolicy`` instance; ``clock`` (default ``time.monotonic``)
    is the ONLY time source — submit stamps, waits, deadlines and busy
    time all read it, so a virtual clock makes every scheduling decision
    and every stat deterministic (tests/sim_scheduler.py).

    Fault tolerance (both OFF by default — behaviour is then exactly the
    pre-retry service: sync ``step()`` rolls back and re-raises, the
    async worker fails futures):

      * ``retry`` (a ``RetryPolicy``): a failed launch re-queues each of
        its requests into an isolated retry lane — every retry is
        re-planned ALONE, so one poisoned request stops failing its
        chunk-mates — with exponential backoff on the service clock.  A
        request that exhausts ``max_attempts`` is quarantined: resolved
        with its best-so-far partial result (``partial_results=True``) or
        abandoned into ``self.failed``.
      * ``partial_results=True``: graceful degradation — a quarantined
        request, and any queued request observed past its deadline,
        resolves with its checkpointed/anytime best (``partial=True``,
        ``EngineFault.partials`` or an empty invalid result) instead of
        nothing.
      * ``sleep`` (default ``time.sleep``): how ``drain``/``stream`` wait
        out retry backoff; the sim passes the virtual clock's ``advance``.

    Result caching (``result_cache``, a ``serve.cache.ResultCache``): a
    submit whose ``request_key`` is cached resolves IMMEDIATELY — the
    request never queues, never launches, and counts as completed with 0
    wait/latency (``stats.cache_hits``).  Misses populate the cache at
    ``_complete`` (full results only; partials never enter), so
    re-submitting an identical mix drains with zero new GA launches and
    bit-identical results.  When the engine was built by this service
    the cache is shared with it; an explicitly passed engine keeps its
    own ``result_cache`` (and the service adopts it if not given one).

    ``pipelined=True`` drains multi-plan queues double-buffered: each
    ``stream``/``drain`` iteration DISPATCHES plan i+1 (JAX async — the
    device starts computing) before HARVESTING plan i (the host-blocking
    finalize), so host packing of one launch overlaps device compute of
    the next.  Results are bit-identical to the sequential drain — only
    the launch interleaving changes — but results carry ``ga=None``
    (transfer-thin; see ``SearchEngine``), so a shared result cache
    stores cache-hits from sequential runs only.  The knob is inherited
    from an explicitly passed engine's own ``pipelined`` flag when left
    ``None``, and silently falls back to the sequential drain on engines
    without the dispatch/harvest split (stubs, fault wrappers).
    """

    def __init__(
        self,
        *,
        engine: Optional[SearchEngine] = None,
        mesh=None,
        max_slots: int = 64,
        policy="fifo",
        clock=time.monotonic,
        retry: Optional[RetryPolicy] = None,
        partial_results: bool = False,
        sleep=None,
        result_cache=None,
        pipelined: Optional[bool] = None,
    ):
        self.engine = engine or SearchEngine(mesh=mesh, max_slots=max_slots,
                                             result_cache=result_cache,
                                             pipelined=bool(pipelined))
        if pipelined is None:
            self.pipelined = bool(getattr(self.engine, "pipelined", False))
        else:
            self.pipelined = bool(pipelined)
        # stub/wrapper engines (sim FakeEngine, fault injectors) have no
        # dispatch/harvest split — they drain sequentially regardless
        self._can_pipeline = (hasattr(self.engine, "dispatch")
                              and hasattr(self.engine, "harvest"))
        # overlap telemetry: launches currently dispatched-not-harvested,
        # and when the device last went quiet (None = never launched)
        self._inflight = 0
        self._last_harvest_end: Optional[float] = None
        self.result_cache = (
            result_cache if result_cache is not None
            else getattr(self.engine, "result_cache", None)
        )
        self.policy = get_policy(policy)
        self.clock = clock
        # wall-clock aging horizon (PriorityPolicy only): a cached plan
        # list is ordered by priorities computed at build time, so once
        # ``aging_s`` passes, some queued request has earned a promotion
        # the cache cannot reflect — ``_dispatch`` invalidates and
        # re-plans (on the warm slot hints: zero new compiled programs).
        # Without this, aging only applied when a submit happened to
        # land, and a busy drain could starve an aged request forever.
        self._aging_s: Optional[float] = getattr(self.policy, "aging_s", None)
        self._plans_built_s: float = 0.0
        self.retry = retry
        self.partial_results = bool(partial_results)
        self._sleep = time.sleep if sleep is None else sleep
        # retry lane + per-rid fault bookkeeping
        self._retry_lane: List[_Retry] = []
        self._attempts: Dict[int, int] = {}
        self._partials: Dict[int, SearchResult] = {}  # best-so-far per rid
        self.failed: Dict[int, BaseException] = {}  # quarantined, no result
        self.queue: List[Tuple[int, SearchRequest]] = []
        self.results: Dict[int, SearchResult] = {}
        self.stats = ServiceStats()
        self.launch_log: List[List[int]] = []  # rids per launch, in order
        self._next_rid = 0
        # per-rid queue facts: submit stamp + absolute deadline (clock() +
        # SearchRequest.deadline_s at ingest) — what the policy keys on
        self._submit_s: Dict[int, float] = {}
        self._deadline_s: Dict[int, Optional[float]] = {}
        # signature -> slot size of the last plan that used it: re-plans
        # (mid-drain submits) round small residues UP to this warm program
        # size instead of compiling an exact-size one
        self._slot_hints: Dict[tuple, int] = {}
        # plans for the current queue snapshot; invalidated on submit so
        # a quiescent drain keeps plan_batch's padded-tail chunking (every
        # chunk of a group = ONE compiled program) instead of re-planning
        # the shrunken residue into a fresh program shape each step
        self._plans_cache: Optional[List[BatchPlan]] = None
        self._snapshot: List[Tuple[int, SearchRequest]] = []
        # mid-search best-so-far stream subscribers, per rid
        self._progress_cbs: Dict[int, Callable] = {}

    # ------------------------------------------------------------- admission
    def submit(self, req: SearchRequest, *, on_progress=None) -> int:
        """Enqueue one request; returns its rid.  Validates the request's
        signature eagerly (bad objectives/backends fail at submit, not
        mid-drain) and pre-builds table-backend cost tables so drains only
        launch the cached seeding/GA programs.

        A result-cache hit resolves the rid right here: the result is in
        ``self.results`` before ``submit`` returns, nothing queues, and
        no launch ever runs for it.

        ``on_progress(rid, partial)`` subscribes to the request's
        mid-search best-so-far stream: called after every guarded GA
        segment with a monotone ``partial=True`` snapshot (requires an
        engine with ``segment_gens``; single-shot engines have no
        mid-search boundaries and never call it).  Callbacks run on the
        draining thread, between segment launches."""
        req.signature()
        if self.result_cache is not None:
            hit = self.result_cache.get(req)
            if hit is not None:
                rid = self._next_rid
                self._next_rid += 1
                self.results[rid] = hit
                self.stats.submitted += 1
                self.stats.completed += 1
                self.stats.cache_hits += 1
                self.stats.wait_samples.append(0.0)
                self.stats.latency_samples.append(0.0)
                return rid
            self.stats.cache_misses += 1
        if req.backend == "table":
            req.ws.tables(req.tech)  # fingerprint-memoized ingest prefill
        now = self.clock()
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, req))
        self._submit_s[rid] = now
        self._deadline_s[rid] = (
            None if req.deadline_s is None else now + float(req.deadline_s)
        )
        if on_progress is not None:
            self._progress_cbs[rid] = on_progress
        self.stats.submitted += 1
        self._plans_cache = None  # next step re-packs the grown queue
        return rid

    def submit_all(self, reqs: Sequence[SearchRequest]) -> List[int]:
        return [self.submit(r) for r in reqs]

    def pending(self) -> int:
        return len(self.queue) + len(self._retry_lane)

    # --------------------------------------------------------------- serving
    def _plans(self) -> List[BatchPlan]:
        """Plans over the current queue snapshot, cached across steps: a
        drain executes the ONE padded chunking plan_batch produced (plan
        indices refer to the snapshot), and only a new submission forces
        a re-pack — where the slot hints keep re-planned residues on the
        warm program shapes."""
        if self._plans_cache is None:
            now = self.clock()
            self._plans_built_s = now
            self._snapshot = list(self.queue)
            meta = [
                RequestMeta(
                    seq=rid,
                    priority=int(r.priority),
                    wait_s=now - self._submit_s[rid],
                    deadline_s=self._deadline_s[rid],
                )
                for rid, r in self._snapshot
            ]
            self._plans_cache = plan_batch(
                [r for _, r in self._snapshot],
                max_slots=self.engine.max_slots,
                policy=self.policy,
                meta=meta,
                slot_hints=self._slot_hints,
            )
            for p in self._plans_cache:
                self._slot_hints[p.signature] = p.slots
        return self._plans_cache

    def _dispatch(self) -> Optional[Tuple[BatchPlan, List[int], float]]:
        """Pick the policy's next plan and remove its requests from the
        queue — the admission point: everything still queued after this
        (including anything submitted while the launch runs) is free to
        re-plan.  Returns (plan, rids, dispatch stamp); pure queue
        surgery, no device work, so the async front end holds its lock
        only across this and ``_complete``.

        Due retries dispatch FIRST, one per step, each re-planned alone
        (quarantine isolation: a poisoned request can only fail its own
        launch from here on) on the warm slot hints."""
        now = self.clock()
        due = [e for e in self._retry_lane if e.not_before <= now]
        if due:
            e = min(due, key=lambda e: (e.not_before, e.rid))
            self._retry_lane.remove(e)
            plan = plan_batch([e.req], max_slots=self.engine.max_slots,
                              slot_hints=self._slot_hints)[0]
            self.stats.wait_samples.append(now - self._submit_s[e.rid])
            return plan, [e.rid], now
        if not self.queue:
            return None
        if (self._plans_cache is not None and self._aging_s is not None
                and now - self._plans_built_s >= self._aging_s):
            # aging re-plan: the cached plan order is >= aging_s old, so
            # wait-time promotions have accrued that it cannot reflect —
            # rebuild with fresh wait_s (see __init__; starvation-freedom
            # is pinned on the virtual clock in tests/test_scheduler_sim.py)
            self._plans_cache = None
        plans = self._plans()
        plan = plans.pop(0)
        if not plans:
            self._plans_cache = None
        rids = [self._snapshot[qi][0] for qi in plan.indices]
        taken = set(rids)
        self.queue = [q for q in self.queue if q[0] not in taken]
        now = self.clock()
        for rid in rids:
            self.stats.wait_samples.append(now - self._submit_s[rid])
        return plan, rids, now

    def _drop_wait_samples(self, n: int) -> None:
        for _ in range(min(n, len(self.stats.wait_samples))):
            self.stats.wait_samples.pop()  # newest = this dispatch's

    def _rollback(self, plan: BatchPlan, rids: List[int]) -> None:
        """Undo a dispatch whose launch failed (sync path): the requests
        return to the queue with their original submit stamps intact —
        ``step()`` stays retryable — and the dispatch's wait samples are
        dropped (the requests were never served)."""
        self._drop_wait_samples(len(rids))
        self.queue = list(zip(rids, plan.requests)) + self.queue
        self._plans_cache = None  # the popped plan list is now stale

    def _abandon(self, rids: List[int]) -> None:
        """Drop failed in-flight requests for good (async path: their
        futures carry the exception): purge per-rid bookkeeping so a
        long-lived worker that survives engine failures leaks nothing
        and keeps wait/latency sample counts consistent.  Counted in
        ``stats.abandoned`` — never silently dropped."""
        self._drop_wait_samples(len(rids))
        for rid in rids:
            self._submit_s.pop(rid, None)
            self._deadline_s.pop(rid, None)
            self._attempts.pop(rid, None)
            self._partials.pop(rid, None)
            self._progress_cbs.pop(rid, None)
        self.stats.abandoned += len(rids)

    # -------------------------------------------------- fault tolerance
    def _next_retry_due(self) -> Optional[float]:
        if not self._retry_lane:
            return None
        return min(e.not_before for e in self._retry_lane)

    def _resolve_partial(self, rid: int, req: SearchRequest,
                         now: float) -> Tuple[int, SearchResult]:
        """Resolve a rid with its best-so-far anytime result (stored
        ``EngineFault`` partial, else an empty invalid one).  Partials
        count as completions — the rid has a result — and as a deadline
        miss when applicable."""
        res = self._partials.pop(rid, None)
        if res is None:
            res = empty_partial_result(req)
        elif getattr(res, "partial", True) is False:
            res = dataclasses.replace(res, partial=True)
        self.results[rid] = res
        self.stats.partials += 1
        self.stats.completed += 1
        waited = now - self._submit_s.pop(rid)
        self.stats.wait_samples.append(waited)
        self.stats.latency_samples.append(waited)
        dl = self._deadline_s.pop(rid, None)
        if dl is not None and now > dl:
            self.stats.deadline_misses += 1
        self._attempts.pop(rid, None)
        self._progress_cbs.pop(rid, None)
        return rid, res

    def _sweep_deadlines(self) -> List[Tuple[int, SearchResult]]:
        """Graceful degradation (``partial_results=True`` only): any
        QUEUED request — main queue or retry lane — observed past its
        absolute deadline resolves immediately with its best-so-far
        partial instead of burning a launch it already missed."""
        now = self.clock()
        out: List[Tuple[int, SearchResult]] = []

        def expired(rid: int) -> bool:
            dl = self._deadline_s.get(rid)
            return dl is not None and now > dl

        dead = [(rid, req) for rid, req in self.queue if expired(rid)]
        if dead:
            gone = {rid for rid, _ in dead}
            self.queue = [q for q in self.queue if q[0] not in gone]
            self._plans_cache = None
        dead += [(e.rid, e.req) for e in self._retry_lane if expired(e.rid)]
        self._retry_lane = [e for e in self._retry_lane if not expired(e.rid)]
        for rid, req in dead:
            out.append(self._resolve_partial(rid, req, now))
        return out

    def _handle_failure(
        self, plan: BatchPlan, rids: List[int], exc: BaseException
    ) -> Tuple[List[Tuple[int, SearchResult]], List[int]]:
        """The retry-policy failure path for one failed launch: harvest
        any anytime partials the fault carried, then per request either
        schedule an isolated backed-off retry, resolve with the partial
        best (quarantine under ``partial_results``), or abandon into
        ``self.failed``.  Returns (partial resolutions, abandoned rids)
        — the async worker fails the latter's futures."""
        assert self.retry is not None
        self._drop_wait_samples(len(rids))
        if isinstance(exc, EngineFault) and exc.partials:
            for rid, p in zip(rids, exc.partials):
                if p is not None:
                    self._partials[rid] = p
        now = self.clock()
        resolutions: List[Tuple[int, SearchResult]] = []
        failed: List[int] = []
        for rid, req in zip(rids, plan.requests):
            a = self._attempts.get(rid, 0) + 1
            self._attempts[rid] = a
            self.stats.failures += 1
            if a < self.retry.max_attempts:
                self._retry_lane.append(_Retry(
                    not_before=now + self.retry.delay_s(a, rid),
                    rid=rid, req=req, attempts=a,
                ))
                self.stats.retries += 1
            elif self.partial_results:
                resolutions.append(self._resolve_partial(rid, req, now))
            else:
                self.failed[rid] = exc
                failed.append(rid)
        for rid in failed:  # wait samples already dropped above
            self._submit_s.pop(rid, None)
            self._deadline_s.pop(rid, None)
            self._attempts.pop(rid, None)
            self._partials.pop(rid, None)
            self._progress_cbs.pop(rid, None)
        self.stats.abandoned += len(failed)
        return resolutions, failed

    def _complete(
        self, rids: List[int], results: Sequence[SearchResult], busy_s: float,
        reqs: Optional[Sequence[SearchRequest]] = None,
    ) -> List[Tuple[int, SearchResult]]:
        """Record one finished launch: results, latency/deadline stats,
        result-cache population (``reqs`` aligns with ``rids``; full
        results only — ``ResultCache.put`` refuses partials itself)."""
        now = self.clock()
        self.stats.busy_s += busy_s
        self.stats.launches += 1
        self.launch_log.append(list(rids))
        if len(self.launch_log) > LAUNCH_LOG_WINDOW:
            del self.launch_log[: len(self.launch_log) - LAUNCH_LOG_WINDOW]
        done: List[Tuple[int, SearchResult]] = []
        for i, (rid, res) in enumerate(zip(rids, results)):
            self.results[rid] = res
            if self.result_cache is not None and reqs is not None:
                self.result_cache.put(reqs[i], res)
            self.stats.latency_samples.append(now - self._submit_s[rid])
            dl = self._deadline_s.pop(rid, None)
            self._submit_s.pop(rid, None)
            self._attempts.pop(rid, None)
            self._partials.pop(rid, None)
            self._progress_cbs.pop(rid, None)
            if dl is not None and now > dl:
                self.stats.deadline_misses += 1
            done.append((rid, res))
        self.stats.completed += len(done)
        return done

    def _progress_kw(self, rids: List[int]) -> Dict[str, Callable]:
        """The ``on_progress`` kwarg for one launch, mapping the engine's
        plan-local index to the subscribed rid — or ``{}`` when no rid in
        the plan subscribed, so engines without the parameter (stubs,
        fault-injection wrappers) are never handed an unknown kwarg."""
        cbs = [self._progress_cbs.get(rid) for rid in rids]
        if not any(cb is not None for cb in cbs):
            return {}

        def bridge(i: int, snap: SearchResult, _cbs=cbs, _rids=rids):
            cb = _cbs[i]
            if cb is not None:
                cb(_rids[i], snap)

        return {"on_progress": bridge}

    def step(self) -> List[Tuple[int, SearchResult]]:
        """Run ONE slot-packed launch (the policy's most urgent plan of
        the current queue); returns that plan's (rid, result) pairs —
        plus, under ``partial_results``, any deadline-swept partial
        resolutions.  Requests submitted while a step runs simply join
        the next plan.  With a ``retry`` policy an engine failure is
        absorbed (retry lane / quarantine) instead of raised."""
        swept = self._sweep_deadlines() if self.partial_results else []
        d = self._dispatch()
        if d is None:
            return swept
        plan, rids, t0 = d
        if self._last_harvest_end is not None:
            self.stats.device_idle_s += max(0.0, t0 - self._last_harvest_end)
        try:
            results = self.engine.execute(plan, **self._progress_kw(rids))
        except Exception as e:
            if self.retry is None:
                self._rollback(plan, rids)  # step() stays retryable
                raise
            resolutions, _ = self._handle_failure(plan, rids, e)
            return swept + resolutions
        except BaseException:
            # KeyboardInterrupt & co: always roll back and surface —
            # the kill half of the kill/resume contract
            self._rollback(plan, rids)
            raise
        te = self.clock()
        # sequential execute harvests inline: the gap is 0 by definition
        self.stats.dispatch_gap_samples.append(0.0)
        self._last_harvest_end = te
        return swept + self._complete(rids, results, te - t0, plan.requests)

    def _wait_for_retries(self) -> None:
        """Nothing dispatchable but retries are backed off: sleep the
        service clock forward to the next ``not_before``."""
        nb = self._next_retry_due()
        if nb is not None:
            dt = nb - self.clock()
            if dt > 0:
                self._sleep(dt)

    def _harvest_one(
        self, entry: Tuple[BatchPlan, List[int], float, object, float]
    ) -> List[Tuple[int, SearchResult]]:
        """Harvest one in-flight launch ``(plan, rids, t0, pending, td)``:
        blocks on the device sync, records the dispatch->harvest gap, and
        completes (or fails, mirroring ``step()``'s fault handling) the
        launch's requests.  ``busy_s`` gets the HOST time only (dispatch +
        harvest walls) — the overlapped in-flight window is exactly what
        the pipelined drain does not spend blocked."""
        plan, rids, t0, pend, td = entry
        th = self.clock()
        try:
            results = self.engine.harvest(pend)
        except Exception as e:
            self._inflight -= 1
            if self._inflight == 0:
                self._last_harvest_end = self.clock()
            if self.retry is None:
                self._rollback(plan, rids)
                raise
            resolutions, _ = self._handle_failure(plan, rids, e)
            return resolutions
        except BaseException:
            self._inflight -= 1
            self._rollback(plan, rids)
            raise
        te = self.clock()
        self.stats.dispatch_gap_samples.append(max(0.0, th - td))
        self._inflight -= 1
        if self._inflight == 0:
            self._last_harvest_end = te
        return self._complete(rids, results, (td - t0) + (te - th),
                              plan.requests)

    def _stream_pipelined(self) -> Iterator[Tuple[int, SearchResult]]:
        """Double-buffered drain: dispatch plan i+1, THEN harvest plan i,
        so the host-side finalize of one launch overlaps device compute
        of the next.  At most one launch is in flight beyond the one
        being harvested; any exception rolls the in-flight launch's
        requests back into the queue before propagating."""
        prev = None  # (plan, rids, t0, pending, td) still in flight
        try:
            while True:
                swept = (self._sweep_deadlines()
                         if self.partial_results else [])
                yield from swept
                d = self._dispatch()
                if d is None:
                    if prev is not None:
                        to_harvest, prev = prev, None
                        yield from self._harvest_one(to_harvest)
                        continue
                    if not self.pending():
                        return
                    self._wait_for_retries()
                    continue
                plan, rids, t0 = d
                if self._inflight == 0 and self._last_harvest_end is not None:
                    self.stats.device_idle_s += max(
                        0.0, t0 - self._last_harvest_end)
                try:
                    pend = self.engine.dispatch(
                        plan, **self._progress_kw(rids))
                except Exception as e:
                    # a failed dispatch resolves like a failed launch; the
                    # in-flight prev is untouched and harvests next round
                    if self.retry is None:
                        self._rollback(plan, rids)
                        raise
                    resolutions, _ = self._handle_failure(plan, rids, e)
                    yield from resolutions
                    continue
                except BaseException:
                    self._rollback(plan, rids)
                    raise
                td = self.clock()
                self._inflight += 1
                cur = (plan, rids, t0, pend, td)
                if prev is not None:
                    # swap BEFORE harvesting: if the harvest raises, the
                    # outer handler rolls back cur (prev already rolled
                    # back inside _harvest_one), never double-rolls
                    to_harvest, prev = prev, cur
                    yield from self._harvest_one(to_harvest)
                else:
                    prev = cur
        except BaseException:
            if prev is not None:
                self._inflight -= 1
                self._rollback(prev[0], prev[1])
            raise

    def stream(self) -> Iterator[Tuple[int, SearchResult]]:
        """Drain, yielding each plan's results as soon as its launch
        finishes — callers overlap their own post-processing with the
        remaining launches.  Under ``pipelined=True`` (on an engine with
        the dispatch/harvest split) the drain double-buffers launches;
        same results, same per-plan yield boundaries."""
        if self.pipelined and self._can_pipeline:
            yield from self._stream_pipelined()
            return
        while self.pending():
            out = self.step()
            yield from out
            if not out and not self.queue and self.pending():
                self._wait_for_retries()

    def drain(self) -> Dict[int, SearchResult]:
        """Run the whole queue — waiting out retry backoff — until every
        request has resolved; returns {rid: SearchResult} for every
        request ever completed (incl. prior drains)."""
        for _ in self.stream():
            pass
        return self.results


class AsyncDSEService:
    """Non-blocking front end: a worker thread drains a ``DSEService``.

    ``submit`` enqueues and returns a ``concurrent.futures.Future``
    immediately — it never waits on a launch in flight, because the
    worker holds the service lock only around ``_dispatch``/``_complete``
    (queue surgery), never around ``engine.execute``.  A request
    submitted mid-launch therefore joins the NEXT launch's packing, and
    under the priority/edf policies an urgent submission preempts every
    still-queued request at that boundary (the re-plan runs on warm
    program shapes via the service's slot hints — 0 new compiled
    programs).

    Future results are ``SearchResult``s, bit-identical to a synchronous
    ``DSEService`` drain of the same requests: scheduling only reorders
    self-contained searches.  Futures resolve on the worker thread, so a
    done-callback runs BEFORE the next dispatch — a deterministic hook
    for reacting mid-drain (the integration test submits its priority-0
    jump there).  ``paused=True`` admits submissions without launching
    until ``resume()`` — batch admission with a deterministic first plan.
    ``pipelined=True`` swaps the worker for a double-buffered loop
    (dispatch plan i+1 before harvesting plan i — see ``DSEService``);
    results and future-resolution order are unchanged.  Use as a context
    manager, or call ``close()``."""

    def __init__(
        self,
        *,
        engine: Optional[SearchEngine] = None,
        mesh=None,
        max_slots: int = 64,
        policy="fifo",
        clock=time.monotonic,
        paused: bool = False,
        retry: Optional[RetryPolicy] = None,
        partial_results: bool = False,
        result_cache=None,
        pipelined: Optional[bool] = None,
    ):
        self.service = DSEService(
            engine=engine, mesh=mesh, max_slots=max_slots, policy=policy,
            clock=clock, retry=retry, partial_results=partial_results,
            result_cache=result_cache, pipelined=pipelined,
        )
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._run = threading.Event()
        if not paused:
            self._run.set()
        self._futures: Dict[int, Future] = {}
        self._closed = False
        svc = self.service
        target = (self._loop_pipelined
                  if svc.pipelined and svc._can_pipeline else self._loop)
        self._worker = threading.Thread(
            target=target, name="dse-service", daemon=True
        )
        self._worker.start()

    @property
    def stats(self) -> ServiceStats:
        return self.service.stats

    @property
    def launch_log(self) -> List[List[int]]:
        return self.service.launch_log

    # ------------------------------------------------------------- admission
    def submit(self, req: SearchRequest, *, on_progress=None) -> Future:
        """Enqueue; returns a Future resolving to the SearchResult.
        Never blocks on device work — at most the queue lock.  A
        result-cache hit comes back as an ALREADY-RESOLVED future (the
        request never reaches the worker).  ``on_progress(rid, partial)``
        subscribes to the mid-search best-so-far stream (segmented
        engines only); callbacks run on the worker thread, between
        segment launches, and may themselves submit."""
        with self._lock:
            if self._closed:
                raise RuntimeError("AsyncDSEService is closed")
            rid = self.service.submit(req, on_progress=on_progress)
            fut: Future = Future()
            fut.rid = rid  # type: ignore[attr-defined]
            hit = self.service.results.get(rid)
            if hit is None:
                self._futures[rid] = fut
                self._idle.clear()
        # a cache hit resolves OUTSIDE the lock (done-callbacks may submit)
        if hit is not None:
            fut.set_result(hit)
            return fut
        self._wake.set()
        return fut

    def submit_all(self, reqs: Sequence[SearchRequest]) -> List[Future]:
        return [self.submit(r) for r in reqs]

    def pause(self):
        """Stop launching at the next launch boundary (in-flight work
        finishes); submissions keep queueing."""
        self._run.clear()

    def resume(self):
        self._run.set()
        self._wake.set()

    # --------------------------------------------------------------- serving
    def _loop(self):
        while True:
            self._wake.wait()
            self._run.wait()
            svc = self.service
            retry_wait = None
            with self._lock:
                if self._closed:
                    return
                swept = (svc._sweep_deadlines()
                         if svc.partial_results else [])
                partial_futs = [
                    (self._futures.pop(rid, None), res) for rid, res in swept
                ]
                d = svc._dispatch()
                if d is None:
                    nb = svc._next_retry_due()
                    if nb is None:
                        self._wake.clear()
                        if not self._futures:
                            self._idle.set()
                    else:
                        retry_wait = max(nb - svc.clock(), 0.0)
            # futures resolve OUTSIDE the lock: done-callbacks may submit
            for f, res in partial_futs:
                if f is not None:
                    f.set_result(res)
            if d is None:
                if retry_wait is not None:
                    # backed-off retries pending: nap on the REAL clock (a
                    # virtual service clock advances externally), bounded
                    # so external clock advances are picked up promptly
                    time.sleep(min(retry_wait, 0.05) or 0.001)
                continue
            plan, rids, t0 = d
            # the launch runs WITHOUT the lock: submits land concurrently
            # and join the next dispatch's re-plan (progress callbacks
            # fire here too — lock-free, so they may submit)
            try:
                results = svc.engine.execute(plan, **svc._progress_kw(rids))
            except BaseException as e:  # noqa: BLE001 — fail the futures, keep serving
                with self._lock:
                    if svc.retry is None:
                        self.service._abandon(rids)
                        resolved = []
                        failed = [self._futures.pop(rid, None) for rid in rids]
                    else:
                        res2, bad = svc._handle_failure(plan, rids, e)
                        resolved = [
                            (self._futures.pop(rid, None), res)
                            for rid, res in res2
                        ]
                        failed = [self._futures.pop(rid, None) for rid in bad]
                # exceptions set OUTSIDE the lock: done-callbacks fire on
                # failure too, and they may submit (which takes the lock)
                for f, res in resolved:
                    if f is not None:
                        f.set_result(res)
                for f in failed:
                    if f is not None:
                        f.set_exception(e)
                continue
            with self._lock:
                done = svc._complete(rids, results, svc.clock() - t0,
                                     plan.requests)
                futs = [(self._futures.pop(rid, None), res) for rid, res in done]
            # resolve OUTSIDE the lock: done-callbacks may submit
            for f, res in futs:
                if f is not None:
                    f.set_result(res)

    def _loop_pipelined(self):
        """The double-buffered worker: dispatch plan i+1 (lock-free — the
        device starts computing), then harvest plan i (the blocking sync).
        Queue surgery and stats stay under the lock exactly as in
        ``_loop``; futures always resolve outside it.  ``pause()`` and
        ``close()`` both finish the in-flight launch before stopping."""
        svc = self.service

        def fail_rids(plan, rids, e):
            """Failure bookkeeping shared by dispatch and harvest faults
            (the async twin of step()'s except-arm): returns the futures
            to resolve/fail, computed under the lock."""
            if svc.retry is None:
                svc._abandon(rids)
                resolved = []
                failed = [self._futures.pop(rid, None) for rid in rids]
            else:
                res2, bad = svc._handle_failure(plan, rids, e)
                resolved = [(self._futures.pop(rid, None), r)
                            for rid, r in res2]
                failed = [self._futures.pop(rid, None) for rid in bad]
            return resolved, failed

        def harvest_entry(entry):
            plan, rids, t0, pend, td = entry
            th = svc.clock()
            try:
                results = svc.engine.harvest(pend)
            except BaseException as e:  # noqa: BLE001 — fail the futures, keep serving
                with self._lock:
                    svc._inflight -= 1
                    if svc._inflight == 0:
                        svc._last_harvest_end = svc.clock()
                    resolved, failed = fail_rids(plan, rids, e)
                for f, r in resolved:
                    if f is not None:
                        f.set_result(r)
                for f in failed:
                    if f is not None:
                        f.set_exception(e)
                return
            te = svc.clock()
            with self._lock:
                svc.stats.dispatch_gap_samples.append(max(0.0, th - td))
                svc._inflight -= 1
                if svc._inflight == 0:
                    svc._last_harvest_end = te
                done = svc._complete(rids, results, (td - t0) + (te - th),
                                     plan.requests)
                futs = [(self._futures.pop(rid, None), r) for rid, r in done]
            for f, r in futs:
                if f is not None:
                    f.set_result(r)

        prev = None  # (plan, rids, t0, pending, td) still in flight
        while True:
            if prev is None:
                self._wake.wait()
                self._run.wait()
            elif not self._run.is_set():
                # paused mid-overlap: settle the in-flight launch, then
                # block at the top of the next iteration
                to_harvest, prev = prev, None
                harvest_entry(to_harvest)
                continue
            retry_wait = None
            d = None
            with self._lock:
                if self._closed:
                    break
                swept = (svc._sweep_deadlines()
                         if svc.partial_results else [])
                partial_futs = [
                    (self._futures.pop(rid, None), res) for rid, res in swept
                ]
                d = svc._dispatch()
                if d is None:
                    nb = svc._next_retry_due()
                    if nb is None and prev is None:
                        self._wake.clear()
                        if not self._futures:
                            self._idle.set()
                    elif nb is not None:
                        retry_wait = max(nb - svc.clock(), 0.0)
                else:
                    plan, rids, t0 = d
                    if (svc._inflight == 0
                            and svc._last_harvest_end is not None):
                        svc.stats.device_idle_s += max(
                            0.0, t0 - svc._last_harvest_end)
            for f, res in partial_futs:
                if f is not None:
                    f.set_result(res)
            if d is None:
                if prev is not None:
                    to_harvest, prev = prev, None
                    harvest_entry(to_harvest)
                elif retry_wait is not None:
                    time.sleep(min(retry_wait, 0.05) or 0.001)
                continue
            # dispatch WITHOUT the lock: it only enqueues device work
            # (progress callbacks fire here too, and may submit)
            try:
                pend = svc.engine.dispatch(plan, **svc._progress_kw(rids))
            except BaseException as e:  # noqa: BLE001 — fail the futures, keep serving
                with self._lock:
                    resolved, failed = fail_rids(plan, rids, e)
                for f, r in resolved:
                    if f is not None:
                        f.set_result(r)
                for f in failed:
                    if f is not None:
                        f.set_exception(e)
                continue
            td = svc.clock()
            with self._lock:
                svc._inflight += 1
            cur = (plan, rids, t0, pend, td)
            if prev is not None:
                to_harvest, prev = prev, cur
                harvest_entry(to_harvest)
            else:
                prev = cur
        # closed with a launch still in flight (timed-out close cancelled
        # its futures): settle it so engine bookkeeping stays consistent —
        # the pops above see an empty future map and skip
        if prev is not None:
            harvest_entry(prev)

    def drain(self, timeout: Optional[float] = None) -> Dict[int, SearchResult]:
        """Block until the queue and all in-flight launches are done;
        returns the service's full {rid: result} map.  On timeout raises
        ``TimeoutError`` naming every unresolved rid."""
        if not self._idle.wait(timeout):
            with self._lock:
                unresolved = sorted(self._futures)
            raise TimeoutError(
                f"drain timed out with {len(unresolved)} unresolved "
                f"rids: {unresolved}"
            )
        return self.service.results

    def close(self, timeout: Optional[float] = None):
        """Finish in-flight work, then stop the worker.  Idempotent — a
        second close is a no-op.  With ``timeout``, a drain that cannot
        finish in time stops waiting and CANCELS every unresolved future
        (``Future.result()`` then raises ``CancelledError``), so a close
        racing an in-flight launch still leaves no future dangling."""
        with self._lock:
            if self._closed:
                return
        if self._run.is_set():
            try:
                self.drain(timeout)
            except TimeoutError:
                pass  # leftovers are cancelled below
        with self._lock:
            self._closed = True
            leftovers = list(self._futures.values())
            self._futures.clear()
        self._run.set()
        self._wake.set()
        # cancel BEFORE joining: the worker may still be inside a launch
        # (its pops see an empty future map and skip), and callers
        # blocked on result() unblock without waiting the launch out
        for f in leftovers:
            f.cancel()
        if threading.current_thread() is not self._worker:
            self._worker.join()

    def __enter__(self) -> "AsyncDSEService":
        return self

    def __exit__(self, *exc):
        self.close()


def paper_request_mix(
    ws: WorkloadSet,
    n: int,
    *,
    backend: str = "table",
    pop_size: int = 40,
    generations: int = 10,
    area_constr: float = 150.0,
    seed0: int = 0,
    priorities: Optional[Sequence[int]] = None,
    deadlines_s: Optional[Sequence[Optional[float]]] = None,
) -> List[SearchRequest]:
    """N heterogeneous requests over ``ws``: cycles through workload
    subsets (full set, singles, pairs) x objective kinds x seeds — the
    service's canonical mixed traffic (bench_dse_service, the CI
    serve-smoke leg, ``launch.search --serve``).  ``priorities`` /
    ``deadlines_s`` cycle the same way, for mixed-priority / EDF
    traffic (the async smoke + scheduler tests)."""
    W = ws.n
    subsets = [tuple(range(W))]
    subsets += [(i,) for i in range(W)]
    subsets += [(i, (i + 1) % W) for i in range(W)] if W > 1 else []
    return [
        SearchRequest(
            ws=ws.subset(list(subsets[i % len(subsets)])),
            objective=OBJECTIVES[i % len(OBJECTIVES)],
            area_constr=area_constr,
            seed=seed0 + i,
            backend=backend,
            pop_size=pop_size,
            generations=generations,
            priority=0 if priorities is None else int(priorities[i % len(priorities)]),
            deadline_s=None if deadlines_s is None
            else deadlines_s[i % len(deadlines_s)],
        )
        for i in range(n)
    ]
