"""DSE-as-a-service: continuous batching of heterogeneous search requests.

The design-search twin of ``serve.engine`` (which continuous-batches LM
prefill/decode into fixed slots): clients ``submit`` ``SearchRequest``s —
any mix of workload sets, objectives, areas, seeds and backends — and the
service drains the queue slot-packed into as few XLA launches as possible
through the shared ``core.engine.SearchEngine``:

  * ``submit``  — enqueue a request, returns a request id.  Table-backend
    requests get their factorized cost tables built (fingerprint-memoized)
    at ingest, the way the LM engine prefills on admission — the drain
    itself then launches only the cached seeding + GA programs.
  * ``step``    — execute ONE plan (one XLA launch) of the current queue;
    finished results free their slots immediately and newly submitted
    requests join the next step's packing.
  * ``drain``   — step until the queue is empty; returns {rid: result}.
  * ``stream``  — generator form of drain: yields (rid, SearchResult) per
    completed plan, so callers consume results while later plans run.

Because the ``table`` backend's traced ctx is layer-free, requests over
*different* workload sets share one compiled program: 256 mixed requests
(subsets x objectives x seeds) drain through 4 launches of 2 cached
programs, bit-identical to running each request alone
(tests/test_engine.py).  ``mesh=`` lays every launch over the 2-D
(search, population) device mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.engine import (
    BatchPlan,
    SearchEngine,
    SearchRequest,
    SearchResult,
    plan_batch,
)
from repro.core.objectives import OBJECTIVES
from repro.workloads.pack import WorkloadSet


@dataclasses.dataclass
class ServiceStats:
    """Running drain telemetry (the bench's requests/s row reads these)."""

    submitted: int = 0
    completed: int = 0
    launches: int = 0
    busy_s: float = 0.0  # wall time spent inside execute()

    def requests_per_s(self) -> float:
        return self.completed / self.busy_s if self.busy_s > 0 else 0.0


class DSEService:
    """Continuous-batching front end over a ``SearchEngine``."""

    def __init__(
        self,
        *,
        engine: Optional[SearchEngine] = None,
        mesh=None,
        max_slots: int = 64,
    ):
        self.engine = engine or SearchEngine(mesh=mesh, max_slots=max_slots)
        self.queue: List[Tuple[int, SearchRequest]] = []
        self.results: Dict[int, SearchResult] = {}
        self.stats = ServiceStats()
        self._next_rid = 0
        # plans for the current queue snapshot; invalidated on submit so
        # a quiescent drain keeps plan_batch's padded-tail chunking (every
        # chunk of a group = ONE compiled program) instead of re-planning
        # the shrunken residue into a fresh program shape each step
        self._plans_cache: Optional[List[BatchPlan]] = None
        self._snapshot: List[Tuple[int, SearchRequest]] = []

    # ------------------------------------------------------------- admission
    def submit(self, req: SearchRequest) -> int:
        """Enqueue one request; returns its rid.  Validates the request's
        signature eagerly (bad objectives/backends fail at submit, not
        mid-drain) and pre-builds table-backend cost tables so drains only
        launch the cached seeding/GA programs."""
        req.signature()
        if req.backend == "table":
            req.ws.tables(req.tech)  # fingerprint-memoized ingest prefill
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, req))
        self.stats.submitted += 1
        self._plans_cache = None  # next step re-packs the grown queue
        return rid

    def submit_all(self, reqs: Sequence[SearchRequest]) -> List[int]:
        return [self.submit(r) for r in reqs]

    def pending(self) -> int:
        return len(self.queue)

    # --------------------------------------------------------------- serving
    def _plans(self) -> List[BatchPlan]:
        """Plans over the current queue snapshot, cached across steps: a
        drain executes the ONE padded chunking plan_batch produced (plan
        indices refer to the snapshot), and only a new submission forces
        a re-pack — so a group's ragged tail launches as the same padded
        program as its full chunks rather than compiling a fresh
        residual-size program."""
        if self._plans_cache is None:
            self._snapshot = list(self.queue)
            self._plans_cache = plan_batch(
                [r for _, r in self._snapshot], max_slots=self.engine.max_slots
            )
        return self._plans_cache

    def step(self) -> List[Tuple[int, SearchResult]]:
        """Run ONE slot-packed launch (the first plan of the current
        queue); returns that plan's (rid, result) pairs.  Requests
        submitted while a step runs simply join the next plan."""
        if not self.queue:
            return []
        plans = self._plans()
        plan = plans.pop(0)
        if not plans:
            self._plans_cache = None
        t0 = time.time()
        results = self.engine.execute(plan)
        self.stats.busy_s += time.time() - t0
        self.stats.launches += 1
        done: List[Tuple[int, SearchResult]] = []
        for qi, res in zip(plan.indices, results):
            rid = self._snapshot[qi][0]
            self.results[rid] = res
            done.append((rid, res))
        taken = {rid for rid, _ in done}
        self.queue = [q for q in self.queue if q[0] not in taken]
        self.stats.completed += len(done)
        return done

    def stream(self) -> Iterator[Tuple[int, SearchResult]]:
        """Drain, yielding each plan's results as soon as its launch
        finishes — callers overlap their own post-processing with the
        remaining launches."""
        while self.queue:
            yield from self.step()

    def drain(self) -> Dict[int, SearchResult]:
        """Run the whole queue; returns {rid: SearchResult} for every
        request ever completed (incl. prior drains)."""
        for _ in self.stream():
            pass
        return self.results


def paper_request_mix(
    ws: WorkloadSet,
    n: int,
    *,
    backend: str = "table",
    pop_size: int = 40,
    generations: int = 10,
    area_constr: float = 150.0,
    seed0: int = 0,
) -> List[SearchRequest]:
    """N heterogeneous requests over ``ws``: cycles through workload
    subsets (full set, singles, pairs) x objective kinds x seeds — the
    service's canonical mixed traffic (bench_dse_service, the CI
    serve-smoke leg, ``launch.search --serve``)."""
    W = ws.n
    subsets = [tuple(range(W))]
    subsets += [(i,) for i in range(W)]
    subsets += [(i, (i + 1) % W) for i in range(W)] if W > 1 else []
    return [
        SearchRequest(
            ws=ws.subset(list(subsets[i % len(subsets)])),
            objective=OBJECTIVES[i % len(OBJECTIVES)],
            area_constr=area_constr,
            seed=seed0 + i,
            backend=backend,
            pop_size=pop_size,
            generations=generations,
        )
        for i in range(n)
    ]
