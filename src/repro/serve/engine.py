"""Serving engine: continuous batching over prefill/decode steps.

A fixed-slot decode batch (static shapes — SPMD-safe): each of B slots
holds one in-flight sequence.  New requests prefill individually and their
KV rows are spliced into free slots; finished sequences free their slot
immediately (continuous batching a la Orca/vLLM, adapted to static-shape
JAX: the decode step always runs the full B x 1 batch, masked by
liveness).

This is the reduced-scale runnable engine (examples/serve_demo.py); the
production-mesh lowering of the same step functions is exercised by the
dry-run cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.serve.steps import greedy_sample, make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    out: Optional[List[int]] = None
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        slots: int = 4,
        max_len: int = 512,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        self.cache = transformer.init_cache(cfg, slots, max_len)
        self.live = np.zeros(slots, bool)
        self.pos = np.zeros(slots, np.int64)
        self.req: List[Optional[Request]] = [None] * slots
        self.last_tok = np.zeros((slots, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        req.t_submit = time.time()
        req.out = []
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and not self.live.all():
            slot = int(np.flatnonzero(~self.live)[0])
            req = self.queue.pop(0)
            logits, cache1 = self.prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
            )
            cache1 = transformer.pad_cache(self.cfg, cache1, self.max_len)
            # splice the prefilled rows into the batched cache at `slot`
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_slice(
                    big,
                    one.astype(big.dtype),
                    (0, slot) + (0,) * (big.ndim - 2),
                ),
                self.cache,
                cache1,
            )
            tok = int(np.asarray(greedy_sample(logits))[0, 0])
            req.out.append(tok)
            req.t_first = time.time()
            self.live[slot] = True
            self.pos[slot] = len(req.prompt)
            self.req[slot] = req
            self.last_tok[slot, 0] = tok

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One engine iteration; returns number of live sequences."""
        self._admit()
        if not self.live.any():
            return 0
        # static-shape decode across all slots at once: each slot decodes
        # at its own absolute position (pos vector), dead slots just write
        # throwaway rows into their own cache lines.
        logits, self.cache = self.decode(
            self.params,
            self.cache,
            {
                "token": jnp.asarray(self.last_tok),
                "pos": jnp.asarray(self.pos, jnp.int32),
            },
        )
        toks = np.asarray(greedy_sample(logits))
        for slot in np.flatnonzero(self.live):
            req = self.req[slot]
            tok = int(toks[slot, 0])
            req.out.append(tok)
            self.last_tok[slot, 0] = tok
            self.pos[slot] += 1
            if len(req.out) >= req.max_new or self.pos[slot] >= self.max_len - 1:
                req.done = True
                req.t_done = time.time()
                self.finished.append(req)
                self.live[slot] = False
                self.req[slot] = None
        return int(self.live.sum())

    def run(self) -> List[Request]:
        while self.queue or self.live.any():
            self.step()
        return self.finished
