"""Serving step builders: prefill (prompt -> cache) and decode (one token).

Both are pure jittable functions; the decode step donates the cache
(in-place KV update under pjit).  The serving engine
(``repro.serve.engine``) drives them with continuous batching.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer

PyTree = Any


def make_prefill_step(cfg: ModelConfig, *, attn_impl: str = "jnp") -> Callable:
    def prefill_step(params, batch) -> Tuple[jax.Array, PyTree]:
        logits, cache = transformer.prefill(
            cfg,
            params,
            batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            mrope_pos=batch.get("mrope_pos"),
            frames=batch.get("frames"),
            attn_impl=attn_impl,
        )
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, batch) -> Tuple[jax.Array, PyTree]:
        logits, cache = transformer.decode_step(
            cfg, params, cache, batch["token"], batch["pos"]
        )
        return logits, cache

    return decode_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


def temperature_sample(logits: jax.Array, key: jax.Array, temperature: float = 1.0) -> jax.Array:
    g = jax.random.gumbel(key, logits[:, -1, :].shape)
    return jnp.argmax(logits[:, -1, :] / temperature + g, axis=-1).astype(jnp.int32)[:, None]
