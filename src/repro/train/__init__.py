from repro.train.step import loss_fn, make_train_step  # noqa: F401
