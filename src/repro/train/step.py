"""Training step: loss, grads, clipping, AdamW — one jittable function.

The step is built per (config, hyperparams) by ``make_train_step``; the
returned function is pure and pjit-friendly:

    new_params, new_opt, metrics = step(params, opt_state, batch)

Mixed precision: params live in fp32 (optimizer math in fp32), activations
and matmuls run in bf16 (casts happen at use inside the model).  Remat:
every transformer block is a ``jax.checkpoint`` unit under ``lax.scan``
(policy = nothing_saveable) so activation memory is O(one block).
Optional gradient accumulation scans over microbatches.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models import transformer
from repro.optim import adamw_update, clip_by_global_norm, cosine_schedule
from repro.utils.unroll import maybe_scan

PyTree = Any


def chunked_softmax_xent(
    hidden: jax.Array,
    head_w: jax.Array,
    targets: jax.Array,
    *,
    chunk: int = 512,
) -> jax.Array:
    """Mean next-token cross-entropy WITHOUT materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits stay vocab-sharded
    (``constrain`` hint) and are consumed by fused reductions:
      * logsumexp via max/exp/sum over the (sharded) vocab dim,
      * the gold logit via a one-hot contraction (no take_along_axis,
        which would all-gather the sharded vocab dim).
    The chunk body is rematerialized in the backward pass, where
    d(logits) = softmax - onehot is recomputed and immediately contracted.
    """
    B, S, d = hidden.shape
    V = head_w.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    hc = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)  # (nc, B, c, d)
    tc = targets.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(acc, xt):
        h, t = xt
        # f32 accumulation straight out of the matmul — no separate convert;
        # bf16 head compute-copy in the gathered-FSDP/vocab-sharded layout
        wc = constrain(head_w.astype(h.dtype), (None, "vocab"))
        logits = jnp.einsum(
            "bcd,dv->bcv", h, wc, preferred_element_type=jnp.float32,
        )
        logits = constrain(logits, ("batch", None, "vocab"))
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2) == t[..., None]
        )
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return acc + jnp.sum(lse - gold), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = maybe_scan(body, jnp.float32(0.0), (hc, tc))
    return total / (B * S)


def loss_fn(
    cfg: ModelConfig,
    params: PyTree,
    batch: Dict[str, jax.Array],
    *,
    aux_weight: float = 0.01,
    remat: bool = True,
    attn_impl: str = "jnp",
    loss_chunk: int = 512,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token cross-entropy (+ MoE load-balance aux)."""
    hidden, aux = transformer.forward(
        cfg,
        params,
        batch["inputs"],
        vision_embeds=batch.get("vision_embeds"),
        mrope_pos=batch.get("mrope_pos"),
        frames=batch.get("frames"),
        remat=remat,
        attn_impl=attn_impl,
        return_hidden=True,
    )
    xent = chunked_softmax_xent(
        hidden,
        transformer.head_weight(cfg, params),
        batch["targets"],
        chunk=loss_chunk,
    )
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "moe_aux": aux}


def make_train_step(
    cfg: ModelConfig,
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    accum: int = 1,
    aux_weight: float = 0.01,
    remat: bool = True,
    attn_impl: str = "jnp",
) -> Callable:
    """Build the jittable train step (optionally with grad accumulation)."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(
            cfg, p, b, aux_weight=aux_weight, remat=remat, attn_impl=attn_impl
        ),
        has_aux=True,
    )

    def compute_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        # split the global batch into `accum` microbatches and scan
        def micro(carry, mb):
            acc_grads, acc_loss = carry
            (loss, _m), grads = grad_fn(params, mb)
            acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
            return (acc_grads, acc_loss + loss), None

        def reshape(name, x):
            if name == "mrope_pos":  # (3, B, S): batch on axis 1
                r = x.reshape(x.shape[0], accum, x.shape[1] // accum, x.shape[2])
                return jnp.moveaxis(r, 1, 0)  # (accum, 3, B/accum, S)
            return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

        mbs = {k: reshape(k, v) for k, v in batch.items()}
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = maybe_scan(micro, (zero, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(lambda g: g / accum, grads)
        loss = loss_sum / accum
        return loss, {"xent": loss, "moe_aux": jnp.float32(0.0)}, grads

    def step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = cosine_schedule(
            opt_state.step,
            peak_lr=peak_lr,
            warmup_steps=warmup_steps,
            total_steps=total_steps,
        )
        new_params, new_opt = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt, metrics

    return step
