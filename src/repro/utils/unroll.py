"""Scan wrapper with a trace-time unroll switch.

XLA's HloCostAnalysis counts a ``while`` body ONCE regardless of trip
count, so FLOPs/bytes/collective numbers from a scanned model are useless
for rooflines.  The dry-run cost pass therefore lowers small model variants
under ``unroll_scans()`` — every ``maybe_scan`` in the model then emits
straight-line code (a Python loop at trace time), making the HLO cost
analysis exact.  Production lowering keeps ``lax.scan`` (small HLO, fast
compiles).

``maybe_scan`` is a drop-in for ``jax.lax.scan(f, init, xs)`` (the subset
of the API the models use: xs pytree with equal leading dims, ys pytree or
None).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

_STATE = threading.local()


def unrolling() -> bool:
    return getattr(_STATE, "unroll", False)


@contextlib.contextmanager
def unroll_scans(enabled: bool = True):
    prev = unrolling()
    _STATE.unroll = enabled
    try:
        yield
    finally:
        _STATE.unroll = prev


MAX_UNROLL = 8  # beyond this, keep the loop.  Set so the block stack, the
# grad-accum loop and the 4k-train attention-chunk scan unroll (their bodies
# carry the matmuls), while long inner scans stay looped: the SSD inter-chunk
# state pass is elementwise noise, and the 32k-prefill attention chunk scan
# is handled by the per-layer extrapolation (its body is counted once per
# unrolled layer — see dryrun.scan_corrected_costs docstring caveat).


def maybe_scan(f: Callable, init: Any, xs: Any, length: Optional[int] = None):
    """lax.scan when tracing normally; an unrolled Python loop under
    ``unroll_scans()`` (straight-line HLO for exact cost analysis)."""
    if xs is None:
        n = length
    else:
        leaves = jax.tree.leaves(xs)
        n = leaves[0].shape[0] if leaves else length
    if not unrolling() or n > MAX_UNROLL:
        return jax.lax.scan(f, init, xs, length=length)

    slices = (
        [None] * n
        if xs is None
        else [jax.tree.map(lambda a: a[i], xs) for i in range(n)]
    )

    carry = init
    ys = []
    for s in slices:
        carry, y = f(carry, s)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs, axis=0), *ys)
    return carry, stacked
