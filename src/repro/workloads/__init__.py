from repro.workloads.cnn import CNN_WORKLOADS, cnn_workload  # noqa: F401
from repro.workloads.pack import WorkloadSet, pack_workloads  # noqa: F401
from repro.workloads.lm import lm_workload  # noqa: F401
