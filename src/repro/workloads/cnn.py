"""The paper's four CNN workloads as IMC layer tables.

A *workload* is a list of layer descriptors; each descriptor is the 6-tuple

    (M, K, N, A_in, A_out, groups)

where  M      = # weight-stationary vector presentations (output positions),
       K      = fan-in per group (crossbar rows needed),
       N      = output channels per group (crossbar cols / cells_per_weight),
       A_in   = unique input activations (bytes at 8-bit),
       A_out  = unique output activations,
       groups = convolution groups (depthwise: groups == channels).

Tables are *derived* from real architecture specs (kernel/stride/channels per
layer), not hand-copied: ``_trace`` walks the net and does the conv
arithmetic.  Sources: VGG16 [18], ResNet18 [19], AlexNet [35],
MobileNetV3-Large [36] (table 1 of the paper, incl. SE blocks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

Layer = Tuple[int, int, int, int, int, int]


@dataclasses.dataclass
class _St:
    h: int
    w: int
    c: int
    layers: List[Layer]

    def conv(self, cout: int, k: int, s: int = 1, p: int = None, groups: int = 1):
        if p is None:
            p = k // 2
        ho = (self.h + 2 * p - k) // s + 1
        wo = (self.w + 2 * p - k) // s + 1
        m = ho * wo
        kin = (self.c // groups) * k * k
        n = cout // groups
        self.layers.append(
            (m, kin, n, self.h * self.w * self.c, ho * wo * cout, groups)
        )
        self.h, self.w, self.c = ho, wo, cout
        return self

    def dwconv(self, k: int, s: int = 1):
        return self.conv(self.c, k, s, groups=self.c)

    def pool(self, k: int = 2, s: int = None):
        s = s or k
        self.h = (self.h - k) // s + 1
        self.w = (self.w - k) // s + 1
        return self

    def gap(self):  # global average pool
        self.h = self.w = 1
        return self

    def fc(self, cout: int):
        cin = self.h * self.w * self.c
        self.layers.append((1, cin, cout, cin, cout, 1))
        self.h = self.w = 1
        self.c = cout
        return self


def _vgg16() -> List[Layer]:
    s = _St(224, 224, 3, [])
    for blk in ([64, 64], [128, 128], [256, 256, 256], [512, 512, 512], [512, 512, 512]):
        for c in blk:
            s.conv(c, 3)
        s.pool()
    s.fc(4096).fc(4096).fc(1000)
    return s.layers


def _resnet18() -> List[Layer]:
    s = _St(224, 224, 3, [])
    s.conv(64, 7, 2, 3).pool(3, 2)
    for stage, (c, n_blocks, stride) in enumerate(
        [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]
    ):
        for b in range(n_blocks):
            st = stride if b == 0 else 1
            if st != 1 or s.c != c:
                # downsample shortcut 1x1 (counted once per stage entry)
                hs, ws, cs = s.h, s.w, s.c
                ho = (hs - 1) // st + 1
                s.layers.append(
                    (ho * ho, cs, c, hs * ws * cs, ho * ho * c, 1)
                )
            s.conv(c, 3, st)
            s.conv(c, 3, 1)
    s.gap().fc(1000)
    return s.layers


def _alexnet() -> List[Layer]:
    s = _St(227, 227, 3, [])
    s.conv(96, 11, 4, 0).pool(3, 2)
    s.conv(256, 5, 1, 2).pool(3, 2)
    s.conv(384, 3).conv(384, 3).conv(256, 3).pool(3, 2)
    s.fc(4096).fc(4096).fc(1000)
    return s.layers


# MobileNetV3-Large bneck table [36]: (k, exp, out, SE, stride)
_MBV3 = [
    (3, 16, 16, False, 1),
    (3, 64, 24, False, 2),
    (3, 72, 24, False, 1),
    (5, 72, 40, True, 2),
    (5, 120, 40, True, 1),
    (5, 120, 40, True, 1),
    (3, 240, 80, False, 2),
    (3, 200, 80, False, 1),
    (3, 184, 80, False, 1),
    (3, 184, 80, False, 1),
    (3, 480, 112, True, 1),
    (3, 672, 112, True, 1),
    (5, 672, 160, True, 2),
    (5, 960, 160, True, 1),
    (5, 960, 160, True, 1),
]


def _mobilenetv3() -> List[Layer]:
    s = _St(224, 224, 3, [])
    s.conv(16, 3, 2)
    for k, exp, out, se, stride in _MBV3:
        if exp != s.c:
            s.conv(exp, 1)  # expand
        s.dwconv(k, stride)  # depthwise — maps terribly onto crossbars
        if se:  # squeeze-excite: two tiny FCs on pooled features
            cin = s.c
            red = max(8, int(np.ceil(cin / 4 / 8) * 8))
            s.layers.append((1, cin, red, cin, red, 1))
            s.layers.append((1, red, cin, red, cin, 1))
        s.conv(out, 1)  # project
    s.conv(960, 1)
    s.gap()
    s.fc(1280).fc(1000)
    return s.layers


CNN_WORKLOADS: Dict[str, List[Layer]] = {}


def cnn_workload(name: str) -> List[Layer]:
    if not CNN_WORKLOADS:
        CNN_WORKLOADS.update(
            vgg16=_vgg16(),
            resnet18=_resnet18(),
            alexnet=_alexnet(),
            mobilenetv3=_mobilenetv3(),
        )
    return CNN_WORKLOADS[name]


PAPER_WORKLOADS = ("vgg16", "resnet18", "alexnet", "mobilenetv3")
