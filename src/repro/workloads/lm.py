"""Export assigned LM architectures as IMC workloads (beyond-paper).

Every *weight* GEMM of a ``ModelConfig`` becomes an IMC layer descriptor —
derived from the same config object that drives the JAX model, so the DSE
workload can never drift from the live model code.

Mapping notes (DESIGN.md §Arch-applicability):
* IMC crossbars hold *weights*; activation-activation products (attention
  QK^T/PV, SSD state updates) execute on the digital periphery and are not
  crossbar layers — standard practice in the IMC-accelerator literature.
* ``mode="decode"`` exports per-token serving cost (M=1 per matmul);
  ``mode="prefill"`` exports a full sequence (M=seq).
* The conv stem of Mamba blocks is a depthwise layer (groups=channels),
  exactly like MobileNet's dwconvs.
* MoE: all experts' weights must be resident (capacity pressure — the
  interesting IMC trade-off), but only ``topk`` experts fire per token, so
  M is scaled by topk/n_experts on expert GEMMs.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.configs.base import ModelConfig

Layer = Tuple[int, int, int, int, int, int]


def _gemm(m: int, k: int, n: int, groups: int = 1, m_frac: float = 1.0) -> Layer:
    m_eff = max(1, int(round(m * m_frac)))
    return (m_eff, k, n, m * k, m_eff * n, groups)


def lm_workload(cfg: ModelConfig, *, mode: str = "decode", seq: int = 1) -> List[Layer]:
    assert mode in ("decode", "prefill")
    M = 1 if mode == "decode" else seq
    d, Dh = cfg.d_model, cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    layers: List[Layer] = []

    def attn_layers() -> List[Layer]:
        return [
            _gemm(M, d, H * Dh),      # wq
            _gemm(M, d, KV * Dh),     # wk
            _gemm(M, d, KV * Dh),     # wv
            _gemm(M, H * Dh, d),      # wo
        ]

    def mlp_layers() -> List[Layer]:
        return [
            _gemm(M, d, cfg.d_ff),
            _gemm(M, d, cfg.d_ff),
            _gemm(M, cfg.d_ff, d),
        ]

    def moe_layers() -> List[Layer]:
        f = cfg.moe_d_ff_
        frac = cfg.topk / cfg.n_experts
        out = [_gemm(M, d, cfg.n_experts)]  # router
        for _ in range(cfg.n_experts):
            out += [
                _gemm(M, d, f, m_frac=frac),
                _gemm(M, d, f, m_frac=frac),
                _gemm(M, f, d, m_frac=frac),
            ]
        return out

    def mamba_layers() -> List[Layer]:
        from repro.models.mamba import _dims

        d_inner, G, N, Hs, Pd, conv_ch, d_in_proj = _dims(cfg)
        # NOTE: the 4-tap causal depthwise conv is NOT exported as a
        # crossbar layer — groups == channels would demand one crossbar
        # per channel (3k+ crossbars for 16 weights each), while 4-tap
        # shift-mul-adds execute on the digital periphery like the SSD
        # state updates and attention score ops (standard IMC practice;
        # unlike MobileNet's 9–49-tap, hundreds-of-channels dwconvs which
        # we DO map and which stress capacity by design).
        return [
            _gemm(M, d, d_in_proj),  # in_proj
            _gemm(M, d_inner, d),    # out_proj
        ]

    per_layer = {
        "attn": attn_layers,
        "mamba": mamba_layers,
        "mlp": mlp_layers,
        "moe": moe_layers,
        "none": lambda: [],
    }
    for _ in range(cfg.n_blocks):
        for mixer, ffn in cfg.layer_plan():
            layers += per_layer[mixer]()
            if cfg.is_encdec and mixer == "attn":
                layers += attn_layers()  # cross-attention projections
            layers += per_layer[ffn]()
    if cfg.is_encdec:
        for _ in range(cfg.encoder_layers):
            layers += attn_layers() + mlp_layers()
    # LM head (embedding lookup is a table read, not a GEMM; the head is)
    layers.append(_gemm(M, d, cfg.vocab_size))
    return layers
