"""Workload packing: list-of-layer-tables -> padded tensors for the JAX model.

A set of W workloads becomes
    feats (W, L_max, 6) float32   and   mask (W, L_max) bool
so the joint `max_w` reduction and the per-layer cost sums are tensor ops.
``WorkloadSet.tables()`` memoizes the factorized cost-model statistics
(``imc.tables``): the layer axis is reduced once per (set, tech) and the
``backend="table"`` search path re-gathers from the cached tables forever
after.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadSet:
    names: Tuple[str, ...]
    feats: jnp.ndarray  # (W, L_max, 6)
    mask: jnp.ndarray  # (W, L_max)

    @property
    def n(self) -> int:
        return len(self.names)

    def subset(self, idx: Sequence[int]) -> "WorkloadSet":
        idx = list(idx)
        return WorkloadSet(
            names=tuple(self.names[i] for i in idx),
            feats=self.feats[np.array(idx)],
            mask=self.mask[np.array(idx)],
        )

    def tables(self, tech=None):
        """Per-workload sufficient statistics for the factorized cost model
        (``imc.tables.WorkloadTables``), cached per tech on this set.  The
        import is deferred because ``imc.cost`` imports this module."""
        from repro.imc.tables import build_tables_arrays
        from repro.imc.tech import TECH

        tech = tech or TECH
        cache = self.__dict__.setdefault("_tables_cache", {})
        if tech not in cache:
            cache[tech] = build_tables_arrays(self.feats, self.mask, tech)
        return cache[tech]


def pack_workloads(named_layers: Sequence[Tuple[str, List[Tuple]]]) -> WorkloadSet:
    l_max = max(len(ls) for _, ls in named_layers)
    W = len(named_layers)
    feats = np.zeros((W, l_max, 6), np.float32)
    mask = np.zeros((W, l_max), bool)
    for i, (_, ls) in enumerate(named_layers):
        arr = np.asarray(ls, np.float32)
        feats[i, : len(ls)] = arr
        mask[i, : len(ls)] = True
    return WorkloadSet(
        names=tuple(n for n, _ in named_layers),
        feats=jnp.asarray(feats),
        mask=jnp.asarray(mask),
    )
