"""Workload packing: list-of-layer-tables -> padded tensors for the JAX model.

A set of W workloads becomes
    feats (W, L_max, 6) float32   and   mask (W, L_max) bool
so the joint `max_w` reduction and the per-layer cost sums are tensor ops.
``WorkloadSet.fingerprint()`` is a content hash (feats/mask bytes + names),
and ``WorkloadSet.tables()`` memoizes the factorized cost-model statistics
(``imc.tables``) on it: the layer axis is reduced once per (content, tech)
— re-packing an identical set (a fresh ``pack_workloads`` call, an equal
``subset``) hits the same cached tables, and the DSE engine
(``core.engine``) keys its padded-table plan cache on the same fingerprint.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import OrderedDict
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# (fingerprint, tech) -> WorkloadTables.  Content-keyed, NOT object-keyed:
# two separately packed but identical sets share one table build.  Entries
# are small (a few KB), but a production service's request stream can
# carry UNBOUNDED many distinct fingerprints (joint workload co-search
# mutates workloads per request), so the memo is a capped LRU: re-access
# refreshes, overflow evicts oldest, an evicted entry simply rebuilds.
# Cap via REPRO_TABLES_MEMO_CAP (entries; read per call so tests and
# operators can retune a live process).
_TABLES_MEMO: "OrderedDict[tuple, object]" = OrderedDict()
_TABLES_MEMO_CAP_ENV = "REPRO_TABLES_MEMO_CAP"
_TABLES_MEMO_CAP_DEFAULT = 1024


def _tables_memo_cap() -> int:
    cap = int(os.environ.get(_TABLES_MEMO_CAP_ENV, _TABLES_MEMO_CAP_DEFAULT))
    if cap < 1:
        raise ValueError(
            f"{_TABLES_MEMO_CAP_ENV} must be >= 1, got {cap}"
        )
    return cap


@dataclasses.dataclass(frozen=True)
class WorkloadSet:
    names: Tuple[str, ...]
    feats: jnp.ndarray  # (W, L_max, 6)
    mask: jnp.ndarray  # (W, L_max)

    @property
    def n(self) -> int:
        return len(self.names)

    def subset(self, idx: Sequence[int]) -> "WorkloadSet":
        idx = list(idx)
        return WorkloadSet(
            names=tuple(self.names[i] for i in idx),
            feats=self.feats[np.array(idx)],
            mask=self.mask[np.array(idx)],
        )

    def fingerprint(self) -> str:
        """Content hash: sha256 over the feats/mask bytes (+ shapes, so
        equal byte streams of different layouts can't collide) and the
        workload names.  Cached on the instance after the first call."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.sha256()
            feats = np.ascontiguousarray(np.asarray(self.feats, np.float32))
            mask = np.ascontiguousarray(np.asarray(self.mask, bool))
            h.update(repr((feats.shape, mask.shape)).encode())
            h.update(feats.tobytes())
            h.update(mask.tobytes())
            h.update("\x00".join(self.names).encode())
            fp = h.hexdigest()
            self.__dict__["_fingerprint"] = fp
        return fp

    def tables(self, tech=None):
        """Per-workload sufficient statistics for the factorized cost model
        (``imc.tables.WorkloadTables``), memoized on ``(fingerprint, tech)``
        in a capped LRU — identical re-packed sets hit the cache, streams
        of unique fingerprints can't grow host memory without bound.  The
        import is deferred because ``imc.cost`` imports this module."""
        from repro.imc.tables import build_tables_arrays
        from repro.imc.tech import TECH

        from repro.core import space

        tech = tech or TECH
        # grid_token: tables are built over the ACTIVE grid — a
        # space.configure_grid() between calls must miss, never serve a
        # stale-density table
        key = (self.fingerprint(), tech, space.grid_token())
        hit = _TABLES_MEMO.get(key)
        if hit is None:
            hit = _TABLES_MEMO[key] = build_tables_arrays(self.feats, self.mask, tech)
        _TABLES_MEMO.move_to_end(key)
        cap = _tables_memo_cap()
        while len(_TABLES_MEMO) > cap:
            _TABLES_MEMO.popitem(last=False)
        return hit


def pack_workloads(named_layers: Sequence[Tuple[str, List[Tuple]]]) -> WorkloadSet:
    l_max = max(len(ls) for _, ls in named_layers)
    W = len(named_layers)
    feats = np.zeros((W, l_max, 6), np.float32)
    mask = np.zeros((W, l_max), bool)
    for i, (_, ls) in enumerate(named_layers):
        arr = np.asarray(ls, np.float32)
        feats[i, : len(ls)] = arr
        mask[i, : len(ls)] = True
    return WorkloadSet(
        names=tuple(n for n, _ in named_layers),
        feats=jnp.asarray(feats),
        mask=jnp.asarray(mask),
    )
