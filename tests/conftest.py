import os

# Tests default to the REAL device set (single CPU device on CI) so perf
# numbers and device-placement assumptions stay honest.  The fake-multi-
# device harness is opt-in, for the sharding tests (tests/test_search_
# sharded.py) and the CI `multidevice` leg (tools/ci.sh multidevice):
#
#   * export XLA_FLAGS=--xla_force_host_platform_device_count=8, or
#   * export REPRO_FAKE_DEVICES=8 and this conftest injects the flag below
#     (it must land in the environment before jax initializes a backend).
#
# Tests marked @pytest.mark.multidevice auto-skip when <2 devices are
# visible, so the tier-1 suite is unchanged on a plain host.
_fake = os.environ.get("REPRO_FAKE_DEVICES")
if _fake and "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_fake)}"
    ).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


def pytest_collection_modifyitems(config, items):
    if jax.device_count() >= 2:
        return
    skip = pytest.mark.skip(
        reason="needs >=2 jax devices (REPRO_FAKE_DEVICES=8 or XLA_FLAGS="
        "--xla_force_host_platform_device_count=8)"
    )
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def device_count():
    """Visible jax device count (8 under the fake-multi-device harness)."""
    return jax.device_count()
