import os

# NOTE: no XLA_FLAGS here on purpose — tests and benches must see the real
# single CPU device; only launch/dryrun.py forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
