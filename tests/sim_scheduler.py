"""Deterministic scheduler simulation harness for the DSE service.

Everything the async/priority front end claims about *scheduling* —
policy ordering, starvation-freedom under aging, deadline-miss
accounting, mid-drain preemption — is a host-side property of
``plan_batch`` + ``DSEService``, independent of XLA.  This harness makes
those claims assertable without a single device launch:

  * ``VirtualClock``  — the service's only time source; tests advance it
    explicitly, so waits, deadlines and latency stats are exact numbers,
    not wall-clock noise.
  * ``StubEngine``    — duck-types ``SearchEngine.execute``: returns a
    ``SimResult`` per real request (echoing seed/names, so every rid can
    be checked against its own request), advances the clock by a
    scripted per-launch duration, and records each launch.
  * ``sim_service``   — a ``DSEService`` wired to both.
  * ``run_script``    — drives a scripted submit / advance / step
    interleaving and returns the completion record.

Workload sets are tiny host-numpy ``WorkloadSet``s (``sim_ws``) on the
``jnp`` backend, so nothing here ever touches a device; the real-engine
twin of these assertions lives in tests/test_engine.py.

Used by tests/test_scheduler_sim.py (run in both the 1-device and
fake-8-device CI jobs — the harness is device-count-independent).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine import BatchPlan, SearchRequest
from repro.serve.dse import DSEService
from repro.workloads.pack import WorkloadSet


class VirtualClock:
    """Monotonic clock a test advances by hand.  Pass as the service's
    ``clock=``; every submit stamp, wait, deadline and busy figure then
    reads simulated seconds."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0, f"clock can only move forward, got {dt}"
        self.t += float(dt)
        return self.t


def sim_ws(w: int = 1, l: int = 2, tag: str = "sim") -> WorkloadSet:
    """A tiny host-numpy workload set (never evaluated by the stub)."""
    return WorkloadSet(
        names=tuple(f"{tag}{i}" for i in range(w)),
        feats=np.ones((w, l, 6), np.float32),
        mask=np.ones((w, l), bool),
    )


_WS = sim_ws()


def sim_request(
    seed: int = 0,
    *,
    priority: int = 0,
    deadline_s: Optional[float] = None,
    ws: Optional[WorkloadSet] = None,
    pop_size: int = 8,
    generations: int = 2,
) -> SearchRequest:
    """A real ``SearchRequest`` on the ``jnp`` backend (no table prefill
    at submit) over a host-only workload set."""
    return SearchRequest(
        ws=ws if ws is not None else _WS,
        seed=seed,
        backend="jnp",
        pop_size=pop_size,
        generations=generations,
        priority=priority,
        deadline_s=deadline_s,
    )


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Stands in for a SearchResult; echoes enough of the request that a
    test can assert every rid got the result of ITS OWN request."""

    seed: int
    workload_names: Tuple[str, ...]
    priority: int


@dataclasses.dataclass
class SimLaunch:
    """One recorded StubEngine launch."""

    seeds: List[int]  # real requests only, slot order
    slots: int
    signature: tuple
    start_s: float
    end_s: float


class StubEngine:
    """Duck-types the half of ``SearchEngine`` the service consumes:
    ``max_slots`` and ``execute(plan)``.  Each execute advances the
    virtual clock by ``launch_s`` (a constant, or a callable of the
    plan — scripted heterogeneous launch times) and logs the launch."""

    def __init__(
        self,
        clock: VirtualClock,
        *,
        max_slots: int = 4,
        launch_s: Union[float, Callable[[BatchPlan], float]] = 1.0,
    ):
        self.clock = clock
        self.max_slots = int(max_slots)
        self.launch_s = launch_s
        self.launches: List[SimLaunch] = []

    def execute(self, plan: BatchPlan, *, mesh=None) -> List[SimResult]:
        t0 = self.clock()
        dt = self.launch_s(plan) if callable(self.launch_s) else self.launch_s
        self.clock.advance(dt)
        self.launches.append(SimLaunch(
            seeds=[r.seed for r in plan.requests],
            slots=plan.slots,
            signature=plan.signature,
            start_s=t0,
            end_s=self.clock(),
        ))
        return [
            SimResult(seed=r.seed, workload_names=r.ws.names,
                      priority=r.priority)
            for r in plan.requests
        ]


def sim_service(
    *,
    policy="fifo",
    max_slots: int = 4,
    launch_s: Union[float, Callable[[BatchPlan], float]] = 1.0,
    t0: float = 0.0,
) -> Tuple[DSEService, VirtualClock, StubEngine]:
    clock = VirtualClock(t0)
    stub = StubEngine(clock, max_slots=max_slots, launch_s=launch_s)
    svc = DSEService(engine=stub, policy=policy, clock=clock)
    return svc, clock, stub


# --------------------------------------------------------------- scripting
# Event grammar (deterministic interleavings, executed in list order):
#   ("submit", SearchRequest)  -> enqueue; records the rid
#   ("advance", dt)            -> move the virtual clock
#   ("step",)                  -> one launch (no-op on an empty queue)
#   ("drain",)                 -> step until empty
Event = tuple


@dataclasses.dataclass
class SimTrace:
    """What a script produced, in order."""

    rids: List[int]  # rid per submit event, in script order
    completions: List[Tuple[int, SimResult, float]]  # (rid, result, t_done)

    def completion_order(self) -> List[int]:
        return [rid for rid, _, _ in self.completions]

    def result(self, rid: int) -> SimResult:
        return next(res for r, res, _ in self.completions if r == rid)

    def done_at(self, rid: int) -> float:
        return next(t for r, _, t in self.completions if r == rid)


def run_script(svc: DSEService, clock: VirtualClock,
               events: Sequence[Event]) -> SimTrace:
    trace = SimTrace(rids=[], completions=[])

    def record(done):
        for rid, res in done:
            trace.completions.append((rid, res, clock()))

    for ev in events:
        kind = ev[0]
        if kind == "submit":
            trace.rids.append(svc.submit(ev[1]))
        elif kind == "advance":
            clock.advance(ev[1])
        elif kind == "step":
            record(svc.step())
        elif kind == "drain":
            while svc.pending():
                record(svc.step())
        else:
            raise ValueError(f"unknown sim event {ev!r}")
    return trace


def submit_burst(
    svc: DSEService,
    n: int,
    *,
    priorities: Sequence[int] = (0,),
    deadlines_s: Sequence[Optional[float]] = (None,),
    seed0: int = 0,
) -> List[int]:
    """n sim requests cycling priorities/deadlines; returns rids."""
    pr = itertools.cycle(priorities)
    dl = itertools.cycle(deadlines_s)
    return [
        svc.submit(sim_request(seed0 + i, priority=next(pr),
                               deadline_s=next(dl)))
        for i in range(n)
    ]
