"""Deterministic scheduler simulation harness for the DSE service.

Everything the async/priority front end claims about *scheduling* —
policy ordering, starvation-freedom under aging, deadline-miss
accounting, mid-drain preemption — is a host-side property of
``plan_batch`` + ``DSEService``, independent of XLA.  This harness makes
those claims assertable without a single device launch:

  * ``VirtualClock``  — the service's only time source; tests advance it
    explicitly, so waits, deadlines and latency stats are exact numbers,
    not wall-clock noise.
  * ``StubEngine``    — duck-types ``SearchEngine.execute``: returns a
    ``SimResult`` per real request (echoing seed/names, so every rid can
    be checked against its own request), advances the clock by a
    scripted per-launch duration, and records each launch.
  * ``sim_service``   — a ``DSEService`` wired to both.
  * ``run_script``    — drives a scripted submit / advance / step
    interleaving and returns the completion record.

Workload sets are tiny host-numpy ``WorkloadSet``s (``sim_ws``) on the
``jnp`` backend, so nothing here ever touches a device; the real-engine
twin of these assertions lives in tests/test_engine.py.

Used by tests/test_scheduler_sim.py (run in both the 1-device and
fake-8-device CI jobs — the harness is device-count-independent).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine import BatchPlan, EngineFault, NonFiniteScoreError, SearchRequest
from repro.serve.dse import DSEService, RetryPolicy
from repro.workloads.pack import WorkloadSet


class VirtualClock:
    """Monotonic clock a test advances by hand.  Pass as the service's
    ``clock=``; every submit stamp, wait, deadline and busy figure then
    reads simulated seconds."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0, f"clock can only move forward, got {dt}"
        self.t += float(dt)
        return self.t


def sim_ws(w: int = 1, l: int = 2, tag: str = "sim") -> WorkloadSet:
    """A tiny host-numpy workload set (never evaluated by the stub)."""
    return WorkloadSet(
        names=tuple(f"{tag}{i}" for i in range(w)),
        feats=np.ones((w, l, 6), np.float32),
        mask=np.ones((w, l), bool),
    )


_WS = sim_ws()


def sim_request(
    seed: int = 0,
    *,
    priority: int = 0,
    deadline_s: Optional[float] = None,
    ws: Optional[WorkloadSet] = None,
    pop_size: int = 8,
    generations: int = 2,
) -> SearchRequest:
    """A real ``SearchRequest`` on the ``jnp`` backend (no table prefill
    at submit) over a host-only workload set."""
    return SearchRequest(
        ws=ws if ws is not None else _WS,
        seed=seed,
        backend="jnp",
        pop_size=pop_size,
        generations=generations,
        priority=priority,
        deadline_s=deadline_s,
    )


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Stands in for a SearchResult; echoes enough of the request that a
    test can assert every rid got the result of ITS OWN request.
    ``partial`` mirrors ``SearchResult.partial`` so the fault-injection
    tests can tell a full result from an anytime one."""

    seed: int
    workload_names: Tuple[str, ...]
    priority: int
    partial: bool = False


@dataclasses.dataclass
class SimLaunch:
    """One recorded StubEngine launch."""

    seeds: List[int]  # real requests only, slot order
    slots: int
    signature: tuple
    start_s: float
    end_s: float


class StubEngine:
    """Duck-types the half of ``SearchEngine`` the service consumes:
    ``max_slots`` and ``execute(plan)``.  Each execute advances the
    virtual clock by ``launch_s`` (a constant, or a callable of the
    plan — scripted heterogeneous launch times) and logs the launch."""

    def __init__(
        self,
        clock: VirtualClock,
        *,
        max_slots: int = 4,
        launch_s: Union[float, Callable[[BatchPlan], float]] = 1.0,
    ):
        self.clock = clock
        self.max_slots = int(max_slots)
        self.launch_s = launch_s
        self.launches: List[SimLaunch] = []

    def execute(self, plan: BatchPlan, *, mesh=None,
                dt: Optional[float] = None) -> List[SimResult]:
        t0 = self.clock()
        if dt is None:
            dt = self.launch_s(plan) if callable(self.launch_s) else self.launch_s
        self.clock.advance(dt)
        self.launches.append(SimLaunch(
            seeds=[r.seed for r in plan.requests],
            slots=plan.slots,
            signature=plan.signature,
            start_s=t0,
            end_s=self.clock(),
        ))
        return [
            SimResult(seed=r.seed, workload_names=r.ws.names,
                      priority=r.priority)
            for r in plan.requests
        ]


@dataclasses.dataclass
class SimFault:
    """One recorded FaultyEngine fault (a launch that did NOT complete)."""

    kind: str  # "fail" | "nan"
    start_s: float
    seeds: List[int]


class FaultyEngine(StubEngine):
    """StubEngine with scripted fault injection — the zero-XLA twin of
    the segmented engine's failure modes, driven on the virtual clock.

    ``script`` is consumed one entry per ``execute`` call, in launch
    order (exhausted script -> "ok"):

      * ``"ok"``           — normal launch (``launch_s`` duration)
      * ``"fail"``         — the launch dies after ``fail_s`` virtual
        seconds with an ``EngineFault``
      * ``"nan"``          — the per-launch NaN score guard fires
        (``NonFiniteScoreError``)
      * ``("slow", dt)``   — a normal launch taking ``dt`` seconds

    ``poison_seeds``: any launch containing one of these request seeds
    fails with the NaN guard REGARDLESS of the script — a persistently
    poisoned request, the quarantine scenario: it keeps failing every
    chunk it rides in until the service isolates and quarantines it.

    ``partials=True`` attaches per-request anytime ``SimResult``s
    (``partial=True``) to every raised fault, mirroring
    ``EngineFault.partials`` from the real segmented engine."""

    def __init__(self, clock, *, script: Sequence = (), fail_s: float = 0.1,
                 poison_seeds: Sequence[int] = (), partials: bool = True, **kw):
        super().__init__(clock, **kw)
        self.script = list(script)
        self._cursor = 0
        self.fail_s = float(fail_s)
        self.poison_seeds = set(poison_seeds)
        self.partials = partials
        self.faults: List[SimFault] = []

    def _next_behavior(self):
        if self._cursor < len(self.script):
            b = self.script[self._cursor]
            self._cursor += 1
            return b if isinstance(b, tuple) else (b,)
        return ("ok",)

    def _raise_fault(self, kind: str, plan: BatchPlan):
        t0 = self.clock()
        self.clock.advance(self.fail_s)
        self.faults.append(SimFault(
            kind=kind, start_s=t0, seeds=[r.seed for r in plan.requests]))
        partials = None
        if self.partials:
            partials = [
                SimResult(seed=r.seed, workload_names=r.ws.names,
                          priority=r.priority, partial=True)
                for r in plan.requests
            ]
        cls = NonFiniteScoreError if kind == "nan" else EngineFault
        raise cls(f"injected {kind} at t={t0}", partials=partials)

    def execute(self, plan: BatchPlan, *, mesh=None) -> List[SimResult]:
        if self.poison_seeds & {r.seed for r in plan.requests}:
            self._raise_fault("nan", plan)
        b = self._next_behavior()
        if b[0] in ("fail", "nan"):
            self._raise_fault(b[0], plan)
        if b[0] == "slow":
            return super().execute(plan, mesh=mesh, dt=float(b[1]))
        return super().execute(plan, mesh=mesh)


def sim_service(
    *,
    policy="fifo",
    max_slots: int = 4,
    launch_s: Union[float, Callable[[BatchPlan], float]] = 1.0,
    t0: float = 0.0,
    retry: Optional[RetryPolicy] = None,
    partial_results: bool = False,
    engine_cls=StubEngine,
    **engine_kw,
) -> Tuple[DSEService, VirtualClock, StubEngine]:
    """A service on the virtual clock.  ``engine_cls=FaultyEngine`` (plus
    its kwargs) wires in fault injection; ``sleep`` is the clock's own
    ``advance``, so drains wait out retry backoff in simulated time."""
    clock = VirtualClock(t0)
    stub = engine_cls(clock, max_slots=max_slots, launch_s=launch_s,
                      **engine_kw)
    svc = DSEService(engine=stub, policy=policy, clock=clock, retry=retry,
                     partial_results=partial_results, sleep=clock.advance)
    return svc, clock, stub


# --------------------------------------------------------------- scripting
# Event grammar (deterministic interleavings, executed in list order):
#   ("submit", SearchRequest)  -> enqueue; records the rid
#   ("advance", dt)            -> move the virtual clock
#   ("step",)                  -> one launch (no-op on an empty queue)
#   ("drain",)                 -> step until empty
Event = tuple


@dataclasses.dataclass
class SimTrace:
    """What a script produced, in order."""

    rids: List[int]  # rid per submit event, in script order
    completions: List[Tuple[int, SimResult, float]]  # (rid, result, t_done)

    def completion_order(self) -> List[int]:
        return [rid for rid, _, _ in self.completions]

    def result(self, rid: int) -> SimResult:
        return next(res for r, res, _ in self.completions if r == rid)

    def done_at(self, rid: int) -> float:
        return next(t for r, _, t in self.completions if r == rid)


def run_script(svc: DSEService, clock: VirtualClock,
               events: Sequence[Event]) -> SimTrace:
    trace = SimTrace(rids=[], completions=[])

    def record(done):
        for rid, res in done:
            trace.completions.append((rid, res, clock()))

    for ev in events:
        kind = ev[0]
        if kind == "submit":
            trace.rids.append(svc.submit(ev[1]))
        elif kind == "advance":
            clock.advance(ev[1])
        elif kind == "step":
            record(svc.step())
        elif kind == "drain":
            while svc.pending():
                record(svc.step())
        else:
            raise ValueError(f"unknown sim event {ev!r}")
    return trace


def submit_burst(
    svc: DSEService,
    n: int,
    *,
    priorities: Sequence[int] = (0,),
    deadlines_s: Sequence[Optional[float]] = (None,),
    seed0: int = 0,
) -> List[int]:
    """n sim requests cycling priorities/deadlines; returns rids."""
    pr = itertools.cycle(priorities)
    dl = itertools.cycle(deadlines_s)
    return [
        svc.submit(sim_request(seed0 + i, priority=next(pr),
                               deadline_s=next(dl)))
        for i in range(n)
    ]
