"""Distribution layer: sharding rules, EP/TP layouts, checkpoint, compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME, get_config
from repro.distributed import ctx as dist_ctx
from repro.distributed.compression import (
    compress,
    decompress,
    ef_init,
)
from repro.distributed.sharding import (
    LOGICAL_RULES,
    cache_spec,
    input_sharding,
    make_rules,
)
from repro.models import transformer
from repro.models.common import ParamDecl, param_specs


def _fake_mesh_rules(data=16, model=16, pod=None):
    sizes = {"data": data, "model": model}
    if pod:
        sizes["pod"] = pod
    rules = dict(LOGICAL_RULES)
    rules["_mesh_sizes"] = sizes
    return rules


# ------------------------------------------------------------- param layouts
def test_dense_2d_sharding():
    tmpl = transformer.param_template(get_config("qwen2-72b"))
    specs = param_specs(tmpl, _fake_mesh_rules())
    wq = specs["blocks"][0]["mixer"]["wq"]
    assert wq == P(None, "data", "model")  # (layers, embed, heads)
    emb = specs["embed"]
    assert emb == P("model", "data")  # (vocab, embed)


def test_moe_ep_layout_when_divisible():
    """qwen3: 128 experts % 16 == 0 -> EP primary layout."""
    tmpl = transformer.param_template(get_config("qwen3-moe-235b-a22b"))
    specs = param_specs(tmpl, _fake_mesh_rules())
    wg = specs["blocks"][0]["ffn"]["w_gate"]
    assert wg == P(None, "model", None, "data")  # (layers, E, d, f)


def test_moe_tp_fallback_when_indivisible():
    """mixtral: 8 experts % 16 != 0 -> whole-tuple alt layout."""
    tmpl = transformer.param_template(get_config("mixtral-8x7b"))
    specs = param_specs(tmpl, _fake_mesh_rules())
    wg = specs["blocks"][0]["ffn"]["w_gate"]
    assert wg == P(None, None, "data", "model")  # (layers, E, embed, moe_ff)


def test_alt_logical_stacking_preserved():
    d = ParamDecl((8, 4, 6), ("experts", None, "moe_ff_ep"),
                  alt_logical=("experts", "embed", "moe_ff"))
    from repro.models.transformer import _stack

    s = _stack({"w": d}, 3)["w"]
    assert s.alt_logical == ("layers", "experts", "embed", "moe_ff")


def test_indivisible_dims_fall_back_replicated():
    specs = param_specs(
        {"w": ParamDecl((6, 10), ("vocab", "embed"))}, _fake_mesh_rules(4, 4)
    )
    assert specs["w"] == P(None, None)  # 6 % 4 != 0, 10 % 4 != 0


# ------------------------------------------------------------- input sharding
def test_input_sharding_batch_divisibility():
    mesh_like = Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )
    cfg = get_config("llama3.2-1b")
    sh = input_sharding(cfg, SHAPES_BY_NAME["train_4k"], mesh_like)
    assert sh["inputs"] == P(("data",), None)


def test_cache_spec_structure_matches_template():
    mesh_like = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    for arch in ("llama3.2-1b", "jamba-v0.1-52b", "whisper-medium"):
        cfg = get_config(arch)
        shape = SHAPES_BY_NAME["decode_32k"]
        spec = cache_spec(cfg, shape, mesh_like)
        tmpl = transformer.cache_template(cfg, shape.global_batch, shape.seq_len)
        assert jax.tree.structure(spec) == jax.tree.structure(tmpl)


# ----------------------------------------------------------------- constrain
def test_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    y = dist_ctx.constrain(x, ("batch", None))
    assert y is x


def test_constrain_applies_on_mesh():
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    rules = make_rules(mesh)
    with dist_ctx.use_rules(mesh, rules):
        x = jnp.ones((4, 8))
        y = dist_ctx.constrain(x, ("batch", "seq"))
        assert y.shape == x.shape  # applied without error on 1-dev mesh


# ---------------------------------------------------------------- compression
def test_compress_roundtrip_bounded_error():
    key = jax.random.PRNGKey(0)
    g = {"a": jax.random.normal(key, (128,)), "b": jax.random.normal(key, (64,)) * 10}
    ef = ef_init(g)
    c, new_ef = compress(g, ef)
    deq = decompress(c)
    for k in g:
        scale = float(jnp.abs(g[k]).max()) / 127.0
        assert float(jnp.abs(deq[k] - g[k]).max()) <= scale * 0.51
        # error feedback carries exactly the quantization residual
        np.testing.assert_allclose(
            np.asarray(new_ef[k]), np.asarray(g[k] - deq[k]), atol=1e-6
        )


def test_error_feedback_unbiased_over_steps():
    """Sum of dequantized updates + final residual == sum of true grads."""
    key = jax.random.PRNGKey(1)
    total_true = jnp.zeros((32,))
    total_sent = jnp.zeros((32,))
    ef = {"g": jnp.zeros((32,))}
    for i in range(20):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (32,))}
        c, ef = compress(g, ef)
        deq = decompress(c)
        total_true += g["g"]
        total_sent += deq["g"]
    np.testing.assert_allclose(
        np.asarray(total_sent + ef["g"]), np.asarray(total_true), atol=1e-4
    )


def test_int8_payload_is_int8():
    g = {"w": jnp.ones((16,))}
    c, _ = compress(g, ef_init(g))
    assert c.q["w"].dtype == jnp.int8


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ck

    tree = {"w": jnp.arange(6.0).reshape(2, 3), "s": jnp.asarray(3)}
    ck.save(tmp_path, 10, tree)
    restored, step = ck.restore(tmp_path, tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_checkpoint_atomicity_ignores_uncommitted(tmp_path):
    from repro import checkpoint as ck

    tree = {"w": jnp.ones((2,))}
    ck.save(tmp_path, 1, tree)
    # simulate a crashed save: directory without the commit marker
    bad = tmp_path / "step_000000002"
    bad.mkdir()
    (bad / "MANIFEST.json").write_text("{}")
    assert ck.latest_step(tmp_path) == 1


def test_checkpoint_retention(tmp_path):
    from repro import checkpoint as ck

    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, tree, keep=2)
    from repro.checkpoint.store import committed_steps
    assert sorted(committed_steps(tmp_path)) == [4, 5]


def test_checkpoint_resharded_restore(tmp_path):
    from repro import checkpoint as ck
    from jax.sharding import NamedSharding

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    tree = {"w": jnp.arange(8.0).reshape(2, 4)}
    ck.save(tmp_path, 3, tree)
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, step = ck.restore_resharded(tmp_path, tree, sh)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
