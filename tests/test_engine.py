"""DSE engine + service: heterogeneous packing == per-request searches.

The acceptance bar for the request -> plan -> execute stack: a batch
mixing workload sets, objectives, areas, seeds and backends must return
BIT-IDENTICAL scores and top designs vs running each request alone
(``run_search``), including under the fake-8-device (search, population)
mesh, and a 256-request drain must compile at most 4 programs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core import ga as ga_mod
from repro.core.engine import (
    SearchEngine,
    SearchRequest,
    default_engine,
    plan_batch,
)
from repro.core.objectives import OBJECTIVES
from repro.core.search import run_search
from repro.serve.dse import AsyncDSEService, DSEService, paper_request_mix
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import _TABLES_MEMO, pack_workloads

POP, GENS = 16, 3


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _mixed_requests(ws, n, backend="table", pop=POP, gens=GENS, seed0=0):
    """n requests cycling subsets x objectives x areas x seeds."""
    subsets = [[0, 1, 2, 3], [0], [2], [1, 3], [3, 2, 1, 0], [0, 2]]
    areas = [150.0, 150.0, 120.0]
    return [
        SearchRequest(
            ws=ws.subset(subsets[i % len(subsets)]),
            objective=OBJECTIVES[i % len(OBJECTIVES)],
            area_constr=areas[i % len(areas)],
            seed=seed0 + i,
            backend=backend,
            pop_size=pop,
            generations=gens,
        )
        for i in range(n)
    ]


def _assert_matches_run_search(req, res):
    ref = run_search(
        req.prng_key(), req.ws, objective=req.objective,
        area_constr=req.area_constr, pop_size=req.pop_size,
        generations=req.generations, top_k=req.top_k, backend=req.backend,
    )
    np.testing.assert_array_equal(
        np.asarray(res.ga.scores), np.asarray(ref.ga.scores)
    )
    np.testing.assert_array_equal(res.top_scores, ref.top_scores)
    np.testing.assert_array_equal(res.top_genomes, ref.top_genomes)
    assert res.workload_names == ref.workload_names
    assert res.objective == ref.objective


# -------------------------------------------------------------- planning
def test_plan_batch_groups_by_signature(ws):
    reqs = _mixed_requests(ws, 6, backend="table")
    reqs += _mixed_requests(ws, 2, backend="table", pop=POP + 2)  # new pop
    # dense requests group by exact (W, L): two subsets of different W
    reqs += [SearchRequest(ws=ws.subset([0]), backend="jnp", pop_size=POP,
                           generations=GENS),
             SearchRequest(ws=ws.subset([0, 1]), backend="jnp", pop_size=POP,
                           generations=GENS)]
    plans = plan_batch(reqs)
    assert [len(p.requests) for p in plans] == [6, 2, 1, 1]
    # the table group ignores workload shape entirely; its chunk is padded
    # to the widest/deepest member
    assert plans[0].pad_w == 4 and plans[0].slots == 6
    assert {p.signature for p in plans[2:]} == {
        plans[2].signature, plans[3].signature
    }
    assert plans[2].signature != plans[3].signature


def test_plan_batch_chunks_large_groups(ws):
    reqs = _mixed_requests(ws, 150, backend="table")
    plans = plan_batch(reqs, max_slots=64)
    assert [p.slots for p in plans] == [64, 64, 64]
    assert [len(p.requests) for p in plans] == [64, 64, 22]
    assert sorted(i for p in plans for i in p.indices) == list(range(150))


def test_plan_batch_exact_fit_no_padding(ws):
    # a group that fits in one launch runs at its exact size (driver paths
    # like batched_search pay zero pad overhead)
    plans = plan_batch(_mixed_requests(ws, 20, backend="table"), max_slots=64)
    assert len(plans) == 1 and plans[0].slots == 20


def test_request_validation(ws):
    with pytest.raises(ValueError, match="objective"):
        SearchRequest(ws=ws, objective="nope").signature()
    with pytest.raises(ValueError, match="backend"):
        SearchRequest(ws=ws, backend="nope").signature()


def test_scheduling_fields_never_touch_the_signature(ws):
    """priority/deadline_s are scheduling metadata: they must not change
    which compiled program a request hits."""
    base = SearchRequest(ws=ws, backend="table", pop_size=POP,
                         generations=GENS)
    urgent = SearchRequest(ws=ws, backend="table", pop_size=POP,
                           generations=GENS, priority=0, deadline_s=0.5)
    lazy = SearchRequest(ws=ws, backend="table", pop_size=POP,
                         generations=GENS, priority=9)
    assert base.signature() == urgent.signature() == lazy.signature()


def test_plan_batch_priority_policy_orders_requests_and_plans(ws):
    reqs = [SearchRequest(ws=ws.subset([i % 4]), seed=i, backend="table",
                          pop_size=POP, generations=GENS, priority=5 - i)
            for i in range(6)]  # priorities 5,4,3,2,1,0
    plans = plan_batch(reqs, policy="priority", max_slots=2)
    flat = [i for p in plans for i in p.indices]
    assert flat == [5, 4, 3, 2, 1, 0]  # most urgent first, chunked 2 by 2
    assert sorted(flat) == list(range(6))  # exact partition
    # fifo on the same mix keeps submit order
    assert [i for p in plan_batch(reqs, max_slots=2) for i in p.indices] \
        == list(range(6))


def test_plan_batch_edf_policy_deadlines_first(ws):
    reqs = [
        SearchRequest(ws=ws, seed=0, backend="table", pop_size=POP,
                      generations=GENS),  # deadline-less -> last
        SearchRequest(ws=ws, seed=1, backend="table", pop_size=POP,
                      generations=GENS, deadline_s=9.0),
        SearchRequest(ws=ws, seed=2, backend="table", pop_size=POP,
                      generations=GENS, deadline_s=2.0),
    ]
    plans = plan_batch(reqs, policy="edf", max_slots=1)
    assert [p.indices[0] for p in plans] == [2, 1, 0]


def test_plan_batch_policy_keeps_chunk_shapes(ws):
    """A policy reorders requests across chunks but the (signature,
    slots) launch shapes — what decides compiled programs — are the
    fifo ones."""
    reqs = [dataclasses.replace(r, priority=i % 3)
            for i, r in enumerate(_mixed_requests(ws, 11, backend="table"))]
    shapes = lambda plans: sorted((p.signature, p.slots) for p in plans)  # noqa: E731
    fifo = shapes(plan_batch(reqs, max_slots=4))
    assert shapes(plan_batch(reqs, policy="priority", max_slots=4)) == fifo
    assert shapes(plan_batch(reqs, policy="edf", max_slots=4)) == fifo


def test_plan_batch_slot_hints_round_up_never_down(ws):
    reqs = _mixed_requests(ws, 3, backend="table")
    sig = reqs[0].signature()
    plans = plan_batch(reqs, max_slots=64, slot_hints={sig: 8})
    assert len(plans) == 1 and plans[0].slots == 8  # 3 real rounded up
    # a hint smaller than the natural size never shrinks the chunk
    plans = plan_batch(reqs, max_slots=64, slot_hints={sig: 2})
    assert [p.slots for p in plans] == [3]
    # a stale hint above max_slots is ignored
    plans = plan_batch(reqs, max_slots=2, slot_hints={sig: 8})
    assert [p.slots for p in plans] == [2, 2]


# ------------------------------------------------- heterogeneous parity
def test_heterogeneous_table_batch_matches_run_search(ws):
    reqs = _mixed_requests(ws, 8, backend="table")
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        _assert_matches_run_search(req, res)


def test_heterogeneous_dense_batch_matches_run_search(ws):
    # same (W, L) shape -> one dense group, mixed objectives/areas/seeds
    subsets = [[0, 1], [2, 3], [3, 0], [1, 2]]
    reqs = [
        SearchRequest(
            ws=ws.subset(subsets[i % 4]), objective=OBJECTIVES[i % 4],
            area_constr=[150.0, 100.0][i % 2], seed=i, backend="jnp",
            pop_size=POP, generations=GENS,
        )
        for i in range(6)
    ]
    assert len(plan_batch(reqs)) == 1
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        _assert_matches_run_search(req, res)


def test_mixed_backends_one_submission(ws):
    reqs = [
        SearchRequest(ws=ws, seed=0, backend="table", pop_size=POP,
                      generations=GENS),
        SearchRequest(ws=ws, seed=1, backend="jnp", pop_size=POP,
                      generations=GENS),
        SearchRequest(ws=ws.subset([1]), seed=2, backend="table",
                      pop_size=POP, generations=GENS),
    ]
    assert len(plan_batch(reqs)) == 2  # table group + dense group
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        _assert_matches_run_search(req, res)


def test_engine_run_preserves_request_order(ws):
    reqs = _mixed_requests(ws, 5, backend="table")
    reqs.insert(2, SearchRequest(ws=ws, seed=99, backend="jnp",
                                 pop_size=POP, generations=GENS))
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        assert res.workload_names == req.ws.names


def test_init_genomes_mixed_with_seeded(ws):
    """Requests with a caller init pack with seeded ones; the caller's
    array is copied (the GA donates), never consumed."""
    from repro.core.search import seed_population

    init = seed_population(jax.random.PRNGKey(7), ws, POP)
    reqs = [
        SearchRequest(ws=ws, seed=0, backend="table", pop_size=POP,
                      generations=2, init_genomes=init),
        SearchRequest(ws=ws, seed=1, backend="table", pop_size=POP,
                      generations=2),
    ]
    out = default_engine().run(reqs)
    assert len(out) == 2
    assert np.asarray(init).shape == (POP, init.shape[1])  # still readable
    ref = run_search(reqs[0].prng_key(), ws, pop_size=POP, generations=2,
                     backend="table", init_genomes=init)
    np.testing.assert_array_equal(
        np.asarray(out[0].ga.scores), np.asarray(ref.ga.scores)
    )


# --------------------------------------------------- acceptance: 256-mix
def test_256_requests_drain_through_at_most_4_programs(ws):
    """256 heterogeneous table-backend requests (mixed workload subsets,
    objectives, seeds) drain through <= 4 compiled search programs (one
    seeding jit + one GA jit entry in steady state), bit-identical to
    per-request ``run_search``."""
    pop, gens = 8, 2
    reqs = _mixed_requests(ws, 256, backend="table", pop=pop, gens=gens,
                           seed0=10_000)
    svc = DSEService()
    rids = svc.submit_all(reqs)
    n_ga0 = ga_mod._run_ga_batched_jit._cache_size()
    n_seed0 = engine_mod._seed_batched_jit._cache_size()
    results = svc.drain()
    new_programs = (
        ga_mod._run_ga_batched_jit._cache_size() - n_ga0
        + engine_mod._seed_batched_jit._cache_size() - n_seed0
    )
    assert new_programs <= 4, new_programs
    assert svc.stats.launches == 4  # 256 / 64 slots
    assert len(results) == 256 and set(rids) == set(results)
    # bit-identical spot checks across the whole mix (every 37th request
    # hits different subset/objective/area combinations)
    for i in range(0, 256, 37):
        _assert_matches_run_search(reqs[i], results[rids[i]])


# ----------------------------------------------------------- fingerprints
def test_fingerprint_content_keyed(ws):
    ws2 = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    assert ws2 is not ws and ws2.fingerprint() == ws.fingerprint()
    assert ws.subset([0]).fingerprint() != ws.fingerprint()
    assert ws.subset([0, 1]).fingerprint() == ws2.subset([0, 1]).fingerprint()


def test_tables_memo_hits_across_repacked_sets(ws):
    from repro.core import space
    from repro.imc.tech import TECH

    t1 = ws.tables()
    ws2 = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    assert ws2.tables() is t1  # content-keyed, not object-keyed
    assert (ws.fingerprint(), TECH, space.grid_token()) in _TABLES_MEMO


def test_engine_padded_table_cache_content_keyed(ws):
    eng = SearchEngine()
    r1 = SearchRequest(ws=ws.subset([0, 1]), backend="table")
    r2 = SearchRequest(ws=ws.subset([0, 1]), backend="table", seed=5)
    t1 = eng._padded_request_tables(r1, 4)
    t2 = eng._padded_request_tables(r2, 4)
    assert t1 is t2  # same fingerprint + pad width -> one padded copy
    assert t1[0].shape[0] == 4  # demand leaf padded W 2 -> 4
    np.testing.assert_array_equal(t1[0][2:], 0.0)


# -------------------------------------------------------------- service
def test_service_interleaved_submit_and_step(ws):
    svc = DSEService()
    first = svc.submit_all(_mixed_requests(ws, 3, pop=8, gens=2))
    done1 = svc.step()
    assert {rid for rid, _ in done1} == set(first)
    # a request submitted after the first step joins the next plan
    late = svc.submit(SearchRequest(ws=ws.subset([1]), seed=42,
                                    backend="table", pop_size=8,
                                    generations=2))
    assert svc.pending() == 1
    done2 = svc.step()
    assert [rid for rid, _ in done2] == [late]
    assert svc.pending() == 0 and svc.step() == []
    assert svc.stats.completed == 4 and svc.stats.launches == 2


def test_service_ragged_drain_keeps_padded_tail_program(ws):
    """A drain whose group size is not a multiple of the slot count must
    execute the ORIGINAL padded-tail chunking (one compiled program per
    group), not re-plan the shrunken residue into a fresh program shape
    each step."""
    svc = DSEService(max_slots=4)
    reqs = [SearchRequest(ws=ws, seed=100 + i, backend="table", pop_size=8,
                          generations=2) for i in range(6)]
    rids = svc.submit_all(reqs)
    # warm the 4-slot program shape so only NEW shapes would compile below
    pre = SearchEngine(max_slots=4)
    pre.run(reqs[:4])
    n_ga0 = ga_mod._run_ga_batched_jit._cache_size()
    n_seed0 = engine_mod._seed_batched_jit._cache_size()
    results = svc.drain()
    assert len(results) == 6 and svc.stats.launches == 2  # 4 + padded 2
    new = (ga_mod._run_ga_batched_jit._cache_size() - n_ga0
           + engine_mod._seed_batched_jit._cache_size() - n_seed0)
    assert new == 0, f"ragged tail compiled {new} extra program(s)"
    for req, rid in zip(reqs, rids):
        _assert_matches_run_search(req, results[rid])


def test_service_mid_drain_submit_zero_new_programs(ws):
    """Submitting WHILE plans are cached (mid-drain) must not compile:
    the re-planned residue rounds up to the signature's warm slot size
    (the service's slot hints), so the ragged tail and the post-submit
    chunk both reuse the 4-slot program — and every rid still maps to
    the result of its OWN request."""
    svc = DSEService(max_slots=4)
    reqs = [SearchRequest(ws=ws, seed=200 + i, backend="table", pop_size=8,
                          generations=2) for i in range(6)]
    rids = svc.submit_all(reqs)
    # warm the 4-slot program shape so only NEW shapes would compile below
    SearchEngine(max_slots=4).run(reqs[:4])
    n_ga0 = ga_mod._run_ga_batched_jit._cache_size()
    n_seed0 = engine_mod._seed_batched_jit._cache_size()
    svc.step()  # launch 1 of the cached [4, padded-2] plan
    late = SearchRequest(ws=ws.subset([1, 2]), seed=777, backend="table",
                         pop_size=8, generations=2)
    rids.append(svc.submit(late))  # invalidates the cache: 2 + 1 remain
    reqs.append(late)
    results = svc.drain()
    assert svc.stats.launches == 2  # 4 real, then 3 real in the 4-slot shape
    new = (ga_mod._run_ga_batched_jit._cache_size() - n_ga0
           + engine_mod._seed_batched_jit._cache_size() - n_seed0)
    assert new == 0, f"mid-drain submit compiled {new} extra program(s)"
    for req, rid in zip(reqs, rids):
        _assert_matches_run_search(req, results[rid])


def _mixed_priority_requests(ws, n, pop=8, gens=2, seed0=0):
    """Mixed subsets/objectives/seeds AND priorities 1..7 (never 0, so a
    later priority-0 submit is uniquely the most urgent)."""
    reqs = _mixed_requests(ws, n, backend="table", pop=pop, gens=gens,
                           seed0=seed0)
    return [dataclasses.replace(r, priority=1 + i % 7)
            for i, r in enumerate(reqs)]


# ----------------------------------------- acceptance: async mixed-priority
def test_async_drain_bit_identical_to_sync_with_priority_jump(ws):
    """256 mixed-priority requests drained through AsyncDSEService are
    bit-identical to the synchronous DSEService drain of the same mix,
    AND a priority-0 request submitted mid-drain (from the first launch's
    future callback — which runs on the worker thread BEFORE the next
    dispatch, so the schedule is deterministic) launches before the
    lower-priority work that is still queued."""
    n = 256
    sync_svc = DSEService(policy="priority")
    sync_rids = sync_svc.submit_all(_mixed_priority_requests(ws, n))
    sync_res = sync_svc.drain()

    async_svc = AsyncDSEService(policy="priority", paused=True)
    reqs = _mixed_priority_requests(ws, n)
    jump_req = SearchRequest(ws=ws.subset([0]), seed=31337, backend="table",
                             pop_size=8, generations=2, priority=0)
    jump: dict = {}

    def submit_urgent(_fut):
        if not jump:  # first completed future only
            jump["fut"] = async_svc.submit(jump_req)

    futs = async_svc.submit_all(reqs)
    for f in futs:
        f.add_done_callback(submit_urgent)
    async_svc.resume()
    results = async_svc.drain(timeout=600)
    async_svc.close()

    # --- the priority-0 jump: submitted after launch 1, launched next
    assert "fut" in jump
    jump_rid = jump["fut"].rid
    jump_launch = next(i for i, l in enumerate(async_svc.launch_log)
                       if jump_rid in l)
    assert jump_launch == 1, async_svc.launch_log
    later = [rid for l in async_svc.launch_log[2:] for rid in l]
    assert later, "nothing queued behind the urgent request"
    by_rid = dict(zip([f.rid for f in futs], reqs))
    assert all(by_rid[rid].priority > 0 for rid in later)

    # --- bit-identical to the synchronous drain of the same mix
    assert len(results) == n + 1
    for f, sync_rid, req in zip(futs, sync_rids, reqs):
        a, s = f.result(), sync_res[sync_rid]
        np.testing.assert_array_equal(np.asarray(a.ga.scores),
                                      np.asarray(s.ga.scores))
        np.testing.assert_array_equal(a.top_scores, s.top_scores)
        np.testing.assert_array_equal(a.top_genomes, s.top_genomes)
        assert a.workload_names == req.ws.names
    assert np.isfinite(jump["fut"].result().top_scores).all()
    # latency telemetry recorded for every request
    assert len(async_svc.stats.latency_samples) == n + 1
    assert len(async_svc.stats.wait_samples) == n + 1


def test_async_submit_returns_future_without_blocking(ws):
    with AsyncDSEService() as svc:
        fut = svc.submit(SearchRequest(ws=ws.subset([0]), seed=5,
                                       backend="table", pop_size=8,
                                       generations=2))
        res = fut.result(timeout=300)
    _assert_matches_run_search(
        SearchRequest(ws=ws.subset([0]), seed=5, backend="table",
                      pop_size=8, generations=2), res)
    assert svc.stats.completed == 1


def test_service_stream_yields_all(ws):
    svc = DSEService()
    rids = svc.submit_all(_mixed_requests(ws, 4, pop=8, gens=2))
    seen = [rid for rid, _ in svc.stream()]
    assert sorted(seen) == sorted(rids)
    assert all(len(svc.results[r].top_scores) >= 0 for r in rids)


def test_paper_request_mix_covers_all_kinds(ws):
    reqs = paper_request_mix(ws, 16, pop_size=8, generations=2)
    assert {r.objective for r in reqs} == set(OBJECTIVES)
    assert len({r.ws.names for r in reqs}) > 1
    assert len({r.seed for r in reqs}) == 16


# ------------------------------------------------------------- multidevice
@pytest.mark.multidevice
def test_heterogeneous_batch_sharded_parity(ws):
    """The packed heterogeneous drain on a (search, population) mesh is
    bit-identical to the meshless engine AND to per-request run_search."""
    from repro.core.distributed import sharded_search_engine
    from repro.launch.mesh import make_search_mesh

    reqs = _mixed_requests(ws, 8, backend="table")
    eng = sharded_search_engine(make_search_mesh(2, 4))
    out = eng.run(reqs)
    ref = SearchEngine().run(reqs)
    for req, s, r in zip(reqs, out, ref):
        np.testing.assert_array_equal(
            np.asarray(s.ga.scores), np.asarray(r.ga.scores)
        )
        np.testing.assert_array_equal(s.top_genomes, r.top_genomes)
        _assert_matches_run_search(req, s)


@pytest.mark.multidevice
def test_service_on_mesh(ws):
    # (2, 4) mirrors the table-backend layouts the sharded parity suite
    # pins; the full (incl. (4,2)-ragged) envelope characterization lives
    # in tests/test_search_sharded.py::test_table_backend_sharded_parity_
    # envelope.
    from repro.launch.mesh import make_search_mesh

    svc = DSEService(mesh=make_search_mesh(2, 4))
    reqs = _mixed_requests(ws, 6, pop=8, gens=2)
    rids = svc.submit_all(reqs)
    results = svc.drain()
    assert set(rids) == set(results)
    for rid, req in zip(rids, reqs):
        _assert_matches_run_search(req, results[rid])
