"""DSE engine + service: heterogeneous packing == per-request searches.

The acceptance bar for the request -> plan -> execute stack: a batch
mixing workload sets, objectives, areas, seeds and backends must return
BIT-IDENTICAL scores and top designs vs running each request alone
(``run_search``), including under the fake-8-device (search, population)
mesh, and a 256-request drain must compile at most 4 programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core import ga as ga_mod
from repro.core.engine import (
    SearchEngine,
    SearchRequest,
    default_engine,
    plan_batch,
)
from repro.core.objectives import OBJECTIVES
from repro.core.search import run_search
from repro.serve.dse import DSEService, paper_request_mix
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import _TABLES_MEMO, pack_workloads

POP, GENS = 16, 3


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _mixed_requests(ws, n, backend="table", pop=POP, gens=GENS, seed0=0):
    """n requests cycling subsets x objectives x areas x seeds."""
    subsets = [[0, 1, 2, 3], [0], [2], [1, 3], [3, 2, 1, 0], [0, 2]]
    areas = [150.0, 150.0, 120.0]
    return [
        SearchRequest(
            ws=ws.subset(subsets[i % len(subsets)]),
            objective=OBJECTIVES[i % len(OBJECTIVES)],
            area_constr=areas[i % len(areas)],
            seed=seed0 + i,
            backend=backend,
            pop_size=pop,
            generations=gens,
        )
        for i in range(n)
    ]


def _assert_matches_run_search(req, res):
    ref = run_search(
        req.prng_key(), req.ws, objective=req.objective,
        area_constr=req.area_constr, pop_size=req.pop_size,
        generations=req.generations, top_k=req.top_k, backend=req.backend,
    )
    np.testing.assert_array_equal(
        np.asarray(res.ga.scores), np.asarray(ref.ga.scores)
    )
    np.testing.assert_array_equal(res.top_scores, ref.top_scores)
    np.testing.assert_array_equal(res.top_genomes, ref.top_genomes)
    assert res.workload_names == ref.workload_names
    assert res.objective == ref.objective


# -------------------------------------------------------------- planning
def test_plan_batch_groups_by_signature(ws):
    reqs = _mixed_requests(ws, 6, backend="table")
    reqs += _mixed_requests(ws, 2, backend="table", pop=POP + 2)  # new pop
    # dense requests group by exact (W, L): two subsets of different W
    reqs += [SearchRequest(ws=ws.subset([0]), backend="jnp", pop_size=POP,
                           generations=GENS),
             SearchRequest(ws=ws.subset([0, 1]), backend="jnp", pop_size=POP,
                           generations=GENS)]
    plans = plan_batch(reqs)
    assert [len(p.requests) for p in plans] == [6, 2, 1, 1]
    # the table group ignores workload shape entirely; its chunk is padded
    # to the widest/deepest member
    assert plans[0].pad_w == 4 and plans[0].slots == 6
    assert {p.signature for p in plans[2:]} == {
        plans[2].signature, plans[3].signature
    }
    assert plans[2].signature != plans[3].signature


def test_plan_batch_chunks_large_groups(ws):
    reqs = _mixed_requests(ws, 150, backend="table")
    plans = plan_batch(reqs, max_slots=64)
    assert [p.slots for p in plans] == [64, 64, 64]
    assert [len(p.requests) for p in plans] == [64, 64, 22]
    assert sorted(i for p in plans for i in p.indices) == list(range(150))


def test_plan_batch_exact_fit_no_padding(ws):
    # a group that fits in one launch runs at its exact size (driver paths
    # like batched_search pay zero pad overhead)
    plans = plan_batch(_mixed_requests(ws, 20, backend="table"), max_slots=64)
    assert len(plans) == 1 and plans[0].slots == 20


def test_request_validation(ws):
    with pytest.raises(ValueError, match="objective"):
        SearchRequest(ws=ws, objective="nope").signature()
    with pytest.raises(ValueError, match="backend"):
        SearchRequest(ws=ws, backend="nope").signature()


# ------------------------------------------------- heterogeneous parity
def test_heterogeneous_table_batch_matches_run_search(ws):
    reqs = _mixed_requests(ws, 8, backend="table")
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        _assert_matches_run_search(req, res)


def test_heterogeneous_dense_batch_matches_run_search(ws):
    # same (W, L) shape -> one dense group, mixed objectives/areas/seeds
    subsets = [[0, 1], [2, 3], [3, 0], [1, 2]]
    reqs = [
        SearchRequest(
            ws=ws.subset(subsets[i % 4]), objective=OBJECTIVES[i % 4],
            area_constr=[150.0, 100.0][i % 2], seed=i, backend="jnp",
            pop_size=POP, generations=GENS,
        )
        for i in range(6)
    ]
    assert len(plan_batch(reqs)) == 1
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        _assert_matches_run_search(req, res)


def test_mixed_backends_one_submission(ws):
    reqs = [
        SearchRequest(ws=ws, seed=0, backend="table", pop_size=POP,
                      generations=GENS),
        SearchRequest(ws=ws, seed=1, backend="jnp", pop_size=POP,
                      generations=GENS),
        SearchRequest(ws=ws.subset([1]), seed=2, backend="table",
                      pop_size=POP, generations=GENS),
    ]
    assert len(plan_batch(reqs)) == 2  # table group + dense group
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        _assert_matches_run_search(req, res)


def test_engine_run_preserves_request_order(ws):
    reqs = _mixed_requests(ws, 5, backend="table")
    reqs.insert(2, SearchRequest(ws=ws, seed=99, backend="jnp",
                                 pop_size=POP, generations=GENS))
    out = default_engine().run(reqs)
    for req, res in zip(reqs, out):
        assert res.workload_names == req.ws.names


def test_init_genomes_mixed_with_seeded(ws):
    """Requests with a caller init pack with seeded ones; the caller's
    array is copied (the GA donates), never consumed."""
    from repro.core.search import seed_population

    init = seed_population(jax.random.PRNGKey(7), ws, POP)
    reqs = [
        SearchRequest(ws=ws, seed=0, backend="table", pop_size=POP,
                      generations=2, init_genomes=init),
        SearchRequest(ws=ws, seed=1, backend="table", pop_size=POP,
                      generations=2),
    ]
    out = default_engine().run(reqs)
    assert len(out) == 2
    assert np.asarray(init).shape == (POP, init.shape[1])  # still readable
    ref = run_search(reqs[0].prng_key(), ws, pop_size=POP, generations=2,
                     backend="table", init_genomes=init)
    np.testing.assert_array_equal(
        np.asarray(out[0].ga.scores), np.asarray(ref.ga.scores)
    )


# --------------------------------------------------- acceptance: 256-mix
def test_256_requests_drain_through_at_most_4_programs(ws):
    """256 heterogeneous table-backend requests (mixed workload subsets,
    objectives, seeds) drain through <= 4 compiled search programs (one
    seeding jit + one GA jit entry in steady state), bit-identical to
    per-request ``run_search``."""
    pop, gens = 8, 2
    reqs = _mixed_requests(ws, 256, backend="table", pop=pop, gens=gens,
                           seed0=10_000)
    svc = DSEService()
    rids = svc.submit_all(reqs)
    n_ga0 = ga_mod._run_ga_batched_jit._cache_size()
    n_seed0 = engine_mod._seed_batched_jit._cache_size()
    results = svc.drain()
    new_programs = (
        ga_mod._run_ga_batched_jit._cache_size() - n_ga0
        + engine_mod._seed_batched_jit._cache_size() - n_seed0
    )
    assert new_programs <= 4, new_programs
    assert svc.stats.launches == 4  # 256 / 64 slots
    assert len(results) == 256 and set(rids) == set(results)
    # bit-identical spot checks across the whole mix (every 37th request
    # hits different subset/objective/area combinations)
    for i in range(0, 256, 37):
        _assert_matches_run_search(reqs[i], results[rids[i]])


# ----------------------------------------------------------- fingerprints
def test_fingerprint_content_keyed(ws):
    ws2 = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    assert ws2 is not ws and ws2.fingerprint() == ws.fingerprint()
    assert ws.subset([0]).fingerprint() != ws.fingerprint()
    assert ws.subset([0, 1]).fingerprint() == ws2.subset([0, 1]).fingerprint()


def test_tables_memo_hits_across_repacked_sets(ws):
    from repro.imc.tech import TECH

    t1 = ws.tables()
    ws2 = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    assert ws2.tables() is t1  # content-keyed, not object-keyed
    assert (ws.fingerprint(), TECH) in _TABLES_MEMO


def test_engine_padded_table_cache_content_keyed(ws):
    eng = SearchEngine()
    r1 = SearchRequest(ws=ws.subset([0, 1]), backend="table")
    r2 = SearchRequest(ws=ws.subset([0, 1]), backend="table", seed=5)
    t1 = eng._padded_request_tables(r1, 4)
    t2 = eng._padded_request_tables(r2, 4)
    assert t1 is t2  # same fingerprint + pad width -> one padded copy
    assert t1[0].shape[0] == 4  # demand leaf padded W 2 -> 4
    np.testing.assert_array_equal(t1[0][2:], 0.0)


# -------------------------------------------------------------- service
def test_service_interleaved_submit_and_step(ws):
    svc = DSEService()
    first = svc.submit_all(_mixed_requests(ws, 3, pop=8, gens=2))
    done1 = svc.step()
    assert {rid for rid, _ in done1} == set(first)
    # a request submitted after the first step joins the next plan
    late = svc.submit(SearchRequest(ws=ws.subset([1]), seed=42,
                                    backend="table", pop_size=8,
                                    generations=2))
    assert svc.pending() == 1
    done2 = svc.step()
    assert [rid for rid, _ in done2] == [late]
    assert svc.pending() == 0 and svc.step() == []
    assert svc.stats.completed == 4 and svc.stats.launches == 2


def test_service_ragged_drain_keeps_padded_tail_program(ws):
    """A drain whose group size is not a multiple of the slot count must
    execute the ORIGINAL padded-tail chunking (one compiled program per
    group), not re-plan the shrunken residue into a fresh program shape
    each step."""
    svc = DSEService(max_slots=4)
    reqs = [SearchRequest(ws=ws, seed=100 + i, backend="table", pop_size=8,
                          generations=2) for i in range(6)]
    rids = svc.submit_all(reqs)
    # warm the 4-slot program shape so only NEW shapes would compile below
    pre = SearchEngine(max_slots=4)
    pre.run(reqs[:4])
    n_ga0 = ga_mod._run_ga_batched_jit._cache_size()
    n_seed0 = engine_mod._seed_batched_jit._cache_size()
    results = svc.drain()
    assert len(results) == 6 and svc.stats.launches == 2  # 4 + padded 2
    new = (ga_mod._run_ga_batched_jit._cache_size() - n_ga0
           + engine_mod._seed_batched_jit._cache_size() - n_seed0)
    assert new == 0, f"ragged tail compiled {new} extra program(s)"
    for req, rid in zip(reqs, rids):
        _assert_matches_run_search(req, results[rid])


def test_service_stream_yields_all(ws):
    svc = DSEService()
    rids = svc.submit_all(_mixed_requests(ws, 4, pop=8, gens=2))
    seen = [rid for rid, _ in svc.stream()]
    assert sorted(seen) == sorted(rids)
    assert all(len(svc.results[r].top_scores) >= 0 for r in rids)


def test_paper_request_mix_covers_all_kinds(ws):
    reqs = paper_request_mix(ws, 16, pop_size=8, generations=2)
    assert {r.objective for r in reqs} == set(OBJECTIVES)
    assert len({r.ws.names for r in reqs}) > 1
    assert len({r.seed for r in reqs}) == 16


# ------------------------------------------------------------- multidevice
@pytest.mark.multidevice
def test_heterogeneous_batch_sharded_parity(ws):
    """The packed heterogeneous drain on a (search, population) mesh is
    bit-identical to the meshless engine AND to per-request run_search."""
    from repro.core.distributed import sharded_search_engine
    from repro.launch.mesh import make_search_mesh

    reqs = _mixed_requests(ws, 8, backend="table")
    eng = sharded_search_engine(make_search_mesh(2, 4))
    out = eng.run(reqs)
    ref = SearchEngine().run(reqs)
    for req, s, r in zip(reqs, out, ref):
        np.testing.assert_array_equal(
            np.asarray(s.ga.scores), np.asarray(r.ga.scores)
        )
        np.testing.assert_array_equal(s.top_genomes, r.top_genomes)
        _assert_matches_run_search(req, s)


@pytest.mark.multidevice
def test_service_on_mesh(ws):
    # (2, 4) mirrors the table-backend layouts the sharded parity suite
    # pins (tests/test_search_sharded.py: (2,4)/(8,1)); a (4,2) mesh with
    # a ragged batch ULP-drifts the table path even on the PRE-engine
    # stack (static objective + argsort survival), so it is outside the
    # bit-parity envelope the repo has ever guaranteed.
    from repro.launch.mesh import make_search_mesh

    svc = DSEService(mesh=make_search_mesh(2, 4))
    reqs = _mixed_requests(ws, 6, pop=8, gens=2)
    rids = svc.submit_all(reqs)
    results = svc.drain()
    assert set(rids) == set(results)
    for rid, req in zip(rids, reqs):
        _assert_matches_run_search(req, results[rid])
