"""Deterministic fault drills for the DSE service (virtual clock, no XLA).

Driven entirely through tests/sim_scheduler.py's ``FaultyEngine``:
scripted launch failures, NaN-guard trips, persistently poisoned
requests and slow launches, all on the virtual clock — so every retry
delay, quarantine decision and partial resolution is an exact number.

The centrepiece is the ISSUE's acceptance drill: a 256-request mixed
drain with poisoned chunks, a scripted transient failure, a slow launch
and short-deadline stragglers completes with EVERY rid resolved, exact
failure/retry/partial/deadline counts in ``ServiceStats``, and no
deadlock or bookkeeping leak.  The async twin pins future resolution
(including exceptions and cancellation on close) with no future leak.
"""
import threading
import time

import pytest

from repro.core.engine import EngineFault
from repro.serve.dse import AsyncDSEService, DSEService, RetryPolicy
from sim_scheduler import (
    FaultyEngine,
    StubEngine,
    VirtualClock,
    sim_request,
    sim_service,
    submit_burst,
)


def _leak_free(svc: DSEService):
    """Every per-rid map and lane must be empty after a full drain."""
    assert svc.queue == [] and svc._retry_lane == []
    assert svc._attempts == {} and svc._partials == {}
    assert svc._submit_s == {} and svc._deadline_s == {}


# ----------------------------------------------------------- RetryPolicy math
def test_retry_policy_deterministic_backoff():
    p = RetryPolicy(max_attempts=5, backoff_s=1.0, multiplier=2.0,
                    max_backoff_s=5.0, jitter=0.1)
    for attempt in (1, 2, 3):
        base = min(1.0 * 2.0 ** (attempt - 1), 5.0)
        d = p.delay_s(attempt, rid=7)
        assert d == p.delay_s(attempt, rid=7)  # pure: replays identically
        assert base * 0.9 <= d <= base * 1.1  # within the jitter band
    # capped at max_backoff (+ jitter), and jitter varies with rid
    assert p.delay_s(10, rid=0) <= 5.0 * 1.1
    assert len({p.delay_s(1, rid=r) for r in range(8)}) > 1
    # jitter=0 is the exact exponential schedule
    q = RetryPolicy(backoff_s=0.5, multiplier=2.0, jitter=0.0)
    assert [q.delay_s(a) for a in (1, 2, 3)] == [0.5, 1.0, 2.0]


# ------------------------------------------------------------- retry recovery
def test_failed_launch_retries_each_request_alone():
    svc, clock, eng = sim_service(
        retry=RetryPolicy(max_attempts=2, backoff_s=0.5, jitter=0.0),
        engine_cls=FaultyEngine, script=["fail"],
    )
    rids = submit_burst(svc, 4)
    res = svc.drain()
    assert sorted(res) == rids
    assert all(res[r].seed == r and not res[r].partial for r in rids)
    st = svc.stats
    assert (st.failures, st.retries, st.partials, st.abandoned) == (4, 4, 0, 0)
    assert st.completed == 4 and svc.failed == {}
    # the chunk failed once; each rid then relaunched ALONE
    assert len(eng.faults) == 1 and eng.faults[0].seeds == rids
    assert [l.seeds for l in eng.launches] == [[r] for r in rids]
    # deterministic schedule: fail at t=0.1, jitter-free backoff 0.5 ->
    # first retry dispatches at exactly 0.6, then 1s per launch
    assert [l.start_s for l in eng.launches] == [0.6, 1.6, 2.6, 3.6]
    _leak_free(svc)


def test_backoff_schedule_matches_policy_exactly():
    pol = RetryPolicy(max_attempts=3, backoff_s=1.0, multiplier=2.0,
                      jitter=0.1)
    svc, clock, eng = sim_service(
        retry=pol, partial_results=True,
        engine_cls=FaultyEngine, poison_seeds=[0],
    )
    (rid,) = submit_burst(svc, 1)
    res = svc.drain()
    # every attempt failed -> quarantined with its anytime partial
    assert res[rid].partial and res[rid].seed == rid
    st = svc.stats
    assert (st.failures, st.retries, st.partials) == (3, 2, 1)
    assert st.completed == 1 and st.abandoned == 0
    # fault start times = the policy's exact jittered schedule: each
    # attempt dies 0.1s in, the next starts delay_s(attempt, rid) later
    t1 = 0.1 + pol.delay_s(1, rid)
    t2 = t1 + 0.1 + pol.delay_s(2, rid)
    assert [f.start_s for f in eng.faults] == [0.0, t1, t2]
    _leak_free(svc)


def test_poisoned_request_is_quarantined_chunk_mates_recover():
    svc, clock, eng = sim_service(
        retry=RetryPolicy(max_attempts=2, backoff_s=0.5, jitter=0.0),
        partial_results=True, engine_cls=FaultyEngine, poison_seeds=[2],
    )
    rids = submit_burst(svc, 4)
    res = svc.drain()
    # chunk fails once (4 failures); isolated retries: 3 clean full
    # results + the poisoned one fails again (5th failure) -> quarantined
    st = svc.stats
    assert (st.failures, st.retries, st.partials) == (5, 4, 1)
    assert st.completed == 4 and st.abandoned == 0
    for r in rids:
        assert res[r].seed == r
        assert res[r].partial == (r == 2)
    # the poisoned rid only ever failed its own isolated launch after the
    # first chunk - its chunk-mates never saw a second failure
    assert [sorted(f.seeds) for f in eng.faults] == [[0, 1, 2, 3], [2]]
    _leak_free(svc)


def test_retry_exhaustion_without_partials_abandons():
    svc, clock, eng = sim_service(
        retry=RetryPolicy(max_attempts=2, backoff_s=0.5, jitter=0.0),
        partial_results=False, engine_cls=FaultyEngine, poison_seeds=[1],
    )
    rids = submit_burst(svc, 2)
    res = svc.drain()
    assert sorted(res) == [0] and res[0].seed == 0
    assert 1 in svc.failed and isinstance(svc.failed[1], EngineFault)
    st = svc.stats
    assert (st.failures, st.retries, st.partials, st.abandoned) == (3, 2, 0, 1)
    assert st.completed == 1
    _leak_free(svc)


# ------------------------------------------------------------ deadline sweeps
def test_expired_queued_request_resolves_partial():
    svc, clock, eng = sim_service(partial_results=True)
    rid_late = svc.submit(sim_request(0, deadline_s=0.5))
    rid_ok = svc.submit(sim_request(1))
    clock.advance(1.0)  # rid_late expires before any launch
    done = svc.step()
    # one step returns BOTH the swept partial and the launched result
    assert sorted(r for r, _ in done) == [rid_late, rid_ok]
    res = dict(done)
    assert res[rid_late].partial and not res[rid_ok].partial
    st = svc.stats
    assert st.deadline_misses == 1 and st.partials == 1 and st.completed == 2
    assert eng.launches[0].seeds == [1]  # the expired rid never launched
    _leak_free(svc)


def test_expired_retry_lane_request_is_swept():
    svc, clock, eng = sim_service(
        retry=RetryPolicy(max_attempts=3, backoff_s=10.0, jitter=0.0),
        partial_results=True, engine_cls=FaultyEngine, script=["fail"],
    )
    (rid,) = [svc.submit(sim_request(0, deadline_s=2.0))]
    svc.step()  # fails; retry parked until t=10.1 > deadline
    clock.advance(5.0)
    done = svc.step()  # sweep fires before any dispatch
    assert [r for r, _ in done] == [rid] and done[0][1].partial
    st = svc.stats
    assert st.deadline_misses == 1 and st.partials == 1
    assert (st.failures, st.retries) == (1, 1)
    _leak_free(svc)


def test_without_partial_results_no_sweep():
    # graceful degradation is opt-in: the default service still completes
    # late requests fully (and only counts the miss)
    svc, clock, eng = sim_service()
    rid = svc.submit(sim_request(0, deadline_s=0.5))
    clock.advance(1.0)
    res = svc.drain()
    assert not res[rid].partial and svc.stats.deadline_misses == 1
    assert svc.stats.partials == 0


# ------------------------------------------------- acceptance: 256-mix drill
def test_256_request_fault_drill_exact_accounting():
    """The ISSUE's deterministic fault drill: 256 fifo requests in 16-slot
    chunks; 3 poisoned seeds in distinct chunks, one scripted transient
    chunk failure, one slow launch, 4 short-deadline stragglers.  The
    drain must terminate with every rid resolved and exact stats."""
    pol = RetryPolicy(max_attempts=2, backoff_s=0.25, multiplier=2.0,
                      jitter=0.1)
    svc, clock, eng = sim_service(
        max_slots=16, retry=pol, partial_results=True,
        engine_cls=FaultyEngine,
        poison_seeds=[5, 37, 101],  # chunks 0, 2 and 6
        script=["fail", ("slow", 5.0)],  # chunk 1 dies once, chunk 3 crawls
    )
    rids = submit_burst(svc, 252)
    rids += [svc.submit(sim_request(252 + i, deadline_s=0.5))
             for i in range(4)]
    res = svc.drain()

    # every rid resolved, none abandoned, and the drain terminated
    assert sorted(res) == rids and svc.failed == {}
    st = svc.stats
    assert st.submitted == 256 and st.completed == 256 and st.abandoned == 0
    # failures: 4 chunk failures (3 poisoned + 1 scripted) x 16 rids,
    # plus the 3 poisoned isolated retries
    assert st.failures == 4 * 16 + 3
    # retries: every rid of a failed chunk got exactly one (max_attempts=2)
    assert st.retries == 4 * 16
    # partials: 3 quarantined poisoned rids + 4 deadline-swept stragglers
    assert st.partials == 7
    assert st.deadline_misses == 4
    # launches (successes only): 12 clean chunks + 61 isolated retries
    # (16 from the scripted chunk + 15 clean per poisoned chunk)
    assert st.launches == 12 + 16 + 3 * 15
    # fault log: 4 chunk-sized faults + 3 single-rid (isolated) faults
    assert sorted(len(f.seeds) for f in eng.faults) == [1, 1, 1, 16, 16, 16, 16]
    # partial vs full results land exactly where the drill says
    partial_rids = {5, 37, 101, 252, 253, 254, 255}
    for r in rids:
        assert res[r].partial == (r in partial_rids), r
        if r not in (252, 253, 254, 255):  # swept rids resolve empty
            assert res[r].seed == r
    # the deadline stragglers never launched
    launched = {s for l in eng.launches for s in l.seeds}
    assert launched.isdisjoint({252, 253, 254, 255})
    # telemetry samples stayed consistent (one wait + one latency per rid)
    assert len(st.wait_samples) == 256 and len(st.latency_samples) == 256
    _leak_free(svc)


# ------------------------------------------------------------------- async
def _async_sim(**kw):
    clock = VirtualClock()
    eng_kw = {k: kw.pop(k) for k in ("script", "poison_seeds", "max_slots")
              if k in kw}
    eng = FaultyEngine(clock, **eng_kw)
    svc = AsyncDSEService(engine=eng, clock=clock, paused=True, **kw)
    return svc, clock, eng


def test_async_retry_resolves_futures():
    svc, clock, eng = _async_sim(
        script=["fail"], max_slots=4,
        retry=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0),
    )
    futs = [svc.submit(sim_request(i)) for i in range(4)]
    svc.resume()
    svc.drain(timeout=60)
    assert [f.result(timeout=1).seed for f in futs] == [0, 1, 2, 3]
    assert all(not f.result().partial for f in futs)
    st = svc.stats
    assert (st.failures, st.retries, st.completed) == (4, 4, 4)
    assert svc._futures == {}  # no future leak
    svc.close()


def test_async_quarantine_resolves_future_with_partial():
    svc, clock, eng = _async_sim(
        poison_seeds=[1], max_slots=4, partial_results=True,
        retry=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0),
    )
    futs = [svc.submit(sim_request(i)) for i in range(3)]
    svc.resume()
    svc.drain(timeout=60)
    assert [f.result(timeout=1).partial for f in futs] == [False, True, False]
    assert futs[1].result().seed == 1  # the anytime partial echoes its rid
    st = svc.stats
    assert (st.partials, st.abandoned, st.completed) == (1, 0, 3)
    assert svc._futures == {}
    svc.close()


def test_async_abandoned_requests_visible_in_stats():
    # no retry policy: a failed launch fails its futures AND is counted
    svc, clock, eng = _async_sim(script=["fail"], max_slots=4)
    futs = [svc.submit(sim_request(i)) for i in range(2)]
    svc.resume()
    svc.drain(timeout=60)
    for f in futs:
        with pytest.raises(EngineFault):
            f.result(timeout=1)
    assert svc.stats.abandoned == 2 and svc.stats.completed == 0
    # the service keeps serving after the failure
    ok = svc.submit(sim_request(9))
    assert ok.result(timeout=60).seed == 9
    assert "abandoned" in svc.stats.summary()
    svc.close()


def test_async_32_request_drill_no_future_leak():
    svc, clock, eng = _async_sim(
        poison_seeds=[3, 17], max_slots=8, partial_results=True,
        retry=RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0),
    )
    futs = [svc.submit(sim_request(i)) for i in range(32)]
    svc.resume()
    res = svc.drain(timeout=120)
    assert len(res) == 32 and svc._futures == {}
    for i, f in enumerate(futs):
        assert f.result(timeout=1).seed == i
        assert f.result().partial == (i in (3, 17))
    st = svc.stats
    # 2 poisoned chunks fail once each (8 rids), poisoned rids fail again
    assert (st.failures, st.retries, st.partials) == (2 * 8 + 2, 16, 2)
    assert st.completed == 32 and st.abandoned == 0
    _leak_free(svc.service)
    svc.close()


# ---------------------------------------------------------- close hardening
class _BlockingEngine(StubEngine):
    """Blocks every execute until ``release`` is set (bounded), so tests
    can hold a launch in flight across a close/drain deterministically."""

    def __init__(self, clock, release: threading.Event, **kw):
        super().__init__(clock, **kw)
        self.release = release

    def execute(self, plan, *, mesh=None):
        self.release.wait(10.0)
        return super().execute(plan, mesh=mesh)


def test_async_close_is_idempotent():
    svc, clock, eng = _async_sim(max_slots=4)
    svc.resume()
    fut = svc.submit(sim_request(0))
    assert fut.result(timeout=60).seed == 0
    svc.close()
    svc.close()  # second close: no-op, no hang
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(sim_request(1))


def test_async_close_while_in_flight_cancels_futures():
    clock = VirtualClock()
    release = threading.Event()
    eng = _BlockingEngine(clock, release, max_slots=4)
    svc = AsyncDSEService(engine=eng, clock=clock)
    fut = svc.submit(sim_request(0))
    time.sleep(0.05)  # let the worker enter the blocked launch
    threading.Timer(0.3, release.set).start()
    svc.close(timeout=0.1)  # drain cannot finish -> cancel, then join
    with pytest.raises(Exception) as ei:
        fut.result(timeout=1)
    assert ei.type.__name__ == "CancelledError"


def test_async_drain_timeout_names_unresolved_rids():
    clock = VirtualClock()
    release = threading.Event()
    eng = _BlockingEngine(clock, release, max_slots=4)
    svc = AsyncDSEService(engine=eng, clock=clock)
    fut = svc.submit(sim_request(0))
    with pytest.raises(TimeoutError, match=r"rids: \[0\]"):
        svc.drain(timeout=0.1)
    release.set()
    assert fut.result(timeout=10).seed == 0
    svc.close()
