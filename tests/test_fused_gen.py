"""Fused generation step, gen-step kernel, direct seeder, grid density.

The PR-8 fast path: ``core.ga`` fuses the survivor epilogue (one combined
``lax.sort``) and optionally the WHOLE generation into a single Pallas
kernel (``kernels.ga_gen_step``); the engine's ``direct_seed`` replaces
the rejection seeding rounds with an inverse-CDF sampler over the
feasible cells of the largest workload; ``space.configure_grid`` densifies
the hardware grid.  Everything here pins BIT-parity between the fast and
reference paths — the repo's invariant that a speedup must never change a
result bit (unless, like ``direct_seed``, it is explicitly opt-in).

NOTE on jit in the kernel-parity tests: both sides are compared as
COMPILED programs.  Eager op-by-op execution differs from any single
compiled program by 1 ULP on CPU (XLA contracts a*b+c into FMA when it
compiles the whole expression), so eager-vs-kernel is NOT the invariant —
jit-vs-kernel is, and ``run_ga`` always jits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ga, space
from repro.core.engine import (
    INDEXED,
    SearchEngine,
    SearchRequest,
    _ctx_eval,
    plan_batch,
)
from repro.core.search import batched_search, run_search, separate_search
from repro.imc.tables import build_tables_arrays, evaluate_genomes_tables, table_bytes
from repro.imc.tech import TECH
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _same_result(a, b):
    assert np.array_equal(np.asarray(a.ga.genomes), np.asarray(b.ga.genomes))
    assert np.array_equal(np.asarray(a.ga.scores), np.asarray(b.ga.scores))
    assert np.array_equal(np.asarray(a.top_scores), np.asarray(b.top_scores))
    assert np.array_equal(np.asarray(a.top_genomes), np.asarray(b.top_genomes))
    assert float(a.ga.best_score) == float(b.ga.best_score)


# --------------------------------------------------- fused-vs-unfused parity
@pytest.mark.parametrize("backend", ["jnp", "table", "pallas"])
def test_fused_unfused_parity_all_backends(ws, backend):
    """The fused epilogue is a pure program-shape change: trajectories,
    top designs and scores are bit-identical on every backend."""
    key = jax.random.PRNGKey(11)
    a = run_search(key, ws, pop_size=16, generations=4, backend=backend,
                   fused=True)
    b = run_search(key, ws, pop_size=16, generations=4, backend=backend,
                   fused=False)
    _same_result(a, b)


@pytest.mark.parametrize("pop", [15, 17])
def test_fused_unfused_parity_odd_pop(ws, pop):
    key = jax.random.PRNGKey(5)
    a = run_search(key, ws, pop_size=pop, generations=3, fused=True)
    b = run_search(key, ws, pop_size=pop, generations=3, fused=False)
    _same_result(a, b)


def test_fused_unfused_parity_ragged_batch(ws):
    """Mixed workload subsets in one ragged batch: per-element parity."""
    subsets = [[0], [1, 2], [0, 1, 2, 3]]
    sets = [ws.subset(s) for s in subsets]
    W = max(s.n for s in sets)
    L = ws.feats.shape[1]
    B = len(sets)
    feats = np.zeros((B, W, L, 6), np.float32)
    mask = np.zeros((B, W, L), bool)
    for i, s in enumerate(sets):
        feats[i, : s.n] = np.asarray(s.feats)
        mask[i, : s.n] = np.asarray(s.mask)
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(B)])
    ra = batched_search(keys, feats, mask, pop_size=12, generations=3,
                        backend="table", fused=True)
    rb = batched_search(keys, feats, mask, pop_size=12, generations=3,
                        backend="table", fused=False)
    for a, b in zip(ra, rb):
        _same_result(a, b)


def test_fused_unfused_parity_segmented(ws):
    """Fused x segmented: the chained fused segments equal the single
    unfused launch bit-for-bit (and vice versa)."""
    key = jax.random.PRNGKey(23)
    kw = dict(pop_size=14, generations=6, backend="table")
    single = run_search(key, ws, fused=False, **kw)
    seg_fused = run_search(
        key, ws, engine=SearchEngine(segment_gens=2, fused=True), **kw)
    _same_result(single, seg_fused)


def test_separate_search_fused_parity(ws):
    key = jax.random.PRNGKey(3)
    ra = separate_search(key, ws, pop_size=12, generations=3,
                         backend="table", fused=True)
    rb = separate_search(key, ws, pop_size=12, generations=3,
                         backend="table", fused=False)
    for n in ws.names:
        _same_result(ra[n], rb[n])


# ------------------------------------------------------- gen-step kernel
def _table_eval_ctx(ws, P):
    tables = build_tables_arrays(ws.feats, ws.mask)
    eval_fn = _ctx_eval(INDEXED, 0.0, TECH, "table")
    ctx = (tables, jnp.int32(0), jnp.float32(1e9))
    return eval_fn, ctx


@pytest.mark.parametrize("pop", [8, 15, 16])
def test_kernel_gen_step_matches_lax(ws, pop):
    """One full fused-kernel generation == the lax gen step, compiled,
    for every output (survivors, scores, children, child scores)."""
    from repro.kernels.ga_gen_step import make_kernel_gen_step

    eval_fn, ctx = _table_eval_ctx(ws, pop)
    assert getattr(eval_fn, "gen_kernel_tech", None) is not None
    gen_lax = ga._make_gen_step(eval_fn, ctx, pop, space.N_GENES,
                                ga.SBX_PROB, ga.SBX_ETA, ga.MUT_ETA,
                                fused=True)
    kgen = make_kernel_gen_step(
        eval_fn, ctx, pop_size=pop, n_genes=space.N_GENES,
        sbx_prob=ga.SBX_PROB, sbx_eta=ga.SBX_ETA, mut_eta=ga.MUT_ETA)
    assert kgen is not None

    popg = space.random_genomes(jax.random.PRNGKey(7), pop)
    scores = eval_fn(popg, ctx)
    k = jax.random.fold_in(jax.random.PRNGKey(3), 0)
    (p1, s1), (c1, cs1) = jax.jit(gen_lax)((popg, scores), k)
    (p2, s2), (c2, cs2) = jax.jit(kgen)((popg, scores), k)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    assert np.array_equal(np.asarray(cs1), np.asarray(cs2))


def test_kernel_gen_step_chained_generations(ws):
    """Several chained kernel generations track the lax trajectory."""
    from repro.kernels.ga_gen_step import make_kernel_gen_step

    P = 12
    eval_fn, ctx = _table_eval_ctx(ws, P)
    gen_lax = jax.jit(ga._make_gen_step(
        eval_fn, ctx, P, space.N_GENES, ga.SBX_PROB, ga.SBX_ETA,
        ga.MUT_ETA, fused=True))
    kgen = jax.jit(make_kernel_gen_step(
        eval_fn, ctx, pop_size=P, n_genes=space.N_GENES,
        sbx_prob=ga.SBX_PROB, sbx_eta=ga.SBX_ETA, mut_eta=ga.MUT_ETA))
    popg = space.random_genomes(jax.random.PRNGKey(1), P)
    ca = (popg, eval_fn(popg, ctx))
    cb = ca
    for g in range(4):
        k = jax.random.fold_in(jax.random.PRNGKey(9), g)
        ca, _ = gen_lax(ca, k)
        cb, _ = kgen(cb, k)
    assert np.array_equal(np.asarray(ca[0]), np.asarray(cb[0]))
    assert np.array_equal(np.asarray(ca[1]), np.asarray(cb[1]))


def test_kernel_hook_requires_table_eval():
    """The kernel factory declines eval callbacks without a table ctx —
    dense/jnp backends keep the lax gen step."""
    from repro.kernels.ga_gen_step import make_kernel_gen_step

    plain = lambda g, ctx: jnp.zeros(g.shape[0])  # noqa: E731
    assert make_kernel_gen_step(plain, (None,), pop_size=8,
                                n_genes=space.N_GENES, sbx_prob=0.9,
                                sbx_eta=3.0, mut_eta=3.0) is None


# -------------------------------------------------------- direct seeder
def test_direct_seed_designs_fit_largest_workload(ws):
    """Every directly-seeded genome fits the largest workload and is
    V/f-valid — by construction, not by rejection."""
    from repro.core.engine import _seed_direct_batched_jit

    eng = SearchEngine(direct_seed=True)
    req = SearchRequest(ws=ws, objective="ela", area_constr=1e9,
                        key=jax.random.PRNGKey(0), backend="table",
                        pop_size=64, generations=1, top_k=4, tech=TECH)
    cdf = eng._request_seed_cdf(req)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(3)])
    pools, counts = _seed_direct_batched_jit(
        keys, jnp.asarray(np.stack([cdf] * 3)), pop_size=64, tech=TECH)
    assert np.all(np.asarray(counts) == 64)
    tables = build_tables_arrays(ws.feats, ws.mask)
    from repro.core.engine import largest_workload_index

    li = largest_workload_index(ws)
    for b in range(3):
        r = evaluate_genomes_tables(pools[b], tables)
        assert bool(np.asarray(r.fits)[:, li].all())
        assert bool(np.asarray(r.valid).all())


def test_direct_seed_engine_results_valid_and_deterministic(ws):
    kw = dict(pop_size=16, generations=3, backend="table")
    key = jax.random.PRNGKey(42)
    a = run_search(key, ws, engine=SearchEngine(direct_seed=True), **kw)
    b = run_search(key, ws, engine=SearchEngine(direct_seed=True), **kw)
    assert a.valid
    _same_result(a, b)


def test_direct_seed_is_opt_in(ws):
    """The default engine keeps the rejection seeder: direct_seed=False
    must reproduce the plain run_search bits exactly."""
    key = jax.random.PRNGKey(8)
    kw = dict(pop_size=12, generations=2, backend="table")
    a = run_search(key, ws, **kw)
    b = run_search(key, ws, engine=SearchEngine(direct_seed=False), **kw)
    _same_result(a, b)


# --------------------------------------------------------- grid density
def test_configure_grid_densify_and_restore(ws):
    """Densifying multiplies the axis sizes, changes the grid token (so
    every content cache misses), keeps the endpoints, and a search still
    runs end-to-end; restoring brings the exact baseline back."""
    base_sizes = {f: len(space.SPACE[f]) for f in space.FIELDS}
    base_token = space.grid_token()
    base_bytes = table_bytes(ws.tables())
    try:
        space.configure_grid(2)
        assert space.grid_token() != base_token
        for f in space.FIELDS:
            # exact axes (bits_cell: integral by definition) keep their
            # points; every refinable axis gains interior ones
            if space._REFINE_KIND[f] == "exact":
                assert len(space.SPACE[f]) == base_sizes[f]
            else:
                assert len(space.SPACE[f]) > base_sizes[f]
            assert space.SPACE[f][0] == pytest.approx(
                np.asarray(space._BASE_SPACE[f][0]))
        assert table_bytes(ws.tables()) > base_bytes
        # generous area: this pins end-to-end execution on the dense
        # grid, not feasibility statistics at a tiny search budget
        res = run_search(jax.random.PRNGKey(1), ws, pop_size=12,
                         generations=2, backend="table", area_constr=1e3)
        assert res.valid
        # decoded indices stay in range on the dense grid
        idx = space.decode_indices_np(np.asarray(res.ga.genomes[-1]))
        for j, f in enumerate(space.FIELDS):
            assert idx[:, j].max() < len(space.SPACE[f])
    finally:
        space.configure_grid(1)
    assert space.grid_token() == base_token
    assert {f: len(space.SPACE[f]) for f in space.FIELDS} == base_sizes


def test_dense_grid_fused_unfused_parity(ws):
    try:
        space.configure_grid(2)
        key = jax.random.PRNGKey(77)
        a = run_search(key, ws, pop_size=12, generations=2,
                       backend="table", fused=True)
        b = run_search(key, ws, pop_size=12, generations=2,
                       backend="table", fused=False)
        _same_result(a, b)
    finally:
        space.configure_grid(1)


# ------------------------------------------------------ batched finalize
def test_finalize_batch_matches_finalize(ws):
    """The batched numpy finalize epilogue == the per-request reference
    on every field (single-shot engine path vs segmented path helper)."""
    from repro.core.engine import _finalize, _finalize_batch, _objective_label
    from repro.core.ga import GAResult

    reqs = [
        SearchRequest(ws=ws.subset([i % ws.n]), objective="ela",
                      area_constr=150.0, key=jax.random.PRNGKey(i),
                      backend="table", pop_size=10, generations=2,
                      top_k=5, tech=TECH)
        for i in range(3)
    ]
    plans = plan_batch(reqs, max_slots=8)
    assert len(plans) == 1
    eng = SearchEngine()
    results = eng.execute(plans[0])  # runs _finalize_batch internally
    # reference: per-request _finalize over the same GA arrays
    for req, res in zip(plans[0].requests, results):
        ga_i = GAResult(
            genomes=np.asarray(res.ga.genomes),
            scores=np.asarray(res.ga.scores),
            best_genome=np.asarray(res.ga.best_genome),
            best_score=np.asarray(res.ga.best_score),
        )
        ref = _finalize(ga_i, req.ws.names, _objective_label(req), req.top_k)
        assert np.array_equal(res.top_scores, ref.top_scores)
        assert np.array_equal(res.top_genomes, ref.top_genomes)
        assert res.top_designs == ref.top_designs
        assert np.array_equal(res.convergence, ref.convergence)
        assert res.valid == ref.valid
