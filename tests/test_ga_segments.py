"""Anytime fault-tolerant execution: segmented GA + engine.

The contract this module pins (ISSUE: robustness PR):

  * **Bit-parity** — N segment launches of k generations through
    ``run_ga_segment`` / ``run_ga_batched_segment`` reproduce a single
    ``run_ga`` of N*k generations bit-for-bit (same history, same best),
    for any split of the budget, odd populations, ragged final segments,
    batched element-wise, on every backend, and under the fake-8-device
    (search, population) mesh.
  * **Guarded retry** — a transient segment failure (exception or NaN
    scores) re-launches from the last good ``GAState`` and the recovered
    run is STILL bit-identical; exhausted retries raise ``EngineFault``
    carrying per-request anytime partial results.
  * **Kill/resume** — a run killed mid-drain (KeyboardInterrupt) leaves
    a committed on-disk checkpoint; a fresh engine re-executing the same
    plan resumes from it and finishes bit-identical to an uninterrupted
    run, then clears its own checkpoint directory.
  * **Finite-score guard** — a history with no finite score finalizes as
    ``valid=False`` instead of silently returning garbage designs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core import space
from repro.core.engine import (
    EngineFault,
    NonFiniteScoreError,
    SearchEngine,
    SearchRequest,
    _finalize,
    empty_partial_result,
    plan_batch,
    plan_key,
)
from repro.core.ga import (
    GAResult,
    GAState,
    init_ga_state,
    init_ga_state_batched,
    run_ga,
    run_ga_batched,
    run_ga_batched_segment,
    run_ga_segment,
)
from repro.serve.dse import DSEService
from repro.workloads.cnn import cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS = 8, 6


@pytest.fixture(scope="module")
def ws():
    return pack_workloads(
        [(n, cnn_workload(n)) for n in ("resnet18", "vgg16")]
    )


def _toy_eval(genomes):
    # cheap deterministic objective; module-level so the jit caches hit
    return jnp.sum((genomes - 0.3) ** 2, axis=-1)


def _init(seed, pop):
    return space.random_genomes(jax.random.PRNGKey(1000 + seed), pop)


def _chain(key, init, splits, total, *, pop):
    """init + segment launches over ``splits``; returns the accumulated
    (total+1, P, n)/(total+1, P) history exactly as the engine builds it."""
    st = init_ga_state(key, _toy_eval, init)
    hg = [np.asarray(st.genomes)[None]]
    hs = [np.asarray(st.scores)[None]]
    for k in splits:
        st, (g, s) = run_ga_segment(
            st, _toy_eval, generations=k, total_generations=total
        )
        hg.append(np.asarray(g))
        hs.append(np.asarray(s))
    return st, np.concatenate(hg), np.concatenate(hs)


# ------------------------------------------------------------ GA-level parity
@pytest.mark.parametrize("splits", [(6,), (3, 3), (2, 2, 2), (1, 5), (4, 2)],
                         ids=lambda s: "+".join(map(str, s)))
def test_ga_segments_bit_identical_to_single_shot(splits):
    key = jax.random.PRNGKey(7)
    init = _init(0, POP)
    full = run_ga(key, _toy_eval, pop_size=POP, generations=GENS,
                  init_genomes=init + 0)  # donated: pass a copy
    st, hg, hs = _chain(key, init, splits, GENS, pop=POP)
    np.testing.assert_array_equal(hg, np.asarray(full.genomes))
    np.testing.assert_array_equal(hs, np.asarray(full.scores))
    # the state's counter walked the whole budget; the history's argmin
    # (what _finalize consumes) equals the single-shot best
    assert int(np.asarray(st.gen)) == GENS
    b = int(np.argmin(hs.reshape(-1)))
    np.testing.assert_array_equal(
        hg.reshape(-1, hg.shape[-1])[b], np.asarray(full.best_genome)
    )
    assert hs.reshape(-1)[b] == float(full.best_score)


def test_ga_segments_odd_population():
    pop = 17  # odd P exercises the extra-pair/truncate path per segment
    key = jax.random.PRNGKey(3)
    init = _init(1, pop)
    full = run_ga(key, _toy_eval, pop_size=pop, generations=5,
                  init_genomes=init + 0)
    _, hg, hs = _chain(key, init, (2, 2, 1), 5, pop=pop)
    np.testing.assert_array_equal(hg, np.asarray(full.genomes))
    np.testing.assert_array_equal(hs, np.asarray(full.scores))


def test_ga_batched_segments_bit_identical():
    B = 3
    keys = jax.random.split(jax.random.PRNGKey(11), B)
    init = jnp.stack([_init(10 + b, POP) for b in range(B)])
    full = run_ga_batched(keys, _toy_eval, pop_size=POP, generations=GENS,
                          init_genomes=init + 0)
    st = init_ga_state_batched(keys, _toy_eval, init)
    hg = [np.asarray(st.genomes)[:, None]]
    hs = [np.asarray(st.scores)[:, None]]
    for k in (2, 3, 1):
        st, (g, s) = run_ga_batched_segment(
            st, _toy_eval, generations=k, total_generations=GENS
        )
        hg.append(np.asarray(g))
        hs.append(np.asarray(s))
    np.testing.assert_array_equal(np.concatenate(hg, axis=1),
                                  np.asarray(full.genomes))
    np.testing.assert_array_equal(np.concatenate(hs, axis=1),
                                  np.asarray(full.scores))
    # batched elements match the unbatched chain element-wise
    _, hg0, hs0 = _chain(keys[0], init[0], (2, 3, 1), GENS, pop=POP)
    np.testing.assert_array_equal(np.concatenate(hg, axis=1)[0], hg0)
    np.testing.assert_array_equal(np.concatenate(hs, axis=1)[0], hs0)


def test_ga_segment_does_not_donate_state():
    # a failed launch must be able to re-run from the same state
    st = init_ga_state(jax.random.PRNGKey(0), _toy_eval, _init(2, POP))
    before = np.asarray(st.genomes).copy()
    a = run_ga_segment(st, _toy_eval, generations=2, total_generations=4)
    b = run_ga_segment(st, _toy_eval, generations=2, total_generations=4)
    np.testing.assert_array_equal(np.asarray(a[1][1]), np.asarray(b[1][1]))
    np.testing.assert_array_equal(np.asarray(st.genomes), before)


# -------------------------------------------------------- engine-level parity
def _reqs(ws, n, backend, *, gens=GENS, seed0=0):
    subsets = [[0, 1], [0], [1]]
    return [
        SearchRequest(ws=ws.subset(subsets[i % 3]), seed=seed0 + i,
                      backend=backend, pop_size=POP, generations=gens)
        for i in range(n)
    ]


def _assert_result_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.ga.scores),
                                  np.asarray(b.ga.scores))
    np.testing.assert_array_equal(np.asarray(a.ga.genomes),
                                  np.asarray(b.ga.genomes))
    np.testing.assert_array_equal(a.top_scores, b.top_scores)
    np.testing.assert_array_equal(a.top_genomes, b.top_genomes)
    assert float(a.ga.best_score) == float(b.ga.best_score)
    assert a.valid == b.valid and a.generations == b.generations


@pytest.mark.parametrize("backend", ["table", "jnp", "pallas"])
def test_segmented_engine_matches_single_shot(ws, backend):
    n = 1 if backend == "pallas" else 3
    reqs = _reqs(ws, n, backend)
    ref = SearchEngine().run(reqs)
    out = SearchEngine(segment_gens=2).run(reqs)
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)
        assert not a.partial and a.generations == GENS


def test_segmented_engine_ragged_final_segment(ws):
    reqs = _reqs(ws, 2, "table")  # 6 = 4 + ragged 2
    ref = SearchEngine().run(reqs)
    out = SearchEngine(segment_gens=4).run(reqs)
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)


def test_segment_gens_at_or_above_budget_uses_single_shot(ws):
    # k >= G falls back to the original one-launch path (same results by
    # construction; pin that it doesn't take the segment path at all)
    eng = SearchEngine(segment_gens=GENS)
    reqs = _reqs(ws, 1, "table")
    ref = SearchEngine().run(reqs)
    _assert_result_equal(eng.run(reqs)[0], ref[0])


@pytest.mark.multidevice
def test_segmented_engine_sharded_parity(ws):
    from repro.launch.mesh import make_search_mesh

    reqs = _reqs(ws, 4, "table")
    ref = SearchEngine().run(reqs)
    eng = SearchEngine(mesh=make_search_mesh(2, 4), segment_gens=2)
    for a, b in zip(eng.run(reqs), ref):
        _assert_result_equal(a, b)


# ------------------------------------------------------------- guarded retry
def test_transient_segment_failure_retries_bit_identical(ws, monkeypatch):
    reqs = _reqs(ws, 2, "table")
    ref = SearchEngine(segment_gens=2).run(reqs)
    real = engine_mod.run_ga_batched_segment
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected transient launch failure")
        return real(*a, **kw)

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", flaky)
    out = SearchEngine(segment_gens=2, segment_retries=1).run(reqs)
    assert calls["n"] == 4  # 3 segments + 1 retried
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)


def test_nan_segment_guard_retries_from_last_good_state(ws, monkeypatch):
    reqs = _reqs(ws, 2, "table")
    ref = SearchEngine(segment_gens=2).run(reqs)
    real = engine_mod.run_ga_batched_segment
    calls = {"n": 0}

    def poisoned_once(*a, **kw):
        calls["n"] += 1
        st, (hg, hs) = real(*a, **kw)
        if calls["n"] == 1:
            return st, (hg, jnp.full_like(hs, jnp.nan))
        return st, (hg, hs)

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", poisoned_once)
    out = SearchEngine(segment_gens=2, segment_retries=1).run(reqs)
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)


def test_exhausted_retries_raise_fault_with_partials(ws, monkeypatch):
    reqs = _reqs(ws, 2, "table")

    def always_fails(*a, **kw):
        raise RuntimeError("injected permanent failure")

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", always_fails)
    eng = SearchEngine(segment_gens=2, segment_retries=1)
    with pytest.raises(EngineFault) as ei:
        eng.run(reqs)
    fault = ei.value
    assert fault.generations_done == 0
    assert fault.partials is not None and len(fault.partials) == len(reqs)
    for p, r in zip(fault.partials, reqs):
        # only the seed evaluation ran: an anytime result over generation 0
        assert p.partial and p.generations == 0
        assert p.workload_names == r.ws.names
        assert p.convergence.shape == (1,)
        # seeds can all be area-infeasible (+inf): valid iff a finite
        # score exists, and whatever made the top list is finite
        assert p.valid == bool(p.top_scores.size)
        assert np.isfinite(p.top_scores).all()


def test_nan_seed_evaluation_raises(ws, monkeypatch):
    def nan_seed_state(keys, eval_fn, init, ctx=None):
        st = init_ga_state_batched(keys, eval_fn, init, ctx=ctx)
        return GAState(genomes=st.genomes,
                       scores=jnp.full_like(st.scores, jnp.nan),
                       key=st.key, gen=st.gen)

    monkeypatch.setattr(engine_mod, "init_ga_state_batched", nan_seed_state)
    eng = SearchEngine(segment_gens=2)
    with pytest.raises(NonFiniteScoreError, match="seed"):
        eng.run(_reqs(ws, 1, "table"))


# --------------------------------------------------------------- kill/resume
def test_kill_resume_from_disk_bit_identical(ws, tmp_path, monkeypatch):
    """The acceptance drill: a drain killed after a checkpointed segment
    resumes from disk in a FRESH engine and produces the same final bests
    as an uninterrupted run — then clears its own checkpoint."""
    from repro.checkpoint import store

    reqs = _reqs(ws, 2, "table", seed0=50)
    ref = SearchEngine(segment_gens=2).run(reqs)
    ck_root = tmp_path / "ck"
    real = engine_mod.run_ga_batched_segment
    calls = {"n": 0}

    def killed_on_second(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise KeyboardInterrupt()  # SIGINT mid-drain
        return real(*a, **kw)

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", killed_on_second)
    eng = SearchEngine(segment_gens=2, checkpoint_dir=str(ck_root))
    with pytest.raises(KeyboardInterrupt):
        eng.run(reqs)
    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", real)

    # segment 1 committed its checkpoint before the kill
    ck = ck_root / plan_key(plan_batch(reqs, max_slots=eng.max_slots)[0])
    assert store.latest_step(ck) == 2

    out = SearchEngine(segment_gens=2, checkpoint_dir=str(ck_root)).run(reqs)
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)
    assert store.latest_step(ck) is None  # completed plan cleared its state


def test_service_drain_kill_resume(ws, tmp_path, monkeypatch):
    """Same drill through the service front end: the sync service rolls
    the dispatched plan back on KeyboardInterrupt (queue intact), and a
    fresh service over a fresh engine resumes from the same directory."""
    reqs = _reqs(ws, 2, "table", seed0=80)
    ref_svc = DSEService(engine=SearchEngine(segment_gens=2))
    ref_rids = ref_svc.submit_all(reqs)
    ref_map = ref_svc.drain()
    ref_res = [ref_map[r] for r in ref_rids]
    ck_root = str(tmp_path / "svc_ck")
    real = engine_mod.run_ga_batched_segment
    calls = {"n": 0}

    def killed_on_second(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise KeyboardInterrupt()
        return real(*a, **kw)

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", killed_on_second)
    svc = DSEService(engine=SearchEngine(segment_gens=2,
                                         checkpoint_dir=ck_root))
    svc.submit_all(reqs)
    with pytest.raises(KeyboardInterrupt):
        svc.drain()
    assert svc.pending() == len(reqs)  # rolled back, still retryable
    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", real)

    svc2 = DSEService(engine=SearchEngine(segment_gens=2,
                                          checkpoint_dir=ck_root))
    rids = svc2.submit_all(reqs)
    res = svc2.drain()
    for rid, b in zip(rids, ref_res):
        _assert_result_equal(res[rid], b)


def test_checkpoint_cadence_writes_only_at_interval(ws, tmp_path, monkeypatch):
    from repro.checkpoint import store

    saves = []
    real_save = store.save

    def counting_save(ck, step, tree, **kw):
        saves.append(step)
        return real_save(ck, step, tree, **kw)

    monkeypatch.setattr(store, "save", counting_save)
    eng = SearchEngine(segment_gens=1, checkpoint_every=2,
                       checkpoint_dir=str(tmp_path / "cad"))
    eng.run(_reqs(ws, 1, "table", gens=5, seed0=70))
    assert saves == [2, 4]  # every 2nd of 5 one-generation segments


# -------------------------------------------------------- finite-score guard
def test_finalize_flags_poisoned_history_invalid():
    P, n = 4, space.N_GENES
    g = np.random.default_rng(0).random((3, P, n)).astype(np.float32)
    for bad in (np.nan, np.inf):
        ga = GAResult(genomes=jnp.asarray(g),
                      scores=jnp.full((3, P), bad, jnp.float32),
                      best_genome=jnp.zeros((n,)),
                      best_score=jnp.float32(bad))
        res = _finalize(ga, ("w0",), "ela", 5)
        assert not res.valid
        assert res.top_scores.size == 0 and res.top_designs == []


def test_poisoned_eval_fn_yields_invalid_result():
    """Satellite regression: a GA run whose eval fn only ever returns
    non-finite scores must finalize as ``valid=False`` — never as a
    confident result over garbage designs."""
    def poisoned(genomes):
        return jnp.full((genomes.shape[0],), jnp.nan, jnp.float32)

    ga = run_ga(jax.random.PRNGKey(0), poisoned, pop_size=POP, generations=2,
                init_genomes=_init(3, POP))
    res = _finalize(ga, ("w0",), "ela", 5)
    assert not res.valid and res.top_scores.size == 0


def test_empty_partial_result_contract(ws):
    req = SearchRequest(ws=ws, seed=1, backend="table", pop_size=POP,
                        generations=GENS)
    res = empty_partial_result(req)
    assert res.partial and not res.valid and res.generations == 0
    assert res.ga is None and res.top_scores.size == 0
    assert res.workload_names == ws.names and res.objective == "ela"
    wreq = dataclasses.replace(req, obj_weights=(1.0, 2.0, 0.0))
    assert empty_partial_result(wreq).objective.startswith("weighted")


# --------------------------------------------------- fused x segment cross
@pytest.mark.parametrize("fused", [True, False],
                         ids=["fused", "unfused"])
def test_ga_segments_fused_parity(fused):
    """Segment chains equal the single shot under BOTH epilogue modes,
    and both modes equal each other — fused is pure program shape."""
    key = jax.random.PRNGKey(19)
    init = _init(4, POP)
    full = run_ga(key, _toy_eval, pop_size=POP, generations=GENS,
                  init_genomes=init + 0, fused=fused)
    st = init_ga_state(key, _toy_eval, init)
    hg = [np.asarray(st.genomes)[None]]
    hs = [np.asarray(st.scores)[None]]
    for k in (2, 2, 2):
        st, (g, s) = run_ga_segment(st, _toy_eval, generations=k,
                                    total_generations=GENS, fused=fused)
        hg.append(np.asarray(g))
        hs.append(np.asarray(s))
    np.testing.assert_array_equal(np.concatenate(hg),
                                  np.asarray(full.genomes))
    np.testing.assert_array_equal(np.concatenate(hs),
                                  np.asarray(full.scores))
    # cross-mode: the fused single shot equals the unfused one
    other = run_ga(key, _toy_eval, pop_size=POP, generations=GENS,
                   init_genomes=init + 0, fused=not fused)
    np.testing.assert_array_equal(np.asarray(full.genomes),
                                  np.asarray(other.genomes))
    np.testing.assert_array_equal(np.asarray(full.scores),
                                  np.asarray(other.scores))


def test_segmented_engine_fused_cross_parity(ws):
    """Engine-level: fused segmented == unfused single shot, including
    the mixed-subset slot packing and both finalize epilogues."""
    reqs = _reqs(ws, 3, "table", seed0=40)
    ref = SearchEngine(fused=False).run(reqs)
    out = SearchEngine(segment_gens=2, fused=True).run(reqs)
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)


def test_segmented_engine_direct_seed_parity(ws):
    """direct_seed crossed with segmentation: the segmented direct-seed
    engine equals the single-shot direct-seed engine bit-for-bit."""
    reqs = _reqs(ws, 3, "table", seed0=60)
    ref = SearchEngine(direct_seed=True).run(reqs)
    out = SearchEngine(direct_seed=True, segment_gens=2, fused=True).run(reqs)
    for a, b in zip(out, ref):
        _assert_result_equal(a, b)
