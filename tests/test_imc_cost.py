"""IMC cost model: physical-consistency checks + kernel parity.

(Property-based variants live in test_properties.py, guarded on
hypothesis being installed.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import space
from repro.imc.cost import DesignArrays, area_mm2, evaluate_designs
from repro.imc.tech import TECH
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.lm import lm_workload
from repro.workloads.pack import pack_workloads


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _design(**kw):
    base = dict(rows=128.0, cols=128.0, c_per_tile=8.0, t_per_router=8.0,
                g_per_chip=8.0, v_op=0.9, bits_cell=2.0, t_cycle_ns=2.0,
                glb_mb=1.0)
    base.update(kw)
    return DesignArrays(**{k: jnp.asarray([v], jnp.float32) for k, v in base.items()})


def test_energy_latency_area_positive(ws):
    g = space.random_genomes(jax.random.PRNGKey(0), 256)
    r = evaluate_designs(space.decode(g), ws)
    assert bool((r.energy_pj > 0).all())
    assert bool((r.latency_ns > 0).all())
    assert bool((r.area_mm2 > 0).all())


def test_more_capacity_never_hurts_fit(ws):
    for rows in (32.0, 128.0, 512.0):
        small = evaluate_designs(_design(rows=rows, c_per_tile=2.0), ws)
        big = evaluate_designs(_design(rows=rows, c_per_tile=32.0), ws)
        # strictly more crossbars on chip -> fits is monotone
        assert bool((big.fits | ~small.fits).all())


def test_area_monotone_in_everything():
    base = area_mm2(_design())
    for f, hi in [("rows", 512.0), ("cols", 512.0), ("c_per_tile", 32.0),
                  ("t_per_router", 16.0), ("g_per_chip", 64.0), ("glb_mb", 16.0)]:
        bigger = area_mm2(_design(**{f: hi}))
        assert float(bigger[0]) > float(base[0]), f


def test_voltage_frequency_coupling():
    # at 0.7 V the device cannot run at 0.5 ns; at 8 ns it can
    fast = evaluate_designs(_design(v_op=0.7, t_cycle_ns=0.5),
                            pack_workloads([("x", [(1, 8, 8, 8, 8, 1)])]))
    slow = evaluate_designs(_design(v_op=0.7, t_cycle_ns=8.0),
                            pack_workloads([("x", [(1, 8, 8, 8, 8, 1)])]))
    assert not bool(fast.valid[0])
    assert bool(slow.valid[0])


def test_bits_per_cell_tradeoff(ws):
    """More bits/cell packs weights denser -> less crossbar demand."""
    lo = evaluate_designs(_design(bits_cell=1.0), ws)
    hi = evaluate_designs(_design(bits_cell=4.0), ws)
    assert bool((hi.util <= lo.util + 1e-6).all())


def test_glb_spill_increases_latency_energy(ws):
    small = evaluate_designs(_design(glb_mb=0.125), ws)
    big = evaluate_designs(_design(glb_mb=16.0), ws)
    # latency is unconditionally monotone (DRAM spill stalls)
    assert bool((small.latency_ns >= big.latency_ns - 1e-3).all())
    # energy: decouple leakage (bigger GLB -> more area -> more leak is a
    # REAL competing effect); with leakage off, spill energy dominates
    tech0 = TECH._replace(leak_mw_per_mm2=0.0)
    small0 = evaluate_designs(_design(glb_mb=0.125), ws, tech0)
    big0 = evaluate_designs(_design(glb_mb=16.0), ws, tech0)
    assert bool((small0.energy_pj >= big0.energy_pj - 1e-3).all())


def test_depthwise_maps_badly():
    """MobileNet's depthwise convs (groups=C) demand far more crossbars per
    MAC than dense convs — the known IMC pathology the paper's workload mix
    exercises."""
    dense = [(196, 1152, 128, 1, 1, 1)]  # 1 group
    dw = [(196, 9, 1, 1, 1, 128)]  # 128 groups, same-ish macs
    r_dense = evaluate_designs(_design(), pack_workloads([("d", dense)]))
    r_dw = evaluate_designs(_design(), pack_workloads([("w", dw)]))
    assert float(r_dw.util[0, 0]) > 0.1 * float(r_dense.util[0, 0])


# ----------------------------------------------------------------- LM export
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b", "mamba2-780m",
                                  "whisper-medium", "jamba-v0.1-52b"])
def test_lm_workload_export(arch):
    from repro.configs.base import get_config

    cfg = get_config(arch)
    layers = lm_workload(cfg, mode="decode")
    assert len(layers) > 0
    arr = np.asarray(layers, np.float64)
    assert (arr[:, :3] >= 1).all()  # M, K, N positive
    # decode mode: single-token presentations everywhere
    assert arr[:, 0].max() <= max(1, cfg.topk or 1)


def test_lm_workload_prefill_scales_m():
    from repro.configs.base import get_config

    cfg = get_config("llama3.2-1b")
    d = np.asarray(lm_workload(cfg, mode="decode"), np.float64)
    p = np.asarray(lm_workload(cfg, mode="prefill", seq=128), np.float64)
    assert p[:, 0].max() == 128


# -------------------------------------------------------------- kernel parity
def test_imc_eval_kernel_parity(ws):
    from repro.kernels.imc_eval.ops import evaluate_designs_kernel

    g = space.random_genomes(jax.random.PRNGKey(0), 300)
    d = space.decode(g)
    ref = evaluate_designs(d, ws)
    for backend in ("jnp", "pallas"):
        r = evaluate_designs_kernel(d, ws, backend=backend)
        np.testing.assert_allclose(r.energy_pj, ref.energy_pj, rtol=2e-5)
        np.testing.assert_allclose(r.latency_ns, ref.latency_ns, rtol=2e-5)
        np.testing.assert_array_equal(np.asarray(r.fits), np.asarray(ref.fits))
        np.testing.assert_array_equal(np.asarray(r.valid), np.asarray(ref.valid))
