"""Pallas kernels: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize(
    "B,Sq,Skv,H,KV,D,causal,window",
    [
        (2, 128, 128, 4, 2, 64, True, 0),
        (1, 256, 256, 8, 8, 64, True, 0),
        (2, 128, 128, 4, 1, 80, True, 0),     # D padded to 128 lanes
        (1, 256, 256, 4, 2, 64, True, 96),    # sliding window
        (2, 100, 128, 4, 2, 64, True, 0),     # ragged Sq padding
        (1, 64, 64, 2, 2, 128, True, 0),
    ],
)
def test_flash_attention_sweep(B, Sq, Skv, H, KV, D, causal, window):
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.flash_attention.ref import attention_reference

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), jnp.float32)
    o_p = fa.flash_attention(q, k, v, causal=causal, window=window)
    o_r = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(o_p, o_r, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.flash_attention.ref import attention_reference

    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    o_p = fa.flash_attention(q, k, v)
    o_r = attention_reference(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        o_p.astype(jnp.float32), o_r.astype(jnp.float32), atol=atol
    )


def test_flash_attention_matches_model_chunked():
    """Pallas kernel == the model's portable chunked-jnp flash attention."""
    from repro.kernels.flash_attention import ops as fa
    from repro.models.attention import flash_attention as jnp_flash

    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 256, 8, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    np.testing.assert_allclose(
        fa.flash_attention(q, k, v), jnp_flash(q, k, v, chunk=64), atol=2e-5
    )


# ---------------------------------------------------------------------- SSD
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (2, 256, 4, 64, 128, 128),
        (1, 128, 8, 32, 64, 32),
        (2, 64, 2, 16, 32, 64),
        (1, 512, 4, 64, 128, 128),
    ],
)
def test_ssd_pallas_sweep(B, S, H, P, N, chunk):
    from repro.kernels.ssd_scan import ops, ref

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    y_p, h_p = ops.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y_r, h_r = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y_p, y_r, atol=1e-4)
    np.testing.assert_allclose(h_p, h_r, atol=1e-4)


def test_ssd_chunked_equals_sequential():
    """The chunked algorithm (and hence the kernel) == step-by-step scan."""
    from repro.kernels.ssd_scan import ref

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    B, S, H, P, N, G = 2, 128, 4, 32, 64, 2
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y_c, h_c = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y_s, h_s = ref.ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y_c, y_s, atol=2e-3)
    np.testing.assert_allclose(h_c, h_s, atol=2e-3)


def test_ssd_decode_consistent_with_scan():
    """Running decode steps one-by-one reproduces the chunked output."""
    from repro.kernels.ssd_scan import ref

    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 5)
    B, S, H, P, N = 1, 16, 2, 8, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    y_c, h_c = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    h = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        y, h = ref.ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    y_d = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_d, y_c, atol=2e-3)
    np.testing.assert_allclose(h, h_c, atol=2e-3)


# ------------------------------------------------------------------ imc_eval
def test_imc_eval_padding_edges():
    """Odd population / layer counts exercise the pad+mask path — P not a
    multiple of the 128 lane tile, L not a multiple of the 8 sublane tile."""
    from repro.core import space
    from repro.kernels.imc_eval import ref
    from repro.kernels.imc_eval.kernel import imc_eval_pallas

    key = jax.random.PRNGKey(0)
    for P, L in [(1, 1), (7, 3), (129, 9), (130, 65), (300, 13)]:
        g = space.random_genomes(key, P)
        d = jnp.stack(list(space.decode(g)), axis=1)
        feats = jnp.abs(jax.random.normal(key, (L, 6))) * 100 + 1
        mask = jnp.ones((L,), bool)
        e_r, l_r, x_r = ref.eval_one_workload(d, feats, mask)
        e_p, l_p, x_p = imc_eval_pallas(d, feats, mask)
        np.testing.assert_allclose(e_p, e_r, rtol=2e-5)
        np.testing.assert_allclose(l_p, l_r, rtol=2e-5)
        np.testing.assert_allclose(x_p, x_r, rtol=2e-5)


def test_imc_eval_multi_workload_padding_edges():
    """3-D-grid kernel vs per-workload oracle, with ragged layer masks and
    non-aligned P / L."""
    from repro.core import space
    from repro.kernels.imc_eval import ref
    from repro.kernels.imc_eval.kernel import imc_eval_pallas_multi

    key = jax.random.PRNGKey(1)
    P, W, L = 70, 3, 13
    g = space.random_genomes(key, P)
    d = jnp.stack(list(space.decode(g)), axis=1)
    feats = jnp.abs(jax.random.normal(key, (W, L, 6))) * 100 + 1
    n_layers = [13, 5, 8]  # ragged
    mask = jnp.stack([jnp.arange(L) < n for n in n_layers])
    e_p, l_p, x_p = imc_eval_pallas_multi(d, feats, mask)
    assert e_p.shape == (W, P)
    for w in range(W):
        e_r, l_r, x_r = ref.eval_one_workload(d, feats[w], mask[w])
        np.testing.assert_allclose(e_p[w], e_r, rtol=2e-5)
        np.testing.assert_allclose(l_p[w], l_r, rtol=2e-5)
        np.testing.assert_allclose(x_p[w], x_r, rtol=2e-5)


def test_imc_eval_multi_workload_single_launch(monkeypatch):
    """A multi-workload evaluation must issue exactly ONE pallas_call and
    stay allclose (rtol 1e-5) to the pure-jnp cost model."""
    from repro.core import space
    from repro.imc.cost import evaluate_designs
    from repro.kernels.imc_eval import kernel as kmod
    from repro.kernels.imc_eval.ops import evaluate_designs_kernel
    from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
    from repro.workloads.pack import pack_workloads

    calls = []
    real = kmod.pl.pallas_call

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(kmod.pl, "pallas_call", counting)
    ws = pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])
    d = space.decode(space.random_genomes(jax.random.PRNGKey(0), 130))
    r = evaluate_designs_kernel(d, ws, backend="pallas")
    ref = evaluate_designs(d, ws)
    assert len(calls) == 1
    np.testing.assert_allclose(r.energy_pj, ref.energy_pj, rtol=1e-5)
    np.testing.assert_allclose(r.latency_ns, ref.latency_ns, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(r.fits), np.asarray(ref.fits))
    np.testing.assert_array_equal(np.asarray(r.valid), np.asarray(ref.valid))
