"""Per-arch smoke + correctness: forward/train/prefill/decode on reduced
configs of all 10 assigned architectures."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec, get_config, list_configs
from repro.launch.cells import make_inputs
from repro.models import transformer
from repro.optim import adamw_init
from repro.train.step import make_train_step

ARCHS = list_configs()
SMOKE = ShapeSpec("smoke", 32, 2, "train")


def _reduced(name, **kw):
    cfg = get_config(name).reduced()
    if cfg.n_experts:  # no-drop capacity for exact path comparisons
        cfg = dataclasses.replace(cfg, capacity_factor=8.0, **kw)
    elif kw:
        cfg = dataclasses.replace(cfg, **kw)
    return cfg


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch, key):
    cfg = _reduced(arch)
    params = transformer.init(cfg, key)
    batch = make_inputs(cfg, SMOKE, key)
    logits, aux = transformer.forward(
        cfg, params, batch["inputs"],
        vision_embeds=batch.get("vision_embeds"),
        mrope_pos=batch.get("mrope_pos"),
        frames=batch.get("frames"),
    )
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, key):
    cfg = _reduced(arch)
    params = transformer.init(cfg, key)
    batch = make_inputs(cfg, SMOKE, key)
    step = jax.jit(make_train_step(cfg, total_steps=10, warmup_steps=1))
    p1, o1, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # lr warms up from 0 — params move from the SECOND step on
    p2, o2, m2 = step(p1, o1, batch)
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch, key):
    cfg = _reduced(arch)
    params = transformer.init(cfg, key)
    batch = make_inputs(cfg, SMOKE, key)
    kw = dict(
        vision_embeds=batch.get("vision_embeds"),
        mrope_pos=batch.get("mrope_pos"),
        frames=batch.get("frames"),
    )
    logits_full, _ = transformer.forward(cfg, params, batch["inputs"], **kw)
    logits_pre, _ = transformer.prefill(cfg, params, batch["inputs"], **kw)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_pre[:, 0], np.float32),
        atol=1e-3,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, key):
    """prefill(S-1) + decode_step == forward(S) at the last position."""
    cfg = _reduced(arch)
    S = SMOKE.seq_len
    params = transformer.init(cfg, key)
    batch = make_inputs(cfg, SMOKE, key)
    toks = batch["inputs"]
    kw = dict(
        vision_embeds=batch.get("vision_embeds"),
        mrope_pos=batch.get("mrope_pos"),
        frames=batch.get("frames"),
    )
    logits_full, _ = transformer.forward(cfg, params, toks, **kw)
    kw2 = dict(kw)
    if kw2.get("mrope_pos") is not None:
        kw2["mrope_pos"] = kw2["mrope_pos"][:, :, : S - 1]
    if kw2.get("frames") is not None:
        kw2["frames"] = kw2["frames"][:, : S - 1]
    _, cache = transformer.prefill(
        cfg, params, toks[:, : S - 1], cache_dtype=jnp.float32, **kw2
    )
    cache = transformer.pad_cache(cfg, cache, S)
    pos = jnp.full((2,), S - 1, jnp.int32)
    ld, _ = transformer.decode_step(cfg, params, cache, toks[:, S - 1 : S], pos)
    err = float(jnp.abs(logits_full[:, -1] - ld[:, 0]).max())
    assert err < 0.15, err  # SSD chunked-vs-step accumulation tolerance


def test_decode_per_slot_positions(key):
    """Vector pos: two sequences decoding at DIFFERENT positions must match
    their scalar-pos decodes exactly (continuous batching invariant)."""
    cfg = _reduced("llama3.2-1b")
    params = transformer.init(cfg, key)
    S = 16
    toks = jax.random.randint(key, (2, S), 0, cfg.vocab_size, jnp.int32)
    _, cache = transformer.prefill(cfg, params, toks, cache_dtype=jnp.float32)
    cache = transformer.pad_cache(cfg, cache, S + 4)
    tok_new = jax.random.randint(jax.random.PRNGKey(9), (2, 1), 0, cfg.vocab_size, jnp.int32)
    # mixed positions: slot 0 at S, slot 1 at S (same here) vs vector API
    pos_vec = jnp.asarray([S, S], jnp.int32)
    l_vec, _ = transformer.decode_step(cfg, params, cache, tok_new, pos_vec)
    l_scalar, _ = transformer.decode_step(
        cfg, params, cache, tok_new, jnp.asarray(S, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(l_vec), np.asarray(l_scalar), atol=1e-5)


def test_sliding_window_ring_evicts(key):
    """With SWA, tokens older than the window must not influence decode."""
    cfg = _reduced("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = transformer.init(cfg, key)
    S, W = 24, 8
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size, jnp.int32)
    # full prefill cache (ring) vs prefill of only the last W tokens
    _, cache_full = transformer.prefill(cfg, params, toks, cache_dtype=jnp.float32)
    logits_ring, _ = transformer.decode_step(
        cfg, params, transformer.pad_cache(cfg, cache_full, S + 1),
        toks[:, -1:], jnp.asarray(S, jnp.int32),
    )
    assert bool(jnp.isfinite(logits_ring.astype(jnp.float32)).all())


def test_moe_capacity_drops_tokens(key):
    """With tiny capacity, MoE output differs from the no-drop case."""
    from repro.models.moe import moe_ffn

    B, S, d, E, f, k = 1, 32, 8, 4, 16, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, d))
    r = jax.random.normal(ks[1], (d, E))
    wg, wu, wd = (jax.random.normal(ks[i], s) * 0.2 for i, s in
                  [(2, (E, d, f)), (3, (E, d, f)), (4, (E, f, d))])
    y_nodrop, _ = moe_ffn(x, r, wg, wu, wd, topk=k, capacity_factor=16.0)
    y_drop, _ = moe_ffn(x, r, wg, wu, wd, topk=k, capacity_factor=0.3)
    assert float(jnp.abs(y_nodrop - y_drop).max()) > 1e-4


def test_moe_combine_weights_normalized(key):
    """Top-k gate weights renormalize to 1 -> output scale independent of E."""
    from repro.models.moe import moe_ffn

    B, S, d, E, f = 1, 8, 4, 8, 8
    x = jnp.ones((B, S, d))
    r = jnp.zeros((d, E))  # uniform router
    wg = jnp.ones((E, d, f)) * 0.1
    wu = jnp.ones((E, d, f)) * 0.1
    wd = jnp.ones((E, f, d)) * 0.1
    y1, _ = moe_ffn(x, r, wg, wu, wd, topk=1, capacity_factor=8.0)
    y2, _ = moe_ffn(x, r, wg, wu, wd, topk=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_rope_relative_property(key):
    """RoPE: <q_i, k_j> depends only on i - j."""
    from repro.models.common import apply_rope

    D = 64
    q = jax.random.normal(key, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 1, 1, D))
    def score(i, j):
        qr = apply_rope(q, jnp.asarray([[i]]), 10_000.0)
        kr = apply_rope(k, jnp.asarray([[j]]), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(score(5, 3) - score(105, 103)) < 1e-3


def test_param_count_matches_arrays(key):
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = transformer.init(cfg, key)
        n_arrays = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        n_analytic = cfg.param_count()
        assert abs(n_arrays - n_analytic) / n_arrays < 0.02, (
            arch, n_arrays, n_analytic)


def test_full_config_param_counts():
    """Full (non-reduced) configs land near their nameplate sizes."""
    expect = {
        "yi-9b": (8.0e9, 10.0e9),
        "gemma-7b": (8.0e9, 10.0e9),   # 8.5B with embeddings
        "qwen2-72b": (70e9, 75e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "mamba2-780m": (0.6e9, 0.9e9),
        "mixtral-8x7b": (45e9, 48e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "jamba-v0.1-52b": (48e9, 56e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
