"""Paper core: search space, GA operators, objectives, joint/separate.

(Property-based variants live in test_properties.py, guarded on
hypothesis being installed; batched-vs-sequential parity in
test_search_batched.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import space
from repro.core.ga import _tournament, run_ga
from repro.core.objectives import OBJECTIVES, make_objective
from repro.core.search import (
    joint_search,
    largest_workload_index,
    rescore_designs,
    seed_population,
    separate_search,
)
from repro.imc.cost import evaluate_designs
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


# ---------------------------------------------------------------- search space
def test_space_size_matches_paper():
    # paper Sec. III-B: ~1.9e7 configurations
    assert 1.8e7 < space.SPACE_SIZE < 2.0e7


def test_decode_hits_every_grid_value():
    for i, f in enumerate(space.FIELDS):
        n = len(space.SPACE[f])
        g = np.full((n, space.N_GENES), 0.5, np.float32)
        g[:, i] = (np.arange(n) + 0.5) / n
        vals = np.asarray(getattr(space.decode(jnp.asarray(g)), f))
        np.testing.assert_allclose(vals, space.SPACE[f], rtol=1e-6)


# ---------------------------------------------------------------- GA operators
def test_tournament_prefers_better():
    scores = jnp.asarray([0.0, 1.0, 2.0, 3.0])
    winners = _tournament(jax.random.PRNGKey(0), scores, 256)
    # winner of each pair has the lower score -> mean winner score below mean
    assert float(scores[winners].mean()) < float(scores.mean())


def test_ga_monotone_convergence(ws):
    key = jax.random.PRNGKey(0)
    res = joint_search(key, ws, pop_size=16, generations=4)
    conv = res.convergence
    assert (np.diff(conv[np.isfinite(conv)]) <= 1e-6).all()


# ----------------------------------------------------------------- objectives
def test_objectives_inf_on_infeasible(ws):
    g = space.random_genomes(jax.random.PRNGKey(0), 256)
    r = evaluate_designs(space.decode(g), ws)
    for kind in OBJECTIVES:
        s = make_objective(kind, 150.0)(r)
        feasible = np.asarray(r.fits.all(-1) & r.valid & (r.area_mm2 <= 150.0))
        assert (np.isfinite(np.asarray(s)) == feasible).all()


def test_area_constraint_binds(ws):
    g = space.random_genomes(jax.random.PRNGKey(1), 512)
    r = evaluate_designs(space.decode(g), ws)
    s_tight = make_objective("ela", 50.0)(r)
    s_loose = make_objective("ela", 1e9)(r)
    assert np.isfinite(np.asarray(s_loose)).sum() >= np.isfinite(np.asarray(s_tight)).sum()


# ------------------------------------------------------------ search behaviour
def test_seed_population_fits_largest(ws):
    pop = seed_population(jax.random.PRNGKey(0), ws, 16)
    wl = ws.subset([largest_workload_index(ws)])
    r = evaluate_designs(space.decode(pop), wl)
    assert bool(r.fits[:, 0].all()) and bool(r.valid.all())


def test_largest_workload_is_vgg16(ws):
    assert ws.names[largest_workload_index(ws)] == "vgg16"


def test_joint_beats_or_ties_separate_on_set(ws):
    """The paper's core claim, in miniature: re-scored on ALL workloads,
    the joint search's best is at least as good as every separate search's
    best (and most separate winners fail outright)."""
    key = jax.random.PRNGKey(0)
    joint = joint_search(key, ws, pop_size=24, generations=6)
    sep = separate_search(jax.random.PRNGKey(1), ws, pop_size=24, generations=6)
    jbest = joint.top_scores[0]
    for name, r in sep.items():
        if not len(r.top_genomes):
            continue
        s_all, _ = rescore_designs(r.top_genomes, ws)
        s_all = s_all[np.isfinite(s_all)]
        if len(s_all):
            assert jbest <= s_all.min() * 1.05  # joint no worse (5% slack)


def test_rescore_identity(ws):
    res = joint_search(jax.random.PRNGKey(0), ws, pop_size=16, generations=3)
    s, _ = rescore_designs(res.top_genomes, ws)
    np.testing.assert_allclose(s, res.top_scores, rtol=1e-5)
