"""Pareto-front DSE: NSGA-II survival + front epilogue, oracle-pinned.

The contract this module pins (ISSUE 10 tentpole):

  * **Oracle parity** — the batched in-jit non-dominated sort
    (``ga._dominance_rank``), folded-bit crowding (``ga._crowding``) and
    the full front epilogue (``ga._pareto_epilogue``) are BIT-identical
    to a brute-force numpy O(N^2) dominance oracle, under adversarial
    inputs: duplicate decoded cells, -0.0/+0.0 ties, tied all-+inf
    infeasible rows, and NaN-guarded rows.
  * **Mode invariance** — fused and unfused survival, thin and
    history-returning runs, sequential and pipelined engines, table and
    jnp backends all select the same front, bit-for-bit.
  * **Engine semantics** — ``SearchRequest(objective="pareto")`` plans
    into its own signature group, validates eagerly, returns per-member
    (E, L, A) ``objective_vectors``, and round-trips the result cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ga, space
from repro.core.engine import SearchEngine, SearchRequest, plan_batch
from repro.core.ga import (
    ParetoThin,
    pareto_epilogue_batched,
    run_pareto_batched,
)
from repro.core.objectives import N_PARETO, PARETO, pareto_scalar
from repro.core.search import run_search
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS, K = 12, 4, 6
SENTINEL = np.int32(0x7FFFFFFF)


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


# ----------------------------------------------------------- numpy oracle
def np_fold_bits(x: np.ndarray) -> np.ndarray:
    """The sign-folded total-order int32 key, host reference of
    ``ga._fold_bits``."""
    bits = np.ascontiguousarray(np.asarray(x, np.float32)).view(np.int32)
    return np.where(bits < 0, -(bits & SENTINEL), bits).astype(np.int32)


def np_dominance_rank(objs: np.ndarray) -> np.ndarray:
    """Brute-force O(N^2) dominance mask + front peeling — the reference
    algorithm ``ga._dominance_rank`` implements in-jit, replayed in
    plain numpy."""
    o = np.asarray(objs, np.float32)
    N = o.shape[0]
    le = (o[:, None, :] <= o[None, :, :]).all(axis=-1)
    lt = (o[:, None, :] < o[None, :, :]).any(axis=-1)
    dom = le & lt
    rank = np.full(N, -1, np.int32)
    r = 0
    while (rank < 0).any():
        unassigned = rank < 0
        blocked = (dom & unassigned[:, None]).any(axis=0)
        front = unassigned & ~blocked
        rank[front] = r
        r += 1
    return rank


def np_crowding(objs: np.ndarray) -> np.ndarray:
    """Crowding distance in folded-bit space, mirroring ``ga._crowding``
    operation for operation (same f32 arithmetic, same unique sort
    order, same per-objective accumulation order)."""
    o = np.asarray(objs, np.float32)
    N, M = o.shape
    total = np.zeros(N, np.float32)
    for m in range(M):
        key = np_fold_bits(o[:, m])
        perm = np.lexsort((np.arange(N), key))  # unique (key, index) order
        kf = key[perm].astype(np.float32)
        span = np.float32(kf[-1] - kf[0])
        prev = np.concatenate([kf[:1], kf[:-1]])
        nxt = np.concatenate([kf[1:], kf[-1:]])
        with np.errstate(invalid="ignore", divide="ignore"):
            d = np.where(span > 0, (nxt - prev) / span,
                         np.float32(0.0)).astype(np.float32)
        d[0] = np.inf
        d[N - 1] = np.inf
        total[perm] += d
    return total


def np_crowded_order_keys(objs: np.ndarray):
    rank = np_dominance_rank(objs)
    crowd = np_crowding(objs)
    ckey = (-crowd.view(np.int32)).astype(np.int32)
    return rank, ckey


def np_pareto_epilogue(genomes_hist, objs_hist, top_k: int):
    """Host replay of ``ga._pareto_epilogue``: crowded-order positions
    over all evaluated designs, feasibility mask, greedy best-unseen-cell
    picks (whole decoded cell retired per pick), E*L*A convergence."""
    gh = np.asarray(genomes_hist, np.float32)
    oh = np.asarray(objs_hist, np.float32)
    G1, P, n = gh.shape
    M = oh.shape[-1]
    N = G1 * P
    flat_g = gh.reshape(N, n)
    flat_o = oh.reshape(N, M)
    flat_s = ((flat_o[:, 0] * flat_o[:, 1]) * flat_o[:, 2]).astype(np.float32)
    rank, ckey = np_crowded_order_keys(flat_o)
    feas = np.isfinite(flat_o).all(axis=-1)
    perm = np.lexsort((np.arange(N), ckey, rank))
    pos = np.empty(N, np.int64)
    pos[perm] = np.arange(N)
    okey = np.where(feas, pos, np.int64(SENTINEL))
    cells = [tuple(r) for r in space.decode_indices_np(flat_g)]
    k = min(int(top_k), N)
    top_g = np.zeros((k, n), np.float32)
    top_v = np.full((k, M), np.inf, np.float32)
    top_s = np.full((k,), np.inf, np.float32)
    kept = 0
    for i in range(k):
        j = int(np.argmin(okey))
        if okey[j] < SENTINEL:
            top_g[i] = flat_g[j]
            top_v[i] = flat_o[j]
            top_s[i] = flat_s[j]
            kept += 1
        cj = cells[j]
        for t in range(N):
            if cells[t] == cj:
                okey[t] = SENTINEL
    conv = np.minimum.accumulate(flat_s.reshape(G1, P).min(axis=1))
    return ParetoThin(top_genomes=top_g, top_vectors=top_v, top_scores=top_s,
                      n_kept=np.int32(kept), convergence=conv)


# -------------------------------------------------- adversarial objectives
def _adversarial_objs(rng: np.random.Generator, n: int) -> np.ndarray:
    """(n, 3) f32 objective vectors with the full pathology menu:
    duplicates, +/-0.0 ties, whole all-+inf infeasible rows, NaN rows."""
    o = rng.uniform(0.5, 4.0, size=(n, N_PARETO)).astype(np.float32)
    o[rng.random(n) < 0.3] = np.inf          # tied infeasible rows
    dup = rng.integers(0, n, size=n // 4)
    o[dup] = o[rng.integers(0, n, size=n // 4)]  # exact duplicates
    zero = rng.random((n, N_PARETO)) < 0.1
    o[zero] = np.float32(-0.0)               # -0.0 vs +0.0 ties
    o[zero & (rng.random((n, N_PARETO)) < 0.5)] = np.float32(0.0)
    o[rng.random(n) < 0.05] = np.nan         # NaN-guard rows
    return o


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n", [1, 2, 7, 40])
def test_sort_keys_match_numpy_oracle(seed, n):
    """The batched non-dominated sort and folded-bit crowding are
    bit-identical to the numpy O(N^2) oracle under adversarial scores."""
    o = _adversarial_objs(np.random.default_rng(seed), n)
    rank = np.asarray(jax.jit(ga._dominance_rank)(jnp.asarray(o)))
    crowd = np.asarray(jax.jit(ga._crowding)(jnp.asarray(o)))
    jrank, jckey = (np.asarray(a) for a in
                    jax.jit(ga._crowded_order_keys)(jnp.asarray(o)))
    np.testing.assert_array_equal(rank, np_dominance_rank(o))
    # bitwise float comparison: view as int so -0.0 != 0.0 and NaN == NaN
    np.testing.assert_array_equal(crowd.view(np.int32),
                                  np_crowding(o).view(np.int32))
    nrank, nckey = np_crowded_order_keys(o)
    np.testing.assert_array_equal(jrank, nrank)
    np.testing.assert_array_equal(jckey, nckey)


def test_rank_semantics_small_case():
    """Hand-checkable front structure: rank 0 = the non-dominated set,
    dominated rows peel into later fronts, all-+inf rows land last."""
    o = np.array([
        [1.0, 4.0, 1.0],   # front 0 (best e)
        [4.0, 1.0, 1.0],   # front 0 (best l)
        [2.0, 2.0, 1.0],   # front 0 (trade-off)
        [2.0, 2.0, 2.0],   # dominated by row 2 -> front 1
        [5.0, 5.0, 5.0],   # dominated by everything finite -> front 2
        [np.inf] * 3,      # infeasible: dominated by all feasible rows
        [np.inf] * 3,      # ... and tied with its twin
    ], np.float32)
    rank = np.asarray(ga._dominance_rank(jnp.asarray(o)))
    assert rank.tolist() == [0, 0, 0, 1, 2, 3, 3]
    np.testing.assert_array_equal(rank, np_dominance_rank(o))


def test_crowding_boundaries_are_inf_interior_normalized():
    o = np.array([[1.0, 9.0], [5.0, 5.0], [9.0, 1.0]], np.float32)
    crowd = np.asarray(ga._crowding(jnp.asarray(o)))
    assert np.isinf(crowd[0]) and np.isinf(crowd[2])
    assert np.isfinite(crowd[1]) and crowd[1] > 0
    np.testing.assert_array_equal(crowd.view(np.int32),
                                  np_crowding(o).view(np.int32))


# --------------------------------------------------- ga-level front search
def _toy_eval(genomes, _ctx=None):
    """Deterministic (P, 3) objectives over real genomes: decoded-cell
    dependent (so duplicate cells collide exactly), with an infeasible
    band — everything the epilogue's dedup/masking must survive."""
    idx = space.decode_indices(genomes).astype(jnp.float32)
    e = 1.0 + idx[:, 0] + 2.0 * idx[:, 1]
    l = 1.0 + idx[:, 2] + 3.0 * idx[:, 3]
    a = 1.0 + idx[:, 4]
    feas = (idx[:, 5] > 0.0)
    objs = jnp.stack([e, l, a], axis=-1)
    return jnp.where(feas[:, None], objs, jnp.inf)


def _toy_run(fused, history, top_k=K, B=3):
    keys = jax.random.split(jax.random.PRNGKey(0), B)
    init = jax.vmap(lambda k: space.random_genomes(k, POP))(
        jax.random.split(jax.random.PRNGKey(1), B))
    return run_pareto_batched(
        keys, _toy_eval, pop_size=POP, generations=GENS,
        init_genomes=init, top_k=top_k, fused=fused, history=history)


def _assert_thin_equal(a: ParetoThin, b: ParetoThin):
    for f, g in zip(a, b):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(g))


def test_front_matches_numpy_oracle_over_evaluated_designs():
    """Acceptance: the returned k-member front is bit-identical to the
    numpy dominance oracle replayed over the SAME evaluated designs."""
    gh, oh, thin = _toy_run(fused=True, history=True)
    for b in range(np.asarray(gh).shape[0]):
        oracle = np_pareto_epilogue(np.asarray(gh)[b], np.asarray(oh)[b], K)
        got = ParetoThin(*(np.asarray(f)[b] for f in thin))
        np.testing.assert_array_equal(got.top_genomes, oracle.top_genomes)
        np.testing.assert_array_equal(got.top_vectors, oracle.top_vectors)
        np.testing.assert_array_equal(got.top_scores, oracle.top_scores)
        assert int(got.n_kept) == int(oracle.n_kept)
        np.testing.assert_array_equal(got.convergence, oracle.convergence)
        # semantic spot-checks on the kept members.  Picks spill past the
        # first front when it has fewer unique cells than top_k, so the
        # invariant is rank-ORDERING, not mutual non-dominance.
        kept = int(got.n_kept)
        v = got.top_vectors[:kept]
        assert np.isfinite(v).all()
        hist_o = np.asarray(oh)[b].reshape(-1, N_PARETO)
        rank = np_dominance_rank(hist_o)
        pick_ranks = [int(rank[(hist_o == row).all(-1)].min()) for row in v]
        assert pick_ranks == sorted(pick_ranks), "picks must be rank-ordered"
        assert pick_ranks[0] == 0, "first pick must be non-dominated"
        cells = {tuple(r) for r in space.decode_indices_np(got.top_genomes[:kept])}
        assert len(cells) == kept, "front members must be cell-unique"


def test_fused_unfused_and_thin_history_parity():
    """Fused vs unfused NSGA-II survival and thin vs history-returning
    runs are all bit-identical; the standalone batched epilogue over the
    returned history reproduces the fused-in thin outputs."""
    thin_f = _toy_run(fused=True, history=False)
    thin_u = _toy_run(fused=False, history=False)
    gh, oh, thin_h = _toy_run(fused=True, history=True)
    _assert_thin_equal(ParetoThin(*map(np.asarray, thin_f)),
                       ParetoThin(*map(np.asarray, thin_u)))
    _assert_thin_equal(ParetoThin(*map(np.asarray, thin_f)),
                       ParetoThin(*map(np.asarray, thin_h)))
    standalone = pareto_epilogue_batched(np.asarray(gh), np.asarray(oh),
                                         top_k=K)
    _assert_thin_equal(ParetoThin(*map(np.asarray, thin_f)),
                       ParetoThin(*map(np.asarray, standalone)))


def test_large_k_covers_whole_first_front_before_spilling():
    """With top_k >= #evaluated designs the picks enumerate every unique
    feasible cell in crowded order: rank-0 cells first, then rank 1..."""
    gh, oh, thin = _toy_run(fused=True, history=True, top_k=(GENS + 1) * POP,
                            B=1)
    oh0 = np.asarray(oh)[0].reshape(-1, N_PARETO)
    rank = np_dominance_rank(oh0)
    kept = int(np.asarray(thin.n_kept)[0])
    v = np.asarray(thin.top_vectors)[0][:kept]
    # recover each pick's rank by matching its vector against the history
    pick_ranks = []
    for row in v:
        m = (oh0 == row).all(-1)
        pick_ranks.append(int(rank[m].min()))
    assert pick_ranks == sorted(pick_ranks), "picks must be rank-ordered"
    n_front0_cells = len({
        tuple(r) for r, rk, f in zip(
            space.decode_indices_np(np.asarray(gh)[0].reshape(-1, space.N_GENES)),
            rank, np.isfinite(oh0).all(-1)) if rk == 0 and f
    })
    assert pick_ranks.count(0) == n_front0_cells


# -------------------------------------------------------- engine end-to-end
def _pareto_reqs(ws, backend, n=3):
    return [
        SearchRequest(
            ws=ws.subset([i % ws.n, (i + 1) % ws.n]), objective=PARETO,
            backend=backend, pop_size=POP, generations=GENS,
            pareto_k=K, seed=i, area_constr=150.0 + 10.0 * (i % 2),
        )
        for i in range(n)
    ]


@pytest.mark.parametrize("backend", ["table", "jnp"])
def test_engine_modes_bit_identical(ws, backend):
    """Sequential vs pipelined vs unfused engines return the same front
    bits on both backends; pipelined results are thin (ga=None) but carry
    identical vectors/designs."""
    reqs = _pareto_reqs(ws, backend)
    seq = SearchEngine().run(reqs)
    pipe = SearchEngine(pipelined=True).run(reqs)
    unfused = SearchEngine(fused=False).run(reqs)
    for a, b, c in zip(seq, pipe, unfused):
        assert a.objective == PARETO
        assert a.ga is not None and b.ga is None and c.ga is not None
        for other in (b, c):
            np.testing.assert_array_equal(a.top_genomes, other.top_genomes)
            np.testing.assert_array_equal(a.top_scores, other.top_scores)
            np.testing.assert_array_equal(a.objective_vectors,
                                          other.objective_vectors)
            np.testing.assert_array_equal(a.convergence, other.convergence)
            assert a.top_designs == other.top_designs
            assert a.valid == other.valid
        kept = len(a.top_scores)
        assert a.objective_vectors.shape == (kept, N_PARETO)
        assert kept <= K
        if a.valid:
            # the leading pick is non-dominated within the returned set
            # (later picks may spill into higher fronts when the first
            # front runs out of unique cells)
            v = a.objective_vectors
            dom0 = ((v <= v[0]).all(-1) & (v < v[0]).any(-1))
            assert not dom0.any()
        # the scalar proxy is the E*L*A product of the member's vector
        np.testing.assert_array_equal(
            a.top_scores,
            (a.objective_vectors[:, 0] * a.objective_vectors[:, 1])
            * a.objective_vectors[:, 2])


def test_pareto_plans_into_own_signature_group(ws):
    """Pareto requests never share a compiled program with scalar ones:
    plan_batch puts them in their own signature group."""
    reqs = [
        SearchRequest(ws=ws, objective="ela", backend="table",
                      pop_size=POP, generations=GENS),
        SearchRequest(ws=ws, objective=PARETO, backend="table",
                      pop_size=POP, generations=GENS),
    ]
    plans = plan_batch(reqs, max_slots=8)
    assert len(plans) == 2
    sigs = {p.signature for p in plans}
    assert len(sigs) == 2
    assert any(("pareto",) in s for s in sigs)


def test_signature_validation():
    ws1 = pack_workloads([(PAPER_WORKLOADS[0],
                           cnn_workload(PAPER_WORKLOADS[0]))])
    with pytest.raises(ValueError, match="obj_weights"):
        SearchRequest(ws=ws1, objective=PARETO,
                      obj_weights=(1.0, 1.0, 1.0)).signature()
    with pytest.raises(ValueError, match="pareto_k"):
        SearchRequest(ws=ws1, objective=PARETO, pareto_k=0).signature()
    with pytest.raises(ValueError, match="pareto"):
        SearchRequest(ws=ws1, objective="nope").signature()


def test_run_search_driver_and_pareto_k_slicing(ws):
    """The run_search driver threads pareto_k through; a smaller k is a
    prefix of a larger k's front (selection is prefix-stable)."""
    k1 = jax.random.PRNGKey(5)
    big = run_search(k1, ws, objective=PARETO, pop_size=POP,
                     generations=GENS, pareto_k=K, backend="table")
    small = run_search(k1, ws, objective=PARETO, pop_size=POP,
                       generations=GENS, pareto_k=2, backend="table")
    assert big.objective == PARETO and big.objective_vectors is not None
    np.testing.assert_array_equal(small.top_genomes,
                                  big.top_genomes[:len(small.top_scores)])
    np.testing.assert_array_equal(small.objective_vectors,
                                  big.objective_vectors[:len(small.top_scores)])


def test_pareto_result_cache_round_trip(ws, tmp_path):
    """Pareto results (thin and full) round-trip the result cache with
    objective_vectors intact, and pareto_k enters the request key."""
    from repro.serve.cache import ResultCache, request_key

    req = _pareto_reqs(ws, "table", n=1)[0]
    assert request_key(req) != request_key(
        dataclasses.replace(req, pareto_k=req.pareto_k + 1))
    cache = ResultCache(disk_dir=tmp_path)
    eng = SearchEngine(pipelined=True, result_cache=cache)
    first = eng.run([req])[0]
    launches = eng.launches
    again = eng.run([req])[0]
    assert eng.launches == launches
    # cold-process disk decode path
    fresh = ResultCache(disk_dir=tmp_path).get(req)
    for other in (again, fresh):
        assert other.objective == PARETO and other.ga is None
        np.testing.assert_array_equal(first.top_genomes, other.top_genomes)
        np.testing.assert_array_equal(first.objective_vectors,
                                      other.objective_vectors)
        np.testing.assert_array_equal(first.convergence, other.convergence)
        assert first.top_designs == other.top_designs


def test_pareto_scalar_matches_ela_bits(ws):
    """A pareto request's convergence curve is bit-identical to the same
    search run under the scalar 'ela' objective... is NOT required (the
    trajectories differ), but the scalar proxy of each returned vector
    must reproduce the ela formula bits: (E*L)*A in f32."""
    res = run_search(jax.random.PRNGKey(2), ws, objective=PARETO,
                     pop_size=POP, generations=GENS, pareto_k=K,
                     backend="table")
    v = jnp.asarray(res.objective_vectors)
    np.testing.assert_array_equal(np.asarray(pareto_scalar(v)),
                                  res.top_scores)
