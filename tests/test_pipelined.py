"""Pipelined engine: transfer-thin epilogue + overlapped dispatch/harvest.

The contract this module pins (ISSUE 9, perf_opt PR):

  * **Bit-parity** — ``pipelined=True`` execution (on-device top-k-unique
    epilogue, only (top_k, n) genomes + (top_k,) scores + the convergence
    curve cross the wire) reproduces the sequential history-syncing path
    bit-for-bit: every result field except ``ga`` (``None`` when thin —
    the history never reaches host), on every backend, odd populations,
    ragged mixed-subset multi-chunk batches, segmented chains, streaming
    snapshots, fault partials, checkpoints, and the fake-8-device mesh.
  * **Epilogue semantics** — the in-jit epilogue matches the host
    ``_top_unique`` exactly, pinned adversarially on duplicate decoded
    cells, +/-inf scores, and -0.0/+0.0 ties.
  * **No stray syncs** — the warm pipelined segmented loop never blocks
    on a device->host array transfer (the old per-segment
    ``int(np.asarray(state.gen))`` regression), and the harvested bytes
    are >= 10x smaller than the history-syncing path's.
  * **Service drain** — ``DSEService(pipelined=True)`` (sync and async)
    double-buffers dispatch/harvest with unchanged results, yield order
    and launch count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core import space
from repro.core.engine import (
    EngineFault,
    SearchEngine,
    SearchRequest,
    _top_unique,
    plan_batch,
)
from repro.core.ga import ga_epilogue_batched
from repro.core.search import batched_search, run_search
from repro.serve.dse import AsyncDSEService, DSEService, paper_request_mix
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS = 14, 5


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _same_thin(thin, full):
    """A pipelined result equals its sequential twin on every field the
    thin path carries; ``ga`` is ``None`` by contract (history on device)."""
    assert thin.ga is None and full.ga is not None
    np.testing.assert_array_equal(thin.top_scores, full.top_scores)
    np.testing.assert_array_equal(thin.top_genomes, full.top_genomes)
    assert thin.top_designs == full.top_designs
    np.testing.assert_array_equal(thin.convergence, full.convergence)
    assert thin.valid == full.valid
    assert thin.generations == full.generations
    assert thin.objective == full.objective
    assert thin.workload_names == full.workload_names


def _reqs(ws, n, *, backend="table", gens=GENS, seed0=0, top_ks=(3, 7)):
    subsets = [[0, 1, 2, 3], [0], [1, 2]]
    return [
        SearchRequest(ws=ws.subset(subsets[i % 3]), seed=seed0 + i,
                      backend=backend, pop_size=POP, generations=gens,
                      top_k=top_ks[i % len(top_ks)])
        for i in range(n)
    ]


# ------------------------------------------------------------ basic parity
@pytest.mark.parametrize("backend", ["jnp", "table", "pallas"])
def test_pipelined_sequential_parity_all_backends(ws, backend):
    key = jax.random.PRNGKey(11)
    a = run_search(key, ws, pop_size=16, generations=4, backend=backend,
                   pipelined=True)
    b = run_search(key, ws, pop_size=16, generations=4, backend=backend,
                   pipelined=False)
    _same_thin(a, b)


@pytest.mark.parametrize("pop", [15, 17])
def test_pipelined_parity_odd_pop(ws, pop):
    key = jax.random.PRNGKey(5)
    a = run_search(key, ws, pop_size=pop, generations=3, backend="table",
                   top_k=7, pipelined=True)
    b = run_search(key, ws, pop_size=pop, generations=3, backend="table",
                   top_k=7, pipelined=False)
    _same_thin(a, b)


def test_pipelined_parity_ragged_multichunk_batch(ws):
    """Mixed workload subsets + mixed top_k across MULTIPLE chunks (small
    max_slots forces >1 launch): back-to-back dispatches then a harvest
    pass must equal the launch-sync-launch reference per element."""
    reqs = _reqs(ws, 5, seed0=100)
    seq = SearchEngine(max_slots=2).run(reqs)
    pip = SearchEngine(max_slots=2, pipelined=True)
    out = pip.run(reqs)
    assert pip.launches >= 3  # 5 requests over 2 slots = 3 chunks
    for a, b in zip(out, seq):
        _same_thin(a, b)


def test_pipelined_parity_ragged_batched_search(ws):
    subsets = [[0], [1, 2], [0, 1, 2, 3]]
    sets = [ws.subset(s) for s in subsets]
    W = max(s.n for s in sets)
    L = ws.feats.shape[1]
    B = len(sets)
    feats = np.zeros((B, W, L, 6), np.float32)
    mask = np.zeros((B, W, L), bool)
    for i, s in enumerate(sets):
        feats[i, : s.n] = np.asarray(s.feats)
        mask[i, : s.n] = np.asarray(s.mask)
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(B)])
    ra = batched_search(keys, feats, mask, pop_size=12, generations=3,
                        backend="table", pipelined=True)
    rb = batched_search(keys, feats, mask, pop_size=12, generations=3,
                        backend="table", pipelined=False)
    for a, b in zip(ra, rb):
        _same_thin(a, b)


def test_pipelined_parity_segmented_chain(ws):
    """Pipelined x segmented: the device-resident history chain + thin
    final epilogue equals the sequential segmented engine AND the plain
    single shot."""
    reqs = _reqs(ws, 3, seed0=20)
    single = SearchEngine().run(reqs)
    out = SearchEngine(segment_gens=2, pipelined=True).run(reqs)
    for a, b in zip(out, single):
        _same_thin(a, b)


def test_pipelined_fused_cross_parity(ws):
    """pipelined x fused compose: both knobs on equals both knobs off."""
    reqs = _reqs(ws, 2, seed0=30)
    ref = SearchEngine(fused=False).run(reqs)
    out = SearchEngine(fused=True, pipelined=True).run(reqs)
    for a, b in zip(out, ref):
        _same_thin(a, b)


@pytest.mark.multidevice
def test_pipelined_sharded_parity(ws):
    from repro.launch.mesh import make_search_mesh

    reqs = _reqs(ws, 4, seed0=40)
    ref = SearchEngine().run(reqs)
    eng = SearchEngine(mesh=make_search_mesh(2, 4), pipelined=True)
    for a, b in zip(eng.run(reqs), ref):
        _same_thin(a, b)


# ---------------------------------------------------- epilogue adversarial
def _epilogue_vs_host(genomes_hist, scores_hist, top_k):
    """One batch slot through the thin epilogue vs the host reference."""
    thin = ga_epilogue_batched(genomes_hist[None], scores_hist[None],
                               top_k=top_k)
    tg = np.asarray(thin.top_genomes[0])
    ts = np.asarray(thin.top_scores[0])
    kept = min(int(thin.n_kept[0]), top_k)
    flat_g = genomes_hist.reshape(-1, genomes_hist.shape[-1])
    flat_s = scores_hist.reshape(-1)
    rg, rs = _top_unique(flat_g, flat_s, top_k)
    assert kept == len(rs)
    np.testing.assert_array_equal(ts[:kept], rs)
    np.testing.assert_array_equal(tg[:kept], rg)
    # convergence: running min of the per-generation minima
    np.testing.assert_array_equal(
        np.asarray(thin.convergence[0]),
        np.minimum.accumulate(scores_hist.min(axis=1)),
    )


def test_epilogue_top_unique_adversarial_ties():
    """Duplicate decoded cells, +/-inf, NaN, and -0.0/+0.0 ties: the
    in-jit epilogue keeps exactly ``_top_unique``'s stable tie-break —
    first (earliest flat index) occurrence of each unique decoded design
    at its best score, non-finite dropped."""
    rng = np.random.default_rng(0)
    G, P = 4, 8
    base = np.asarray(space.random_genomes(jax.random.PRNGKey(2), P))
    g = np.tile(base[None], (G, 1, 1)).astype(np.float32)
    # rows 0/1 of every generation decode to the SAME cell as each other
    g[:, 1] = g[:, 0]
    # a second occurrence of cell 0 with a DIFFERENT float genome (same
    # decoded cell) — the signed-zero tie-break below picks one of the
    # two visibly, via the returned genome row
    g[1, 0] = np.clip(g[0, 0] + 1e-4, 0.0, 1.0).astype(np.float32)
    assert np.array_equal(space.decode_indices_np(g[1, 0][None]),
                          space.decode_indices_np(g[0, 0][None]))
    s = (np.abs(rng.standard_normal((G, P))) + 1.0).astype(np.float32)
    # cell 0's BEST score is a -0.0/+0.0 tie across two occurrences: the
    # stable rule keeps the earliest flat index (gen 0's -0.0 genome)
    s[0, 0] = -0.0
    s[1, 0] = +0.0
    # duplicated +inf occurrences and a NaN poke the non-finite drop
    s[0, 3] = np.inf
    s[1, 3] = np.inf
    s[2, 5] = np.nan
    _epilogue_vs_host(g, s, top_k=5)


def test_epilogue_all_nonfinite_and_topk_over_n():
    g = np.asarray(space.random_genomes(jax.random.PRNGKey(3), 4))
    hist_g = np.tile(g[None], (2, 1, 1)).astype(np.float32)
    hist_s = np.full((2, 4), np.inf, np.float32)
    _epilogue_vs_host(hist_g, hist_s, top_k=3)
    # top_k larger than the whole history: kept = #unique finite designs
    hist_s2 = np.arange(8, dtype=np.float32).reshape(2, 4)
    _epilogue_vs_host(hist_g, hist_s2, top_k=64)


def test_epilogue_duplicate_scores_distinct_cells():
    """Equal scores on DIFFERENT cells: both kept, history order."""
    P = 6
    g = np.asarray(space.random_genomes(jax.random.PRNGKey(4), P))
    hist_g = g[None].astype(np.float32)
    hist_s = np.zeros((1, P), np.float32)  # all tied
    _epilogue_vs_host(hist_g, hist_s, top_k=P)


def test_engine_invalid_when_all_infeasible(ws):
    """A search whose every score is +inf finalizes thin as invalid —
    same contract as the history path."""
    req = SearchRequest(ws=ws, seed=0, backend="table", pop_size=POP,
                        generations=2, area_constr=1e-9)
    a = SearchEngine(pipelined=True).run([req])[0]
    b = SearchEngine().run([req])[0]
    assert not a.valid and not b.valid
    assert a.top_scores.size == 0 and a.top_designs == []
    np.testing.assert_array_equal(a.convergence, b.convergence)


# ---------------------------------------------------------- streaming parity
def test_pipelined_streaming_snapshot_parity(ws):
    """on_progress snapshots through the thin epilogue equal the
    history-finalized ones at every segment boundary."""
    reqs = _reqs(ws, 2, seed0=50)

    def run(pipelined):
        snaps = []
        eng = SearchEngine(segment_gens=2, pipelined=pipelined)
        plan = plan_batch(reqs, max_slots=eng.max_slots)[0]
        res = eng.execute(plan, on_progress=lambda i, s: snaps.append((i, s)))
        return snaps, res

    snaps_p, res_p = run(True)
    snaps_s, res_s = run(False)
    assert len(snaps_p) == len(snaps_s) > 0
    for (ia, a), (ib, b) in zip(snaps_p, snaps_s):
        assert ia == ib
        assert a.partial and b.partial
        np.testing.assert_array_equal(a.top_scores, b.top_scores)
        np.testing.assert_array_equal(a.top_genomes, b.top_genomes)
        np.testing.assert_array_equal(a.convergence, b.convergence)
        assert a.generations == b.generations
    for a, b in zip(res_p, res_s):
        _same_thin(a, b)


# ------------------------------------------------------- fault + checkpoint
def test_pipelined_fault_partials_parity(ws, monkeypatch):
    """Exhausted retries raise ``EngineFault`` whose anytime partials are
    identical under both modes (the thin path syncs the device history at
    the fault boundary)."""
    reqs = _reqs(ws, 2, seed0=60)
    real = engine_mod.run_ga_batched_segment
    calls = {"n": 0}

    def fails_from_second(*a, **kw):
        calls["n"] += 1
        if calls["n"] % 10 >= 2:  # per-engine counter below resets decade
            raise RuntimeError("injected permanent failure")
        return real(*a, **kw)

    def fault_partials(pipelined):
        calls["n"] = (calls["n"] // 10 + 1) * 10
        eng = SearchEngine(segment_gens=2, segment_retries=0,
                           pipelined=pipelined)
        with pytest.raises(EngineFault) as ei:
            eng.run(reqs)
        return ei.value

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment",
                        fails_from_second)
    fp = fault_partials(True)
    fs = fault_partials(False)
    assert fp.generations_done == fs.generations_done == 2
    assert len(fp.partials) == len(fs.partials) == len(reqs)
    for a, b in zip(fp.partials, fs.partials):
        assert a.partial and b.partial
        np.testing.assert_array_equal(a.top_scores, b.top_scores)
        np.testing.assert_array_equal(a.top_genomes, b.top_genomes)
        np.testing.assert_array_equal(a.convergence, b.convergence)
        assert a.generations == b.generations == 2


def test_pipelined_checkpoint_cross_mode_resume(ws, tmp_path, monkeypatch):
    """Checkpoints written by a killed PIPELINED run restore into a
    SEQUENTIAL engine (and vice versa) and finish bit-identical to an
    uninterrupted run — the on-disk state is mode-agnostic host numpy."""
    from repro.checkpoint import store

    reqs = _reqs(ws, 2, seed0=70)
    ref = SearchEngine(segment_gens=2).run(reqs)
    real = engine_mod.run_ga_batched_segment

    def drill(kill_pipelined, resume_pipelined, sub):
        ck_root = tmp_path / sub
        calls = {"n": 0}

        def killed_on_second(*a, **kw):
            calls["n"] += 1
            if calls["n"] == 2:
                raise KeyboardInterrupt()
            return real(*a, **kw)

        monkeypatch.setattr(engine_mod, "run_ga_batched_segment",
                            killed_on_second)
        eng = SearchEngine(segment_gens=2, checkpoint_dir=str(ck_root),
                           pipelined=kill_pipelined)
        with pytest.raises(KeyboardInterrupt):
            eng.run(reqs)
        monkeypatch.setattr(engine_mod, "run_ga_batched_segment", real)
        ck = ck_root / engine_mod.plan_key(
            plan_batch(reqs, max_slots=eng.max_slots)[0])
        assert store.latest_step(ck) == 2  # segment 1 committed pre-kill
        out = SearchEngine(segment_gens=2, checkpoint_dir=str(ck_root),
                           pipelined=resume_pipelined).run(reqs)
        assert store.latest_step(ck) is None
        return out

    for a, b in zip(drill(True, False, "p2s"), ref):
        np.testing.assert_array_equal(a.top_scores, b.top_scores)
        np.testing.assert_array_equal(a.top_genomes, b.top_genomes)
        assert a.ga is not None  # sequential resume keeps the history
    for a, b in zip(drill(False, True, "s2p"), ref):
        _same_thin(a, b)


# --------------------------------------------------------- sync regression
def test_warm_pipelined_segmented_loop_never_syncs(ws, monkeypatch):
    """Satellite regression: once the first segment launches, the warm
    pipelined loop performs NO device->host array conversion — neither
    the old per-segment ``int(np.asarray(state.gen))`` counter sync nor
    per-segment history materialization.  The recorder arms at the first
    segment call and every ``np.asarray`` over a jax array from then to
    the end of ``dispatch`` is a regression."""
    reqs = _reqs(ws, 2, seed0=90)
    SearchEngine(segment_gens=2, pipelined=True).run(reqs)  # warm caches
    eng = SearchEngine(segment_gens=2, pipelined=True)
    plan = plan_batch(reqs, max_slots=eng.max_slots)[0]

    real_asarray = np.asarray
    rec = {"armed": False, "synced": []}

    def recording(a, *args, **kw):
        if rec["armed"] and isinstance(a, jax.Array):
            rec["synced"].append((tuple(a.shape), str(a.dtype)))
        return real_asarray(a, *args, **kw)

    real_seg = engine_mod.run_ga_batched_segment

    def arming(*a, **kw):
        rec["armed"] = True
        return real_seg(*a, **kw)

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", arming)
    monkeypatch.setattr(np, "asarray", recording)
    try:
        pending = eng.dispatch(plan)
        in_loop = list(rec["synced"])
        results = eng.harvest(pending)
    finally:
        monkeypatch.setattr(np, "asarray", real_asarray)
    assert in_loop == [], f"warm segmented loop synced: {in_loop}"
    # control: the recorder is live — harvest DID sync the thin fields
    assert len(rec["synced"]) > len(in_loop)
    assert all(r.generations == GENS for r in results)


def test_transfer_bytes_reduction_and_launch_count(ws):
    """The harvested-bytes telemetry: the thin path moves >= 10x fewer
    bytes than the history path for the same plan chunks, with the same
    launch count."""
    reqs = _reqs(ws, 5, seed0=110, gens=8)
    seq = SearchEngine(max_slots=2)
    pip = SearchEngine(max_slots=2, pipelined=True)
    seq.run(reqs), pip.run(reqs)  # warm: caches + programs
    seq.reset_transfer_stats()
    pip.reset_transfer_stats()
    a = seq.run(reqs)
    b = pip.run(reqs)
    for x, y in zip(b, a):
        _same_thin(x, y)
    assert seq.launches == pip.launches == 3
    assert pip.transfer_bytes * 10 <= seq.transfer_bytes, (
        pip.transfer_bytes, seq.transfer_bytes)


# ------------------------------------------------------------ service drain
def test_service_pipelined_drain_parity(ws):
    reqs = paper_request_mix(ws, 18, pop_size=POP, generations=4)

    def drain(pipelined):
        svc = DSEService(max_slots=8, pipelined=pipelined)
        rids = svc.submit_all(reqs)
        order = [rid for rid, _ in svc.stream()]
        return svc, rids, order

    s_seq, rids_seq, order_seq = drain(False)
    s_pip, rids_pip, order_pip = drain(True)
    assert order_seq == order_pip  # same plans, same yield boundaries
    assert s_seq.stats.launches == s_pip.stats.launches
    assert s_pip.stats.completed == len(reqs)
    for ra, rb in zip(rids_seq, rids_pip):
        _same_thin(s_pip.results[rb], s_seq.results[ra])
    # telemetry shape: gap samples per launch, idle accumulates, and the
    # summary keys serialize (None or float, never NaN)
    assert len(s_pip.stats.dispatch_gap_samples) == s_pip.stats.launches
    summ = s_pip.stats.summary()
    assert "dispatch_gap_p50_s" in summ and "device_idle_s" in summ
    assert s_seq.stats.dispatch_gap_p(50) == 0.0  # inline harvests


def test_async_service_pipelined_parity(ws):
    reqs = paper_request_mix(ws, 12, pop_size=POP, generations=4, seed0=7)
    ref_svc = DSEService(max_slots=8)
    ref_rids = ref_svc.submit_all(reqs)
    ref_map = ref_svc.drain()
    with AsyncDSEService(max_slots=8, pipelined=True) as svc:
        futs = svc.submit_all(reqs)
        res = [f.result(timeout=600) for f in futs]
    for ra, b in zip(ref_rids, res):
        _same_thin(b, ref_map[ra])


def test_service_pipelined_falls_back_on_stub_engines(ws):
    """Engines without the dispatch/harvest split (sim stubs, fault
    wrappers) drain sequentially even under pipelined=True."""
    class MiniEngine:
        max_slots = 4
        result_cache = None

        def execute(self, plan, **kw):
            return SearchEngine().execute(plan)

    svc = DSEService(engine=MiniEngine(), pipelined=True)
    assert not svc._can_pipeline
    rids = svc.submit_all(_reqs(ws, 2, seed0=130))
    out = svc.drain()
    assert all(out[r].valid for r in rids)
