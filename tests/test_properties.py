"""Property-based tests (hypothesis).

Kept in their own module so ``pytest.importorskip`` can skip them cleanly
when hypothesis isn't installed, while the deterministic parity tests in
test_imc_cost.py / test_paper_core.py always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import space
from repro.core.ga import _poly_mutation, _sbx
from repro.imc.cost import DesignArrays, evaluate_designs
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _design(**kw):
    base = dict(rows=128.0, cols=128.0, c_per_tile=8.0, t_per_router=8.0,
                g_per_chip=8.0, v_op=0.9, bits_cell=2.0, t_cycle_ns=2.0,
                glb_mb=1.0)
    base.update(kw)
    return DesignArrays(**{k: jnp.asarray([v], jnp.float32) for k, v in base.items()})


@given(st.sampled_from([32.0, 64.0, 128.0, 256.0, 512.0]))
@settings(max_examples=5, deadline=None)
def test_more_capacity_never_hurts_fit(ws, rows):
    small = evaluate_designs(_design(rows=rows, c_per_tile=2.0), ws)
    big = evaluate_designs(_design(rows=rows, c_per_tile=32.0), ws)
    # strictly more crossbars on chip -> fits is monotone
    assert bool((big.fits | ~small.fits).all())


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_genome_roundtrip(seed):
    g = space.random_genomes(jax.random.PRNGKey(seed), 16)
    idx = space.decode_indices(g)
    g2 = space.genome_from_indices(np.asarray(idx))
    idx2 = space.decode_indices(jnp.asarray(g2, jnp.float32))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_sbx_bounds_and_mean(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    p1 = jax.random.uniform(k1, (64, space.N_GENES))
    p2 = jax.random.uniform(k2, (64, space.N_GENES))
    c1, c2 = _sbx(k3, p1, p2, eta=3.0, prob=0.95)
    assert float(c1.min()) >= 0.0 and float(c1.max()) < 1.0
    assert float(c2.min()) >= 0.0 and float(c2.max()) < 1.0
    # SBX preserves the parent-pair mean wherever the [0,1) clip didn't bind
    c1n, c2n = np.asarray(c1), np.asarray(c2)
    interior = (c1n > 1e-6) & (c1n < 1 - 1e-6) & (c2n > 1e-6) & (c2n < 1 - 1e-6)
    np.testing.assert_allclose(
        (c1n + c2n)[interior], np.asarray(p1 + p2)[interior], atol=1e-4
    )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_poly_mutation_in_bounds(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (64, space.N_GENES))
    y = _poly_mutation(key, x, eta=3.0, prob=1.0)
    assert float(y.min()) >= 0.0 and float(y.max()) < 1.0
