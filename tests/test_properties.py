"""Property-based tests (hypothesis).

Kept in their own module so ``pytest.importorskip`` can skip them cleanly
when hypothesis isn't installed, while the deterministic parity tests in
test_imc_cost.py / test_paper_core.py always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import space
from repro.core.distributed import batch_axes, batch_spec, shape_spec
from repro.core.ga import _poly_mutation, _sbx
from repro.imc.cost import DesignArrays, evaluate_designs
from repro.launch.mesh import (
    make_mesh,
    make_search_mesh,
    make_test_mesh,
    mesh_axis_sizes,
)
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _design(**kw):
    base = dict(rows=128.0, cols=128.0, c_per_tile=8.0, t_per_router=8.0,
                g_per_chip=8.0, v_op=0.9, bits_cell=2.0, t_cycle_ns=2.0,
                glb_mb=1.0)
    base.update(kw)
    return DesignArrays(**{k: jnp.asarray([v], jnp.float32) for k, v in base.items()})


@given(st.sampled_from([32.0, 64.0, 128.0, 256.0, 512.0]))
@settings(max_examples=5, deadline=None)
def test_more_capacity_never_hurts_fit(ws, rows):
    small = evaluate_designs(_design(rows=rows, c_per_tile=2.0), ws)
    big = evaluate_designs(_design(rows=rows, c_per_tile=32.0), ws)
    # strictly more crossbars on chip -> fits is monotone
    assert bool((big.fits | ~small.fits).all())


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_genome_roundtrip(seed):
    g = space.random_genomes(jax.random.PRNGKey(seed), 16)
    idx = space.decode_indices(g)
    g2 = space.genome_from_indices(np.asarray(idx))
    idx2 = space.decode_indices(jnp.asarray(g2, jnp.float32))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_sbx_bounds_and_mean(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    p1 = jax.random.uniform(k1, (64, space.N_GENES))
    p2 = jax.random.uniform(k2, (64, space.N_GENES))
    c1, c2 = _sbx(k3, p1, p2, eta=3.0, prob=0.95)
    assert float(c1.min()) >= 0.0 and float(c1.max()) < 1.0
    assert float(c2.min()) >= 0.0 and float(c2.max()) < 1.0
    # SBX preserves the parent-pair mean wherever the [0,1) clip didn't bind
    c1n, c2n = np.asarray(c1), np.asarray(c2)
    interior = (c1n > 1e-6) & (c1n < 1 - 1e-6) & (c2n > 1e-6) & (c2n < 1 - 1e-6)
    np.testing.assert_allclose(
        (c1n + c2n)[interior], np.asarray(p1 + p2)[interior], atol=1e-4
    )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_poly_mutation_in_bounds(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (64, space.N_GENES))
    y = _poly_mutation(key, x, eta=3.0, prob=1.0)
    assert float(y.min()) >= 0.0 and float(y.max()) < 1.0


# ------------------------------------------------- factorized-table properties
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 3),  # workloads
    st.integers(1, 12),  # padded layer-table depth
    st.floats(0.0, 1.0),  # per-layer mask density
)
@settings(max_examples=15, deadline=None)
def test_table_backend_matches_dense_oracle(seed, w, l, density):
    """imc.tables: for ANY random design population and ragged / partially-
    or fully-masked workload set, the factorized table path reproduces the
    dense (P, W, L) oracle: metrics allclose, fits/valid identical, and
    identical objective scores (incl. the +inf infeasible pattern)."""
    from repro.core.objectives import make_objective
    from repro.imc.cost import evaluate_designs_arrays
    from repro.imc.tables import build_tables_arrays, evaluate_genomes_tables

    rng = np.random.default_rng(seed)
    feats = np.zeros((w, l, 6), np.float32)
    feats[..., 0] = rng.integers(1, 4096, (w, l))  # M
    feats[..., 1] = rng.integers(1, 8192, (w, l))  # K
    feats[..., 2] = rng.integers(1, 2048, (w, l))  # N
    feats[..., 3] = rng.integers(1, 1 << 22, (w, l))  # A_in
    feats[..., 4] = rng.integers(1, 1 << 22, (w, l))  # A_out
    feats[..., 5] = rng.integers(1, 512, (w, l))  # groups
    mask = rng.random((w, l)) < density
    feats, mask = jnp.asarray(feats), jnp.asarray(mask)

    g = space.random_genomes(jax.random.PRNGKey(seed), 64)
    ref = evaluate_designs_arrays(space.decode(g), feats, mask)
    tab = evaluate_genomes_tables(g, build_tables_arrays(feats, mask))

    np.testing.assert_allclose(tab.energy_pj, ref.energy_pj, rtol=1e-5)
    np.testing.assert_allclose(tab.latency_ns, ref.latency_ns, rtol=1e-5)
    np.testing.assert_allclose(tab.area_mm2, ref.area_mm2, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(tab.fits), np.asarray(ref.fits))
    np.testing.assert_array_equal(np.asarray(tab.valid), np.asarray(ref.valid))
    obj = make_objective("ela", 150.0)
    s_ref, s_tab = np.asarray(obj(ref)), np.asarray(obj(tab))
    np.testing.assert_array_equal(np.isfinite(s_ref), np.isfinite(s_tab))
    np.testing.assert_allclose(
        s_tab[np.isfinite(s_ref)], s_ref[np.isfinite(s_ref)], rtol=1e-5
    )


# ----------------------------------------------------- batch-plan properties
@given(
    st.lists(
        st.tuples(
            st.sampled_from([(0,), (1,), (0, 1), (0, 1, 2, 3)]),  # ws subset
            st.sampled_from([8, 16]),  # pop_size -> distinct signature
            st.sampled_from(["table", "jnp"]),
            st.integers(0, 5),  # priority
            st.one_of(st.none(), st.floats(0.0, 100.0)),  # deadline_s
        ),
        min_size=1, max_size=12,
    ),
    st.randoms(use_true_random=False),  # submit-order permutation
    st.sampled_from([2, 3, 64]),
    st.sampled_from(["fifo", "priority", "edf"]),
)
@settings(max_examples=40, deadline=None)
def test_plan_batch_is_a_policy_ordered_partition(
    ws, specs, rnd, max_slots, policy
):
    """For ANY request mix (signatures, priorities, deadlines) and ANY
    submit-order permutation, plan_batch's plan indices are an exact
    partition of the queue — every request in exactly one plan — and the
    emitted order respects the policy: members of a plan are urgency-
    sorted, plans launch most-urgent-first, and each signature group's
    chunk concatenation is urgency-sorted."""
    import dataclasses as dc

    from repro.core.engine import (
        RequestMeta,
        SearchRequest,
        get_policy,
        plan_batch,
    )

    reqs = [
        SearchRequest(ws=ws.subset(list(sub)), seed=i, backend=be,
                      pop_size=pop, generations=2, priority=pr,
                      deadline_s=dl)
        for i, (sub, pop, be, pr, dl) in enumerate(specs)
    ]
    rnd.shuffle(reqs)
    pol = get_policy(policy)
    keys = [
        pol.key(r, RequestMeta(seq=i, priority=r.priority,
                               deadline_s=r.deadline_s))
        for i, r in enumerate(reqs)
    ]
    plans = plan_batch(reqs, max_slots=max_slots, policy=policy)

    flat = [i for p in plans for i in p.indices]
    assert sorted(flat) == list(range(len(reqs)))  # exact partition
    for p in plans:
        assert 0 < len(p.requests) <= p.slots <= max_slots
        assert p.requests == [reqs[i] for i in p.indices]
        ks = [keys[i] for i in p.indices]
        assert ks == sorted(ks)  # within-plan members urgency-ordered
    firsts = [keys[p.indices[0]] for p in plans]
    assert firsts == sorted(firsts)  # most urgent plan launches first
    by_sig = {}
    for p in plans:
        by_sig.setdefault(p.signature, []).append(p)
    for chunks in by_sig.values():
        assert len({p.slots for p in chunks}) == 1  # one program per group
        cat = [keys[i] for p in chunks for i in p.indices]
        assert cat == sorted(cat)  # group order respects the policy
    # scheduling metadata never perturbs the signature partition
    stripped = [dc.replace(r, priority=0, deadline_s=None) for r in reqs]
    ref = plan_batch(stripped, max_slots=max_slots)
    assert sorted((p.signature, p.slots, len(p.requests)) for p in ref) == \
        sorted((p.signature, p.slots, len(p.requests)) for p in plans)


# -------------------------------------------------- sharding-helper properties
@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_genome_roundtrip_any_population_and_batch_shape(b, p, seed):
    """decode∘encode is the identity on grid indices for every (B, P)
    factorization of the population pool — the invariant the vmapped and
    sharded GA paths rely on."""
    g = space.random_genomes(jax.random.PRNGKey(seed), b * p)
    idx_flat = np.asarray(space.decode_indices(g))
    # batched view decodes identically to the flat view
    idx_b = jax.vmap(space.decode_indices)(g.reshape(b, p, space.N_GENES))
    np.testing.assert_array_equal(
        np.asarray(idx_b).reshape(b * p, space.N_GENES), idx_flat
    )
    # encode -> decode round-trips exactly
    g2 = space.genome_from_indices(idx_flat)
    idx2 = np.asarray(space.decode_indices(jnp.asarray(g2, jnp.float32)))
    np.testing.assert_array_equal(idx2, idx_flat)


def _check_mesh_layout(mesh):
    """Invariants every mesh layout the repo constructs must satisfy."""
    sizes = mesh_axis_sizes(mesh)
    assert tuple(sizes) == tuple(mesh.axis_names)
    assert all(v >= 1 for v in sizes.values())
    assert int(np.prod(list(sizes.values()))) == int(mesh.devices.size)
    assert int(mesh.devices.size) <= jax.device_count()
    s_ax, p_ax = batch_axes(mesh)
    assert set(s_ax).isdisjoint(set(p_ax))
    assert set(s_ax) | set(p_ax) <= set(mesh.axis_names)
    assert all(a == "search" for a in s_ax)
    assert all(a in ("pod", "data") for a in p_ax)
    # specs: dim 0 is the search group, pop_dim the pop group, rest None
    spec = batch_spec(mesh, 3, pop_dim=1)
    assert len(spec) == 3 and spec[2] is None
    assert spec[0] in (s_ax or None, None) and spec[1] in (p_ax or None, None)
    # shape_spec only ever shards a dim its axis-group size divides
    shape = (7, 11, 9)
    for dim, part in enumerate(shape_spec(mesh, shape, pop_dim=1)):
        if part is not None:
            group = int(np.prod([sizes[a] for a in part]))
            assert shape[dim] % group == 0


@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_test_mesh_layout_invariants(search, data, model):
    mesh = make_test_mesh(data=data, model=model, search=search)
    _check_mesh_layout(mesh)
    sizes = mesh_axis_sizes(mesh)
    # clamped sizes never exceed the request
    assert sizes.get("search", 1) <= max(search, 1)
    assert sizes["data"] <= data and sizes["model"] <= model


@given(
    st.one_of(st.none(), st.integers(1, 16)),
    st.one_of(st.none(), st.integers(1, 16)),
)
@settings(max_examples=20, deadline=None)
def test_search_mesh_layout_invariants(searches, pop):
    mesh = make_search_mesh(searches, pop)
    _check_mesh_layout(mesh)
    assert tuple(mesh.axis_names) == ("search", "data")


@given(st.integers(1, 12), st.integers(1, 48), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_shape_spec_shards_only_divisible_dims(b, p, nd):
    mesh = make_search_mesh()
    shape = (b, p) + (space.N_GENES,) * (nd - 1) if nd > 1 else (b,)
    spec = shape_spec(mesh, shape, pop_dim=1 if len(shape) > 1 else None)
    sizes = mesh_axis_sizes(mesh)
    assert len(spec) == len(shape)
    for dim, part in enumerate(spec):
        if part is not None:
            group = int(np.prod([sizes[a] for a in part]))
            assert shape[dim] % group == 0


def test_plain_mesh_layout_invariants():
    """Non-hypothesis anchor: the exact layouts the drivers build."""
    _check_mesh_layout(make_test_mesh(1, 1))
    _check_mesh_layout(make_search_mesh(1, 1))
    _check_mesh_layout(make_mesh((1,), ("model",)))


# ---------------------------------------------------- segmented-GA properties
def _toy_obj(genomes):
    return jnp.sum((genomes - 0.3) ** 2, axis=-1)


@given(
    st.integers(0, 2**31 - 1),
    st.lists(st.integers(1, 4), min_size=1, max_size=4),  # segment split
)
@settings(max_examples=10, deadline=None)
def test_ga_random_segment_splits_bit_exact(seed, splits):
    """For ANY split of the generation budget into segment launches, the
    chained ``run_ga_segment`` history is bit-for-bit the single-shot
    ``run_ga`` history — the anytime/checkpoint contract at the GA level."""
    from repro.core.ga import init_ga_state, run_ga, run_ga_segment

    total = sum(splits)
    key = jax.random.PRNGKey(seed)
    init = space.random_genomes(jax.random.PRNGKey(seed ^ 0x5EED), 8)
    full = run_ga(key, _toy_obj, pop_size=8, generations=total,
                  init_genomes=init + 0)  # run_ga donates: pass a copy
    st = init_ga_state(key, _toy_obj, init)
    hg = [np.asarray(st.genomes)[None]]
    hs = [np.asarray(st.scores)[None]]
    for k in splits:
        st, (g, s) = run_ga_segment(st, _toy_obj, generations=k,
                                    total_generations=total)
        hg.append(np.asarray(g))
        hs.append(np.asarray(s))
    np.testing.assert_array_equal(np.concatenate(hg), np.asarray(full.genomes))
    np.testing.assert_array_equal(np.concatenate(hs), np.asarray(full.scores))


@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([1, 2, 4, 5]),  # segment size over a 6-generation budget
    st.sampled_from(["table", "jnp"]),
)
@settings(max_examples=8, deadline=None)
def test_engine_segmented_bit_parity_across_backends(ws, seed, seg, backend):
    """Segmented engine execution — any segment size, including ragged
    final segments — is bit-identical to the single-shot engine on every
    backend, and under the active (search, population) device mesh when
    the suite runs in the fake-8-device job."""
    from repro.core.engine import SearchEngine, SearchRequest

    req = SearchRequest(ws=ws.subset([seed % 4]), seed=seed, backend=backend,
                        pop_size=8, generations=6)
    mesh = make_search_mesh() if jax.device_count() > 1 else None
    ref = SearchEngine().run([req])[0]
    out = SearchEngine(segment_gens=seg, mesh=mesh).run([req])[0]
    np.testing.assert_array_equal(np.asarray(out.ga.scores),
                                  np.asarray(ref.ga.scores))
    np.testing.assert_array_equal(np.asarray(out.ga.genomes),
                                  np.asarray(ref.ga.genomes))
    np.testing.assert_array_equal(out.top_scores, ref.top_scores)
    np.testing.assert_array_equal(out.top_genomes, ref.top_genomes)
