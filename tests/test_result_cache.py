"""Result-cache suite: the plan_key/TechParams collision regression and
the fingerprint-keyed request cache (ISSUE: cache PR).

What this file pins:

  * **plan_key regression** — ``plan_key`` hashes ``TechParams``: plans
    differing only in one tech field get distinct keys, and a checkpoint
    written under tech A is never resumed by the same plan under tech B.
  * **request_key semantics** — everything that determines a result bit
    changes the key (objective, weights, area, backend, GA params,
    top_k, tech, PRNG key bytes, init population); scheduling metadata
    (priority, deadline) never does, and ``seed=n`` equals
    ``key=PRNGKey(n)``.
  * **Cache correctness** — a hit is bit-identical to a fresh search,
    partials are refused, the memory tier evicts in LRU order, and the
    disk tier survives a process "restart" (a fresh cache over the same
    directory) with ``top_designs`` recomputed, never drifted.
  * **Service integration** — a drain with 50% repeated requests needs
    exactly half the launches (fifo and priority; virtual-clock sim),
    and an identical resubmitted mix drains with ZERO new GA launches
    and bit-identical results through both the sync and async front
    ends (real engine).
  * **Streaming** — ``on_progress`` best-so-far snapshots are monotone
    non-increasing and exactly the accumulated history's prefix;
    single-shot engines never emit.
  * **Satellites** — the ``_TABLES_MEMO`` LRU cap (env-tunable,
    eviction + rebuild) and ``ServiceStats`` None-not-NaN percentiles.
"""
import json

import jax
import numpy as np
import pytest
from sim_scheduler import StubEngine, VirtualClock, sim_request

from repro.core import engine as engine_mod
from repro.core.engine import (
    SearchEngine,
    SearchRequest,
    empty_partial_result,
    plan_batch,
    plan_key,
)
from repro.imc.tech import TECH
from repro.serve.cache import ResultCache, request_key
from repro.serve.dse import AsyncDSEService, DSEService, ServiceStats
from repro.workloads.cnn import cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS = 8, 6  # the segment suite's operating point: warm jit caches


@pytest.fixture(scope="module")
def ws():
    return pack_workloads(
        [(n, cnn_workload(n)) for n in ("resnet18", "vgg16")]
    )


def _reqs(ws, n, *, gens=GENS, seed0=0, tech=TECH):
    subsets = [[0, 1], [0], [1]]
    return [
        SearchRequest(ws=ws.subset(subsets[i % 3]), seed=seed0 + i,
                      backend="table", pop_size=POP, generations=gens,
                      tech=tech)
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def one(ws):
    """One request + its uncached reference result (shared: GA runs are
    the expensive part of this suite)."""
    req = _reqs(ws, 1, seed0=11)[0]
    return req, SearchEngine().run([req])[0]


def _assert_bit_equal(a, b, ctx=""):
    assert a.objective == b.objective and a.workload_names == b.workload_names
    assert a.valid == b.valid and a.partial == b.partial
    assert a.generations == b.generations
    assert a.top_designs == b.top_designs, ctx
    for name in ("top_scores", "top_genomes", "convergence"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{ctx}: {name}")
    for name in ("genomes", "scores", "best_genome", "best_score"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.ga, name)), np.asarray(getattr(b.ga, name)),
            err_msg=f"{ctx}: ga.{name}")


# ------------------------------------------------------ plan_key regression
def _perturb(tech, field):
    v = getattr(tech, field)
    new = v + 1 if isinstance(v, int) else v * 1.5 + 1e-9
    return tech._replace(**{field: new})


def test_plan_key_distinct_under_any_single_tech_field(ws):
    """THE regression: plans identical except for ONE TechParams field
    must hash to distinct checkpoint keys — for every field.  (The
    original bug omitted ``tech`` entirely, colliding all of these.)"""
    req = _reqs(ws, 1)[0]
    base = plan_key(plan_batch([req], max_slots=64)[0])
    for field in TECH._fields:
        other = SearchRequest(
            ws=req.ws, seed=req.seed, backend=req.backend,
            pop_size=req.pop_size, generations=req.generations,
            tech=_perturb(TECH, field),
        )
        key = plan_key(plan_batch([other], max_slots=64)[0])
        assert key != base, f"plan_key collides when only tech.{field} differs"


def test_checkpoint_under_tech_a_not_resumed_under_tech_b(
    ws, tmp_path, monkeypatch
):
    """A drain killed mid-search under tech A leaves its checkpoint on
    disk; re-running the SAME plan under tech B must ignore it (fresh
    trajectory, bit-identical to an uninterrupted tech-B run) and leave
    A's state untouched for A's own restart."""
    from repro.checkpoint import store

    tech_b = TECH._replace(adc_energy_pj=TECH.adc_energy_pj * 4.0)
    req_a = _reqs(ws, 1, seed0=70)[0]
    req_b = _reqs(ws, 1, seed0=70, tech=tech_b)[0]
    ck_root = tmp_path / "ck"

    real = engine_mod.run_ga_batched_segment
    calls = {"n": 0}

    def killed_on_second(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise KeyboardInterrupt()
        return real(*a, **kw)

    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", killed_on_second)
    eng_a = SearchEngine(segment_gens=2, checkpoint_dir=str(ck_root))
    with pytest.raises(KeyboardInterrupt):
        eng_a.run([req_a])
    monkeypatch.setattr(engine_mod, "run_ga_batched_segment", real)

    key_a = plan_key(plan_batch([req_a], max_slots=eng_a.max_slots)[0])
    key_b = plan_key(plan_batch([req_b], max_slots=eng_a.max_slots)[0])
    assert key_a != key_b
    assert store.latest_step(ck_root / key_a) == 2  # A's state committed

    ref_b = SearchEngine(segment_gens=2).run([req_b])[0]
    out_b = SearchEngine(
        segment_gens=2, checkpoint_dir=str(ck_root)
    ).run([req_b])[0]
    _assert_bit_equal(out_b, ref_b, "tech-B run resumed tech-A state")
    # B completed and cleared ITS directory; A's checkpoint is untouched
    assert store.latest_step(ck_root / key_a) == 2


# -------------------------------------------------- request_key semantics
def test_request_key_stable_and_seed_equals_explicit_key(ws):
    a = _reqs(ws, 1, seed0=3)[0]
    b = _reqs(ws, 1, seed0=3)[0]  # rebuilt, equal content
    assert request_key(a) == request_key(b)
    c = SearchRequest(ws=a.ws, seed=999, key=jax.random.PRNGKey(3),
                      backend="table", pop_size=POP, generations=GENS)
    assert request_key(c) == request_key(a)  # key bytes, not the seed int


def test_request_key_excludes_scheduling_metadata(ws):
    import dataclasses

    base = _reqs(ws, 1)[0]
    for change in ({"priority": 7}, {"deadline_s": 5.0}):
        other = dataclasses.replace(base, **change)
        assert request_key(other) == request_key(base), change


def test_request_key_distinct_per_result_bit_field(ws):
    import dataclasses

    base = _reqs(ws, 1)[0]
    changes = [
        {"objective": "edp"},
        {"obj_weights": (1.0, 2.0, 1.0)},
        {"area_constr": 151.0},
        {"backend": "jnp"},
        {"pop_size": POP + 1},
        {"generations": GENS + 1},
        {"top_k": 5},
        {"tech": _perturb(TECH, "adc_bits")},
        {"key": jax.random.PRNGKey(12345)},
        {"init_genomes": np.full((POP, 8), 0.5, np.float32)},
        {"ws": base.ws.subset([0])},
    ]
    keys = {request_key(base)}
    for change in changes:
        k = request_key(dataclasses.replace(base, **change))
        assert k not in keys, f"request_key collides on {list(change)}"
        keys.add(k)


# ------------------------------------------------------- cache correctness
def test_hit_bit_identical_to_fresh_search_and_zero_recompute(ws, one):
    req, fresh = one
    cache = ResultCache()
    eng = SearchEngine(result_cache=cache)
    a = eng.run([req])[0]
    b = eng.run([req])[0]
    assert b is a  # memory-tier hit: the stored object, nothing re-ran
    assert cache.stats.hits == 1 and cache.stats.puts == 1
    _assert_bit_equal(a, fresh, "cached vs uncached engine")


def test_put_refuses_partial_results(ws):
    req = _reqs(ws, 1)[0]
    cache = ResultCache()
    assert cache.put(req, empty_partial_result(req)) is False
    assert len(cache) == 0 and cache.get(req) is None


class _Full:
    """Duck-typed full result for tier mechanics (no GA needed)."""

    partial = False
    ga = True

    def __init__(self, tag):
        self.tag = tag


def test_lru_eviction_order_and_refresh_on_access():
    cache = ResultCache(capacity=2)
    cache.put("k1", _Full(1))
    cache.put("k2", _Full(2))
    assert cache.get("k1").tag == 1  # refresh: k2 becomes LRU
    cache.put("k3", _Full(3))  # evicts k2, not k1
    assert cache.mem_keys() == ["k1", "k3"]
    assert cache.get("k2") is None
    assert cache.stats.evictions == 1 and cache.stats.misses == 1
    with pytest.raises(ValueError):
        ResultCache(capacity=0)


def test_disk_tier_survives_restart_bit_identical(tmp_path, one):
    req, res = one
    c1 = ResultCache(disk_dir=tmp_path / "rc")
    c1.put(req, res)
    key = request_key(req)
    assert c1.disk_keys() == [key]

    c2 = ResultCache(disk_dir=tmp_path / "rc")  # "restarted process"
    hit = c2.get(req)
    assert hit is not None and hit is not res
    assert c2.stats.disk_hits == 1
    _assert_bit_equal(hit, res, "disk roundtrip")
    assert key in c2.mem_keys()  # promoted into the memory tier

    c2.clear()  # memory only: disk entry stays
    assert c2.disk_keys() == [key] and c2.get(req) is not None
    c2.clear(disk=True)
    assert c2.disk_keys() == [] and key not in c2


def _mini_full(tag: float):
    """The smallest REAL full SearchResult (disk-tier encodable)."""
    from repro.core.engine import SearchResult
    from repro.core.ga import GAResult

    n = 4
    ga = GAResult(genomes=np.full((2, 3, n), tag, np.float32),
                  scores=np.full((2, 3), tag, np.float32),
                  best_genome=np.zeros(n, np.float32),
                  best_score=np.float32(tag))
    return SearchResult(workload_names=("m",), objective="ela", ga=ga,
                        top_designs=[], top_scores=np.zeros((0,), np.float32),
                        top_genomes=np.zeros((0, n), np.float32),
                        convergence=np.full((2,), tag, np.float32),
                        valid=False, partial=False, generations=1)


def test_memory_eviction_never_touches_disk(tmp_path):
    cache = ResultCache(capacity=1, disk_dir=tmp_path / "rc")
    cache.put("k1", _mini_full(1.0))
    cache.put("k2", _mini_full(2.0))  # evicts k1 from memory ONLY
    assert cache.mem_keys() == ["k2"]
    assert sorted(cache.disk_keys()) == sorted(["k1", "k2"])
    # the evicted entry comes back from disk, intact
    back = cache.get("k1")
    assert back is not None and float(np.asarray(back.ga.best_score)) == 1.0


# ----------------------------------------------------- service integration
class _FullSim:
    """StubEngine result upgraded to what ResultCache accepts."""

    partial = False
    ga = True

    def __init__(self, seed, names):
        self.seed = seed
        self.workload_names = names


class _FullStub(StubEngine):
    def execute(self, plan, *, mesh=None):
        return [_FullSim(s.seed, s.workload_names)
                for s in super().execute(plan, mesh=mesh)]


@pytest.mark.parametrize("policy", ["fifo", "priority"])
def test_drain_with_half_repeats_exactly_halves_launches(policy):
    """The 256-request acceptance drill, sim form: after a 128-unique
    warmup drain, a 256-request drain whose half are repeats launches
    EXACTLY the 8 chunks the 128 fresh requests need — the 128 repeats
    resolve at submit, each with its own original's result."""
    clock = VirtualClock()
    stub = _FullStub(clock, max_slots=16, launch_s=1.0)
    svc = DSEService(engine=stub, policy=policy, clock=clock,
                     sleep=clock.advance, result_cache=ResultCache())
    for i in range(128):
        svc.submit(sim_request(i, priority=i % 4))
    svc.drain()
    assert svc.stats.launches == 8  # 128 / 16 slots

    expect = {}
    for i in range(128):
        # repeats carry DIFFERENT priorities than the originals:
        # scheduling metadata must not break the cache key
        expect[svc.submit(sim_request(i, priority=(i + 2) % 4))] = i
        expect[svc.submit(sim_request(1000 + i, priority=i % 4))] = 1000 + i
    svc.drain()
    assert svc.stats.launches == 16, "repeats burned launches"
    assert svc.stats.cache_hits == 128
    assert svc.stats.completed == svc.stats.submitted == 384
    for rid, seed in expect.items():
        assert svc.results[rid].seed == seed, "rid got a foreign result"


def test_identical_resubmit_zero_launches_sync_and_async(ws):
    """Real-engine acceptance: the identical mix resubmitted drains with
    ZERO new GA launches, bit-identical, sync and async."""
    cache = ResultCache()
    svc = DSEService(result_cache=cache)
    rids = svc.submit_all(_reqs(ws, 6, seed0=300))
    cold = dict(svc.drain())
    launches = svc.stats.launches
    assert launches > 0 and svc.stats.cache_hits == 0

    rids2 = svc.submit_all(_reqs(ws, 6, seed0=300))
    hot = svc.drain()
    assert svc.stats.launches == launches
    assert svc.stats.cache_hits == 6
    for r1, r2 in zip(rids, rids2):
        _assert_bit_equal(cold[r1], hot[r2], f"sync rid {r1}->{r2}")

    with AsyncDSEService(result_cache=cache) as asvc:
        futs = asvc.submit_all(_reqs(ws, 6, seed0=300))
        results = [f.result(timeout=600) for f in futs]
    assert asvc.stats.launches == 0 and asvc.stats.cache_hits == 6
    for r1, res in zip(rids, results):
        _assert_bit_equal(cold[r1], res, f"async rid {r1}")


def _assert_thin_bit_equal(a, b, ctx=""):
    """Bit-equality for transfer-thin full results (``ga is None``)."""
    assert a.ga is None and b.ga is None, ctx
    assert a.objective == b.objective and a.workload_names == b.workload_names
    assert a.valid == b.valid and not a.partial and not b.partial
    assert a.generations == b.generations
    assert a.top_designs == b.top_designs, ctx
    for name in ("top_scores", "top_genomes", "convergence"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{ctx}: {name}")


def test_thin_full_results_are_cacheable_partials_still_refused(ws):
    """THE regression (ISSUE 10 headline): pipelined engines return thin
    FULL results (``res.ga is None``), and ``ResultCache.put`` used to
    refuse exactly those — so a pipelined service never populated its
    cache and every resubmit re-ran the GA.  Thin full results now cache;
    partial snapshots (``res.partial``) stay refused."""
    req = _reqs(ws, 1, seed0=500)[0]
    thin = SearchEngine(pipelined=True).run([req])[0]
    assert thin.ga is None and not thin.partial
    cache = ResultCache()
    assert cache.put(req, thin) is True
    assert cache.get(req) is thin
    assert cache.put(req, empty_partial_result(req)) is False


def test_pipelined_resubmit_drain_zero_launches_bit_identical(ws):
    """Acceptance: a 32-request mix drained through a pipelined engine
    with a result cache, resubmitted identically, resolves with ZERO new
    GA launches, bit-identical thin results, and a positive hit rate."""
    cache = ResultCache(capacity=64)
    eng = SearchEngine(pipelined=True)
    svc = DSEService(engine=eng, result_cache=cache)
    rids = svc.submit_all(_reqs(ws, 32, seed0=600))
    cold = dict(svc.drain())
    launches = eng.launches
    assert launches > 0 and cache.stats.puts == 32

    rids2 = svc.submit_all(_reqs(ws, 32, seed0=600))
    hot = dict(svc.drain())
    assert eng.launches == launches, "resubmit burned GA launches"
    assert svc.stats.cache_hits == 32
    assert cache.stats.hit_rate() > 0
    for r1, r2 in zip(rids, rids2):
        _assert_thin_bit_equal(cold[r1], hot[r2], f"rid {r1}->{r2}")


def test_thin_entry_disk_round_trip(tmp_path, ws):
    """A thin full result survives the disk tier across a process
    'restart' with ``ga`` still None and designs recomputed, not drifted."""
    req = _reqs(ws, 1, seed0=510)[0]
    thin = SearchEngine(pipelined=True).run([req])[0]
    c1 = ResultCache(disk_dir=tmp_path / "rc")
    assert c1.put(req, thin)
    c2 = ResultCache(disk_dir=tmp_path / "rc")  # fresh process
    back = c2.get(req)
    assert back is not None and back is not thin
    assert c2.stats.disk_hits == 1
    _assert_thin_bit_equal(back, thin, "thin disk roundtrip")


# ---------------------------------------------------------------- streaming
def test_streamed_snapshots_monotone_and_prefix_of_history(ws):
    reqs = _reqs(ws, 2, seed0=40)
    svc = DSEService(engine=SearchEngine(segment_gens=2))
    snaps = {}
    rid0 = svc.submit(reqs[0],
                      on_progress=lambda r, s: snaps.setdefault(r, []).append(s))
    rid1 = svc.submit(reqs[1])  # unsubscribed chunk-mate: no callbacks
    res = svc.drain()

    assert list(snaps) == [rid0]
    got = snaps[rid0]
    assert len(got) == 2  # G=6, k=2: boundaries at gen 2 and 4; 6 is final
    final = res[rid0]
    bests = [float(np.asarray(s.ga.best_score)) for s in got]
    bests.append(float(np.asarray(final.ga.best_score)))
    assert all(a >= b for a, b in zip(bests, bests[1:])), bests
    for k, snap in enumerate(got):
        assert snap.partial and snap.generations == 2 * (k + 1)
        # the snapshot IS the final trajectory's prefix, bit-for-bit
        np.testing.assert_array_equal(
            np.asarray(snap.convergence),
            np.asarray(final.convergence)[: snap.generations + 1])
        np.testing.assert_array_equal(
            np.asarray(snap.ga.scores),
            np.asarray(final.ga.scores)[: snap.generations + 1])
    assert not final.partial


def test_single_shot_engine_never_streams(ws):
    svc = DSEService()  # no segment_gens: no mid-search boundaries
    called = []
    svc.submit(_reqs(ws, 1, seed0=60)[0],
               on_progress=lambda r, s: called.append(r))
    svc.drain()
    assert called == []


# --------------------------------------------------------------- satellites
def test_tables_memo_lru_cap(monkeypatch):
    from repro.workloads import pack

    monkeypatch.setenv("REPRO_TABLES_MEMO_CAP", "2")
    pack._TABLES_MEMO.clear()
    w1 = pack_workloads([("resnet18", cnn_workload("resnet18"))])
    w2 = pack_workloads([("alexnet", cnn_workload("alexnet"))])
    w3 = pack_workloads([("vgg16", cnn_workload("vgg16"))])

    from repro.core import space

    gt = space.grid_token()  # memo keys carry the active grid's token
    t2 = w2.tables()
    w1.tables()
    w2.tables()  # refresh w2: w1 becomes LRU
    w3.tables()  # evicts w1
    assert len(pack._TABLES_MEMO) == 2
    assert (w1.fingerprint(), TECH, gt) not in pack._TABLES_MEMO
    assert (w2.fingerprint(), TECH, gt) in pack._TABLES_MEMO

    # evicted entries simply rebuild, to identical tables
    t1b = w1.tables()  # evicts w2
    assert (w2.fingerprint(), TECH, gt) not in pack._TABLES_MEMO
    t2b = w2.tables()
    for a, b in zip(jax.tree_util.tree_leaves(t2),
                    jax.tree_util.tree_leaves(t2b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert t1b is w1.tables()  # still memoized while resident

    monkeypatch.setenv("REPRO_TABLES_MEMO_CAP", "0")
    with pytest.raises(ValueError):
        w3.tables()
    pack._TABLES_MEMO.clear()


def test_service_stats_empty_percentiles_are_none_not_nan():
    st = ServiceStats()
    assert st.wait_p(50) is None and st.latency_p(99) is None
    s = st.summary()
    assert s["wait_p50_s"] is None and s["latency_p99_s"] is None
    assert "NaN" not in json.dumps(s)  # json.dumps(nan) emits bare NaN
    st.wait_samples.append(1.0)
    st.latency_samples.append(2.0)
    assert st.wait_p(0) == 1.0 and st.latency_p(100) == 2.0


# ------------------------------------------- cost-model version + grid keying
def test_request_key_changes_on_cost_model_version_bump(ws, monkeypatch):
    """PR-8 satellite: a COST_MODEL_VERSION bump must MISS every existing
    cache entry (persisted disk tiers can outlive a model change), while
    the same version keeps hitting."""
    import repro.imc as imc

    req = _reqs(ws, 1)[0]
    k_before = request_key(req)
    assert request_key(req) == k_before  # same version -> same key
    monkeypatch.setattr(imc, "COST_MODEL_VERSION",
                        imc.COST_MODEL_VERSION + ".bumped")
    assert request_key(req) != k_before


def test_cache_misses_after_cost_model_version_bump(ws, monkeypatch):
    import repro.imc as imc

    req = _reqs(ws, 1, seed0=90)[0]
    cache = ResultCache(capacity=8)
    res = SearchEngine().run([req])[0]
    assert cache.put(req, res)
    assert cache.get(req) is not None
    monkeypatch.setattr(imc, "COST_MODEL_VERSION",
                        imc.COST_MODEL_VERSION + ".bumped")
    assert cache.get(req) is None  # old entry invisible under the new model


def test_request_key_changes_with_grid_density(ws):
    """The active grid density redefines what a genome decodes to, so it
    must enter the request key."""
    from repro.core import space

    req = _reqs(ws, 1)[0]
    k1 = request_key(req)
    try:
        space.configure_grid(2)
        assert request_key(req) != k1
    finally:
        space.configure_grid(1)
    assert request_key(req) == k1


# -------------------------------------------------- hit-rate telemetry
def test_cache_stats_hit_rate(ws):
    cache = ResultCache(capacity=8)
    req = _reqs(ws, 1, seed0=91)[0]
    assert cache.stats.hit_rate() == 0.0  # cold: 0, never NaN
    assert cache.get(req) is None
    assert cache.stats.hit_rate() == 0.0
    res = SearchEngine().run([req])[0]
    cache.put(req, res)
    assert cache.get(req) is not None
    assert cache.get(req) is not None
    s = cache.stats.summary()
    assert s["hits"] == 2 and s["misses"] == 1
    assert s["hit_rate"] == pytest.approx(2 / 3)


def test_service_stats_cache_hit_miss_counters(ws):
    """ServiceStats counts submit-time lookups: one miss then one hit,
    and the summary carries the rate."""
    cache = ResultCache(capacity=8)
    svc = DSEService(result_cache=cache)
    req = _reqs(ws, 1, seed0=92)[0]
    svc.submit(req)
    svc.drain()
    assert (svc.stats.cache_hits, svc.stats.cache_misses) == (0, 1)
    svc.submit(req)  # identical resubmit: resolves at submit
    assert (svc.stats.cache_hits, svc.stats.cache_misses) == (1, 1)
    s = svc.stats.summary()
    assert s["cache_hits"] == 1 and s["cache_misses"] == 1
    assert s["cache_hit_rate"] == pytest.approx(0.5)
    assert ServiceStats().cache_hit_rate() == 0.0  # cacheless: 0, not NaN
