"""Scheduler-sim suite: policy ordering, starvation-freedom, deadline
accounting — asserted on the virtual-clock harness (tests/sim_scheduler.py),
no XLA launches.  The real-engine twins (bit-parity, compiled-program
counts, the async priority-0 jump) live in tests/test_engine.py."""
import numpy as np
import pytest

from sim_scheduler import (
    StubEngine,
    VirtualClock,
    run_script,
    sim_request,
    sim_service,
    sim_ws,
    submit_burst,
)

from repro.core.engine import PriorityPolicy, get_policy
from repro.serve.dse import DSEService


# ---------------------------------------------------------------- policies
def test_fifo_completes_in_submit_order():
    svc, clock, stub = sim_service(policy="fifo", max_slots=1)
    trace = run_script(svc, clock, [
        ("submit", sim_request(0)), ("submit", sim_request(1)),
        ("submit", sim_request(2)), ("drain",),
    ])
    assert trace.completion_order() == trace.rids
    assert [l.seeds for l in stub.launches] == [[0], [1], [2]]


def test_priority_orders_launches_most_urgent_first():
    svc, clock, stub = sim_service(policy="priority", max_slots=1)
    trace = run_script(svc, clock, [
        ("submit", sim_request(0, priority=5)),
        ("submit", sim_request(1, priority=0)),
        ("submit", sim_request(2, priority=2)),
        ("submit", sim_request(3, priority=0)),  # ties break by submit order
        ("drain",),
    ])
    assert [l.seeds[0] for l in stub.launches] == [1, 3, 2, 0]
    assert trace.completion_order() == [trace.rids[i] for i in (1, 3, 2, 0)]


def test_edf_orders_by_absolute_deadline_deadlineless_last():
    svc, clock, stub = sim_service(policy="edf", max_slots=1, launch_s=0.25)
    # B's RELATIVE deadline is shorter but it is submitted later; absolute
    # deadlines on the clock are what EDF sorts: A=6, B=2+1=3, C=none
    trace = run_script(svc, clock, [
        ("submit", sim_request(0, deadline_s=6.0)),
        ("submit", sim_request(2)),  # no deadline -> after every deadline
        ("advance", 2.0),
        ("submit", sim_request(1, deadline_s=1.0)),
        ("drain",),
    ])
    assert [l.seeds[0] for l in stub.launches] == [1, 0, 2]
    assert trace.completion_order() == [trace.rids[2], trace.rids[0],
                                        trace.rids[1]]


def test_priority_zero_mid_drain_preempts_queued_work():
    """The acceptance criterion, sim form: a priority-0 submit lands in
    the very next launch while lower-priority queued work keeps waiting."""
    svc, clock, stub = sim_service(policy="priority", max_slots=4)
    low = submit_burst(svc, 12, priorities=(5,))
    svc.step()  # launch 1: four of the low-priority requests
    urgent = svc.submit(sim_request(99, priority=0))
    svc.step()  # launch 2 must carry the urgent request
    assert urgent in svc.launch_log[1]
    assert 99 in stub.launches[1].seeds
    still_queued = {rid for rid, _ in svc.queue}
    assert still_queued <= set(low) and len(still_queued) == 5
    svc.drain()
    assert set(svc.results) == set(low) | {urgent}


def test_priority_aging_prevents_starvation():
    """Under a saturating priority-0 stream, a priority-9 request still
    launches once its age buys 9 levels (aging_s=2 -> 18 sim-seconds),
    because aged urgency beats fresh priority 0."""
    svc, clock, stub = sim_service(
        policy=PriorityPolicy(aging_s=2.0), max_slots=4, launch_s=1.0
    )
    starved = svc.submit(sim_request(-1, priority=9))
    done_at = None
    for round_ in range(40):
        submit_burst(svc, 4, priorities=(0,), seed0=100 * round_)
        for rid, _ in svc.step():
            if rid == starved:
                done_at = clock()
    assert done_at is not None, "aged request never launched: starvation"
    # 9 levels * aging_s=2 = 18s of waiting; one extra launch of slack
    assert done_at <= 20.0


def test_priority_without_aging_starves():
    """aging_s=None is strict priority: the same saturating stream
    starves the low-priority request indefinitely — the behavior aging
    exists to rule out."""
    svc, clock, stub = sim_service(
        policy=PriorityPolicy(aging_s=None), max_slots=4, launch_s=1.0
    )
    starved = svc.submit(sim_request(-1, priority=9))
    for round_ in range(40):
        submit_burst(svc, 4, priorities=(0,), seed0=100 * round_)
        done = svc.step()
        assert starved not in [rid for rid, _ in done]
    assert starved in {rid for rid, _ in svc.queue}
    svc.drain()  # once the stream stops it does complete
    assert starved in svc.results


# ----------------------------------------------------- deadline accounting
def test_aging_replan_fires_on_stale_plan_cache():
    """The wall-clock aging trigger (ROADMAP gap): once a cached plan
    list is >= aging_s old, the next dispatch re-runs plan_batch with
    fresh wait_s instead of consuming the stale order — without a submit
    having to land.  The re-plan runs on the warm slot hints: the launch
    shape multiset is untouched (zero new compiled programs)."""
    svc, clock, stub = sim_service(
        policy=PriorityPolicy(aging_s=2.0), max_slots=1, launch_s=1.0
    )
    submit_burst(svc, 4, priorities=(3,))
    svc.step()  # builds + caches plans at t=0, consumes one
    built0 = svc._plans_built_s
    assert built0 == 0.0 and svc._plans_cache is not None
    clock.advance(5.0)  # > aging_s with NO submit landing
    svc.step()
    assert svc._plans_built_s >= 5.0, "stale plan cache was not re-planned"
    svc.drain()
    # scheduling-only: every request completes, and every launch reused
    # the one warm (signature, slots) shape — the re-plan compiled nothing
    assert svc.stats.completed == 4
    assert len({(l.signature, l.slots) for l in stub.launches}) == 1


def test_aging_replan_starvation_free_without_submit_triggers():
    """Starvation-freedom in REAL time, not just at submit boundaries: a
    priority-9 request outlives a saturating priority-0 backlog even when
    later rounds only advance the clock and step (no fresh submissions to
    invalidate the plan cache) — the aging re-plan trigger keeps the
    promotions applied.  Zero new compiled programs throughout."""
    svc, clock, stub = sim_service(
        policy=PriorityPolicy(aging_s=2.0), max_slots=4, launch_s=1.0
    )
    starved = svc.submit(sim_request(-1, priority=9))
    # saturating phase: fresh priority-0 bursts keep the queue hot
    for round_ in range(4):
        submit_burst(svc, 4, priorities=(0,), seed0=100 * round_)
        svc.step()
    # quiet phase: the clock runs, steps land, nothing is submitted —
    # the old code would consume the stale cached order here forever
    done_at = None
    for _ in range(30):
        if not svc.pending():
            break
        clock.advance(1.0)
        for rid, _res in svc.step():
            if rid == starved and done_at is None:
                done_at = clock()
    assert done_at is not None, "aged request never launched: starvation"
    assert done_at <= 40.0
    assert svc.stats.completed == 17
    assert len({(l.signature, l.slots) for l in stub.launches}) == 1


def test_aging_replan_disabled_without_aging():
    """aging_s=None (and fifo/edf) must never trip the staleness check —
    the cached plan list survives arbitrary clock advances untouched."""
    for policy in (PriorityPolicy(aging_s=None), "fifo", "edf"):
        svc, clock, stub = sim_service(policy=policy, max_slots=1)
        assert svc._aging_s is None
        submit_burst(svc, 3)
        svc.step()
        cached = svc._plans_cache
        assert cached is not None
        clock.advance(1000.0)
        svc.step()
        assert svc._plans_built_s == 0.0  # never rebuilt
        svc.drain()
        assert svc.stats.completed == 3


# ----------------------------------------------------- deadline accounting
def test_deadline_miss_accounting_exact():
    svc, clock, stub = sim_service(policy="edf", max_slots=1, launch_s=2.0)
    trace = run_script(svc, clock, [
        ("submit", sim_request(0, deadline_s=1.0)),   # misses: done at t=2
        ("submit", sim_request(1, deadline_s=10.0)),  # makes it: done at t=4
        ("submit", sim_request(2)),                   # no deadline: never a miss
        ("drain",),
    ])
    assert svc.stats.deadline_misses == 1
    assert trace.done_at(trace.rids[0]) == 2.0
    assert trace.done_at(trace.rids[1]) == 4.0
    # exact telemetry on the virtual clock: waits 0/2/4, latencies 2/4/6
    assert sorted(svc.stats.wait_samples) == [0.0, 2.0, 4.0]
    assert sorted(svc.stats.latency_samples) == [2.0, 4.0, 6.0]
    assert svc.stats.latency_p(50) == 4.0
    assert svc.stats.wait_p(0) == 0.0
    s = svc.stats.summary()
    assert s["deadline_misses"] == 1 and s["latency_p99_s"] <= 6.0


def test_deadline_met_exactly_at_boundary_is_not_a_miss():
    svc, clock, stub = sim_service(policy="edf", max_slots=1, launch_s=1.0)
    run_script(svc, clock, [
        ("submit", sim_request(0, deadline_s=1.0)), ("drain",),
    ])
    assert svc.stats.deadline_misses == 0  # done at t==deadline: on time


# ------------------------------------------------- interleaving invariants
def test_every_rid_gets_its_own_result_under_interleaving():
    svc, clock, stub = sim_service(policy="priority", max_slots=2)
    ws2 = sim_ws(2, 3, tag="alt")
    events = [
        ("submit", sim_request(10, priority=3)),
        ("step",),
        ("submit", sim_request(11, priority=0, ws=ws2)),
        ("submit", sim_request(12, priority=1)),
        ("advance", 0.5),
        ("submit", sim_request(13, priority=0)),
        ("step",), ("step",),
        ("submit", sim_request(14, priority=2)),
        ("drain",),
    ]
    trace = run_script(svc, clock, events)
    seeds = [10, 11, 12, 13, 14]
    assert sorted(trace.completion_order()) == sorted(trace.rids)
    for rid, seed in zip(trace.rids, seeds):
        res = trace.result(rid)
        assert res.seed == seed  # rid -> its OWN request's result
    assert trace.result(trace.rids[1]).workload_names == ws2.names


def test_launches_partition_the_submitted_rids():
    svc, clock, stub = sim_service(policy="priority", max_slots=3)
    rids = submit_burst(svc, 10, priorities=(2, 0, 1),
                        deadlines_s=(None, 5.0))
    svc.drain()
    flat = [rid for launch in svc.launch_log for rid in launch]
    assert sorted(flat) == sorted(rids)  # every rid exactly once


def test_mid_drain_submit_reuses_warm_slot_size():
    """The slot-hint contract, sim form: a re-plan forced by a mid-drain
    submit rounds the residue UP to the signature's warm slot size
    instead of planning a fresh smaller program shape."""
    svc, clock, stub = sim_service(policy="fifo", max_slots=4)
    submit_burst(svc, 6)
    svc.step()  # 4 launch; plans cached with tail slots=4
    svc.submit(sim_request(50))  # invalidates the plan cache: 3 remain
    svc.step()
    assert [l.slots for l in stub.launches] == [4, 4]
    assert len(stub.launches[1].seeds) == 3  # 3 real in the 4-slot shape
    svc.drain()
    assert svc.stats.completed == 7


def test_policy_never_changes_program_shapes():
    """Same request mix under fifo vs priority vs edf: identical multiset
    of (signature, slots) launches — scheduling reorders, never re-chunks."""
    def launches_for(policy):
        svc, clock, stub = sim_service(policy=policy, max_slots=4)
        submit_burst(svc, 11, priorities=(0, 3, 1), deadlines_s=(4.0, None))
        svc.drain()
        return sorted((l.signature, l.slots) for l in stub.launches)

    fifo = launches_for("fifo")
    assert launches_for("priority") == fifo
    assert launches_for("edf") == fifo


# ---------------------------------------------------------- failure paths
class FlakyEngine(StubEngine):
    """Fails the first ``fail_times`` launches, then behaves."""

    def __init__(self, clock, *, fail_times=1, **kw):
        super().__init__(clock, **kw)
        self.fail_times = fail_times

    def execute(self, plan, *, mesh=None):
        if self.fail_times > 0:
            self.fail_times -= 1
            raise RuntimeError("injected engine failure")
        return super().execute(plan, mesh=mesh)


def test_sync_step_engine_failure_is_retryable():
    """A failed launch must roll the dispatched requests back into the
    queue (original submit stamps intact) — step() raises but nothing is
    lost, and a retry serves everything."""
    clock = VirtualClock()
    svc = DSEService(engine=FlakyEngine(clock, fail_times=1, max_slots=2),
                     clock=clock)
    rids = submit_burst(svc, 3)
    with pytest.raises(RuntimeError, match="injected"):
        svc.step()
    assert svc.pending() == 3  # nothing silently dropped
    assert len(svc.stats.wait_samples) == 0  # failed dispatch not sampled
    out = svc.drain()
    assert set(out) == set(rids)
    assert svc.stats.completed == 3
    assert len(svc.stats.wait_samples) == len(svc.stats.latency_samples) == 3


def test_async_engine_failure_fails_futures_and_keeps_serving():
    """An engine failure fails exactly that plan's futures (done-callbacks
    fire on the exception and may SUBMIT without deadlocking — exceptions
    are set outside the service lock), purges the failed rids'
    bookkeeping, and the worker keeps serving later submissions."""
    from repro.serve.dse import AsyncDSEService

    clock = VirtualClock()
    svc = AsyncDSEService(
        engine=FlakyEngine(clock, fail_times=1, max_slots=2),
        clock=clock, paused=True,
    )
    f1 = svc.submit(sim_request(1))
    f2 = svc.submit(sim_request(2))  # packs with f1: one 2-slot plan
    resubmitted = []

    def resubmit(_fut):  # runs on the worker thread, on the FAILURE
        if not resubmitted:
            resubmitted.append(svc.submit(sim_request(3)))

    f1.add_done_callback(resubmit)
    svc.resume()
    with pytest.raises(RuntimeError, match="injected"):
        f1.result(timeout=30)
    with pytest.raises(RuntimeError, match="injected"):
        f2.result(timeout=30)
    results = svc.drain(timeout=30)  # the callback's resubmission serves
    assert resubmitted and resubmitted[0].result(timeout=30).seed == 3
    assert set(results) == {resubmitted[0].rid}
    st = svc.stats
    assert st.submitted == 3 and st.completed == 1  # failures never served
    assert len(st.wait_samples) == len(st.latency_samples) == 1
    assert not svc.service._submit_s and not svc.service._deadline_s  # no leak
    svc.close()


# ------------------------------------------------------------- misc guards
def test_get_policy_rejects_unknown():
    with pytest.raises(ValueError, match="policy"):
        get_policy("sjf")
    with pytest.raises(ValueError, match="aging_s"):
        PriorityPolicy(aging_s=0.0)
    assert get_policy("edf").name == "edf"
    p = PriorityPolicy(aging_s=1.0)
    assert get_policy(p) is p


def test_empty_step_and_stats_defaults():
    svc, clock, stub = sim_service()
    assert svc.step() == []
    assert svc.stats.requests_per_s() == 0.0
    # empty sample windows report None, not NaN: a fresh service's
    # summary() must serialize to valid JSON (bench rows read it)
    assert svc.stats.wait_p(50) is None
    assert svc.stats.latency_p(99) is None
    s = svc.stats.summary()
    assert s["wait_p50_s"] is None and s["latency_p99_s"] is None
    import json

    assert "NaN" not in json.dumps(s)  # NaN would serialize as bare NaN


def test_service_clock_defaults_are_real_time():
    # the default service still works without any clock injection
    svc = DSEService(engine=StubEngine(VirtualClock(), max_slots=2))
    rid = svc.submit(sim_request(7))
    out = dict(svc.drain())
    assert out[rid].seed == 7
    assert svc.stats.latency_samples and svc.stats.wait_samples
