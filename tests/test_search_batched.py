"""Batched (one-jit, vmapped) search stack == sequential reference paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import space
from repro.core import ga as ga_mod
from repro.core.objectives import OBJECTIVES, OBJECTIVE_WEIGHTS, make_objective, \
    make_weighted_objective
from repro.core.search import (
    batched_search,
    joint_search,
    joint_search_batched,
    run_search,
    seed_population,
    seed_population_batched,
    separate_search,
)
from repro.imc.cost import evaluate_designs
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS = 16, 4


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def test_separate_batched_matches_sequential(ws):
    sb = separate_search(jax.random.PRNGKey(0), ws, pop_size=POP,
                         generations=GENS, batched=True)
    ss = separate_search(jax.random.PRNGKey(0), ws, pop_size=POP,
                         generations=GENS, batched=False)
    for name in ws.names:
        np.testing.assert_allclose(
            np.asarray(sb[name].ga.scores), np.asarray(ss[name].ga.scores),
            rtol=1e-6,
        )
        np.testing.assert_allclose(
            sb[name].top_scores, ss[name].top_scores, rtol=1e-6
        )


def test_multi_seed_batched_matches_sequential(ws):
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(3)])
    batch = joint_search_batched(keys, ws, pop_size=POP, generations=GENS)
    for s in range(3):
        seq = joint_search(jax.random.PRNGKey(s), ws, pop_size=POP,
                           generations=GENS)
        np.testing.assert_allclose(
            np.asarray(batch[s].ga.scores), np.asarray(seq.ga.scores), rtol=1e-6
        )


def test_seed_population_batched_matches(ws):
    keys = jnp.stack([jax.random.PRNGKey(5), jax.random.PRNGKey(6)])
    B = 2
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    pools = seed_population_batched(keys, feats, mask, 8)
    for b in range(B):
        seq = seed_population(keys[b], ws, 8)
        np.testing.assert_array_equal(np.asarray(pools[b]), np.asarray(seq))


def test_share_init_not_consumed(ws):
    """run_ga donates its init buffer, but driver APIs must never consume
    caller-owned arrays (the lm_hw_cosearch example reuses one init)."""
    init = seed_population(jax.random.PRNGKey(0), ws, POP)
    joint_search(jax.random.PRNGKey(1), ws, pop_size=POP, generations=2,
                 init_genomes=init)
    sep = separate_search(jax.random.PRNGKey(2), ws, pop_size=POP,
                          generations=2, share_init=init)
    assert len(sep) == ws.n
    assert np.asarray(init).shape == (POP, space.N_GENES)  # still readable


def test_ga_odd_population(ws):
    """Odd P used to silently drop a tournament parent; now one extra pair
    is drawn and the children truncated, keeping history shapes (G+1, P)."""
    res = joint_search(jax.random.PRNGKey(0), ws, pop_size=15, generations=3)
    assert res.ga.genomes.shape == (4, 15, space.N_GENES)
    assert res.ga.scores.shape == (4, 15)
    conv = res.convergence
    assert (np.diff(conv[np.isfinite(conv)]) <= 1e-6).all()


def test_survivor_selection_matches_argsort():
    """The integer-key survival sort (``ga._survivor_indices``) must pick
    IDENTICAL survivors, in identical order, to the stable float argsort
    it replaced — including duplicate scores (lower index wins), +inf
    infeasibles (sort last) and mixed +-0.0 (equal keys)."""
    rng = np.random.default_rng(0)
    for trial in range(50):
        P = int(rng.integers(1, 40))
        pool = np.array([0.0, -0.0, 1.5, 1.5, np.inf, 3.25, 7.0, 1e30],
                        np.float32)
        alls = rng.choice(pool, size=2 * P).astype(np.float32)
        ref = np.argsort(alls, kind="stable")[:P]
        got = np.asarray(ga_mod._survivor_indices(jnp.asarray(alls), P))
        np.testing.assert_array_equal(got, ref)


def test_ga_jit_cached_across_seeds(ws):
    """Different seeds / same shapes must NOT retrace the GA program."""
    run_search(jax.random.PRNGKey(0), ws, pop_size=8, generations=2)
    n1 = ga_mod._run_ga_jit._cache_size()
    run_search(jax.random.PRNGKey(1), ws, pop_size=8, generations=2)
    assert ga_mod._run_ga_jit._cache_size() == n1


def test_weighted_objective_matches_kinds(ws):
    g = space.random_genomes(jax.random.PRNGKey(0), 64)
    r = evaluate_designs(space.decode(g), ws)
    w_obj = make_weighted_objective(150.0)
    for kind in OBJECTIVES:
        s_ref = np.asarray(make_objective(kind, 150.0)(r))
        s_w = np.asarray(w_obj(r, jnp.asarray(OBJECTIVE_WEIGHTS[kind])))
        np.testing.assert_allclose(s_w, s_ref, rtol=1e-6)


def test_batched_obj_weights_matches_plain(ws):
    """obj_weights path == the string-objective path for 'ela'."""
    keys = jnp.stack([jax.random.PRNGKey(3), jax.random.PRNGKey(4)])
    B = 2
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    plain = batched_search(keys, feats, mask, pop_size=POP, generations=GENS)
    weighted = batched_search(
        keys, feats, mask, pop_size=POP, generations=GENS,
        obj_weights=jnp.tile(jnp.asarray(OBJECTIVE_WEIGHTS["ela"])[None], (B, 1)),
    )
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(weighted[b].ga.scores), np.asarray(plain[b].ga.scores),
            rtol=1e-5,
        )
