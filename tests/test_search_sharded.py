"""Sharded (2-D search x population mesh) search stack == unsharded stack.

The ``@pytest.mark.multidevice`` tests need >=2 devices — run them with
``REPRO_FAKE_DEVICES=8 python -m pytest tests/test_search_sharded.py`` (or
the XLA flag directly; see tests/conftest.py).  Parity is asserted
BIT-IDENTICAL (``assert_array_equal``): sharding is a layout, never a
numerics change.  The unmarked tests cover graceful degradation on a
single-device host, so they also run in the tier-1 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import space
from repro.core.distributed import (
    batch_axes,
    batch_spec,
    place_batched,
    pop_axes,
    shape_spec,
    sharded_batched_eval_fn,
    sharded_batched_search,
    sharded_eval_fn,
    sharded_run_ga_batched,
    sharded_separate_search,
    sharded_seed_population_batched,
)
from repro.core.search import (
    _ctx_eval,
    batched_search,
    make_eval_fn,
    seed_population_batched,
    separate_search,
)
from repro.imc.tech import TECH
from repro.launch.mesh import (
    describe,
    make_mesh,
    make_search_mesh,
    make_test_mesh,
    mesh_axis_sizes,
)
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import pack_workloads

POP, GENS = 16, 3
MESH_LAYOUTS = [(2, 4), (4, 2), (8, 1)]


@pytest.fixture(scope="module")
def ws():
    # 4 CNN workloads with different layer counts -> ragged (W, L) masks
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.ga.scores), np.asarray(b.ga.scores))
    np.testing.assert_array_equal(
        np.asarray(a.ga.best_genome), np.asarray(b.ga.best_genome)
    )
    np.testing.assert_array_equal(a.top_scores, b.top_scores)
    np.testing.assert_array_equal(a.top_genomes, b.top_genomes)


# ------------------------------------------------------------ parity (>=2 dev)
@pytest.mark.multidevice
@pytest.mark.parametrize("searches,pop", MESH_LAYOUTS)
def test_batched_search_sharded_parity(ws, searches, pop):
    mesh = make_search_mesh(searches, pop)
    assert mesh_axis_sizes(mesh) == {"search": searches, "data": pop}
    B = 8
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=POP, generations=GENS)
    sh = batched_search(keys, feats, mask, pop_size=POP, generations=GENS,
                        mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


@pytest.mark.multidevice
def test_batched_search_sharded_parity_odd_pop_and_ragged_batch(ws):
    """Odd population (15) and B (6) not divisible by the search axis: the
    ragged dimensions replicate instead of sharding, scores unchanged."""
    mesh = make_search_mesh(2, 4)
    B = 6
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=15, generations=GENS)
    sh = batched_search(keys, feats, mask, pop_size=15, generations=GENS,
                        mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


@pytest.mark.multidevice
def test_batched_search_sharded_parity_mixed_workload_sets(ws):
    """W>1 ragged-mask sets that DIFFER per batch element (reversed order
    flips which rows are padding)."""
    mesh = make_search_mesh(4, 2)
    rev_feats, rev_mask = ws.feats[::-1], ws.mask[::-1]
    feats = jnp.stack([ws.feats, rev_feats] * 4)  # (8, W, L, 6)
    mask = jnp.stack([ws.mask, rev_mask] * 4)
    keys = jnp.stack([jax.random.PRNGKey(200 + i) for i in range(8)])
    ref = batched_search(keys, feats, mask, pop_size=POP, generations=GENS)
    sh = sharded_batched_search(mesh, keys, feats, mask, pop_size=POP,
                                generations=GENS)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


@pytest.mark.multidevice
@pytest.mark.parametrize("searches,pop", MESH_LAYOUTS)
def test_batched_search_sharded_parity_table_backend(ws, searches, pop):
    """The factorized-table ctx (imc.tables.WorkloadTables leaves) shards
    over the search axis like any other batched leaf — bit-identical to
    the unsharded table path.  (4, 2) joined the envelope when the
    total-order survival sort landed; see
    test_table_backend_sharded_parity_envelope for the history."""
    mesh = make_search_mesh(searches, pop)
    B = 8
    keys = jnp.stack([jax.random.PRNGKey(300 + i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=POP, generations=GENS,
                         backend="table")
    sh = batched_search(keys, feats, mask, pop_size=POP, generations=GENS,
                        backend="table", mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


@pytest.mark.multidevice
@pytest.mark.parametrize("searches,pop", MESH_LAYOUTS)
def test_table_backend_sharded_parity_envelope(ws, searches, pop):
    """Characterization: the table-backend sharded bit-parity envelope.

    History: PR 4's ROADMAP note pinned the envelope at (2,4)/(8,1) and
    documented that a (4,2) mesh with a ragged batch ULP-drifted the
    table eval on the then-current stack (static objective + plain
    argsort survival).  On the CURRENT stack — total-order-key survival
    sort (``ga._survivor_indices``) everywhere — that drift no longer
    reproduces: a 60-config sweep over (4,2) x {ragged B=6/7, odd pop,
    per-element mixed workload sets} x 20 seeds is bit-exact.  A
    strict-xfail on the old drift would therefore XPASS; the truthful
    pin is the WIDE envelope, asserted bit-identical on all three
    layouts at the adversarial shape (ragged B=6, odd pop=15,
    per-element differing ragged-mask sets).  If the drift ever comes
    back — an XLA upgrade re-fusing the table gathers, a survival-sort
    change — this fails loudly, and narrowing the envelope again must
    be a deliberate, documented decision."""
    mesh = make_search_mesh(searches, pop)
    B, P = 6, 15  # B ragged on every layout's search axis; odd population
    rev_feats, rev_mask = ws.feats[::-1], ws.mask[::-1]
    feats = jnp.stack([ws.feats if i % 2 == 0 else rev_feats for i in range(B)])
    mask = jnp.stack([ws.mask if i % 2 == 0 else rev_mask for i in range(B)])
    keys = jnp.stack([jax.random.PRNGKey(700 + i) for i in range(B)])
    ref = batched_search(keys, feats, mask, pop_size=P, generations=GENS,
                         backend="table")
    sh = batched_search(keys, feats, mask, pop_size=P, generations=GENS,
                        backend="table", mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


@pytest.mark.multidevice
@pytest.mark.parametrize("searches,pop", [(4, 2), (2, 4)])
def test_separate_search_sharded_parity(ws, searches, pop):
    mesh = make_search_mesh(searches, pop)
    ref = separate_search(jax.random.PRNGKey(0), ws, pop_size=POP,
                          generations=GENS)
    sh = sharded_separate_search(mesh, jax.random.PRNGKey(0), ws,
                                 pop_size=POP, generations=GENS)
    assert set(ref) == set(sh)
    for name in ws.names:
        _assert_results_equal(ref[name], sh[name])


@pytest.mark.multidevice
def test_seed_population_batched_sharded_parity(ws):
    mesh = make_search_mesh(2, 4)
    B = 4
    keys = jnp.stack([jax.random.PRNGKey(10 + i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = seed_population_batched(keys, feats, mask, 8)
    sh = sharded_seed_population_batched(mesh, keys, feats, mask, 8)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(sh))


@pytest.mark.multidevice
def test_sharded_run_ga_outputs_live_on_the_mesh(ws):
    """The layout proof: committed inputs propagate through the cached GA
    program and the results come back sharded over every mesh device."""
    mesh = make_search_mesh(4, 2)
    B = 8
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    init = seed_population_batched(keys, feats, mask, POP, mesh=mesh)
    ga = sharded_run_ga_batched(
        mesh, keys, _ctx_eval("ela", 150.0, TECH, "jnp"),
        pop_size=POP, generations=GENS, init_genomes=init, ctx=(feats, mask),
    )
    assert len(ga.scores.sharding.device_set) == len(mesh.devices.ravel())
    assert ga.scores.shape == (B, GENS + 1, POP)


@pytest.mark.multidevice
def test_place_batched_layout(ws):
    mesh = make_search_mesh(4, 2)
    x = place_batched(mesh, jnp.zeros((8, 16, 9)), pop_dim=1)
    assert x.sharding.spec == jax.sharding.PartitionSpec(
        ("search",), ("data",), None
    )
    assert len(x.sharding.device_set) == 8
    # ragged dims degrade to replication rather than erroring
    y = place_batched(mesh, jnp.zeros((6, 15, 9)), pop_dim=1)
    assert y.sharding.spec == jax.sharding.PartitionSpec(None, None, None)


@pytest.mark.multidevice
def test_sharded_batched_eval_fn_parity(ws):
    mesh = make_search_mesh(2, 4)
    B = 4
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    genomes = jax.vmap(lambda k: space.random_genomes(k, POP))(keys)
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ev = sharded_batched_eval_fn(mesh, "ela", 150.0)
    base = _ctx_eval("ela", 150.0, TECH, "jnp")
    ref = jax.vmap(lambda g: base(g, (ws.feats, ws.mask)))(genomes)
    np.testing.assert_array_equal(
        np.asarray(ev(genomes, (feats, mask))), np.asarray(ref)
    )


# ----------------------------------------------- degradation (any device count)
def test_make_test_mesh_accepts_search_axis():
    mesh = make_test_mesh(data=2, model=1, search=8)
    sizes = mesh_axis_sizes(mesh)
    assert tuple(sizes) == ("search", "data", "model")
    # degrades down to all-1 axes on a single-device host, never raises
    assert all(s >= 1 for s in sizes.values())
    n = jax.device_count()
    assert int(np.prod(list(sizes.values()))) <= n
    assert sizes["search"] <= 8 and sizes["data"] <= 2 and sizes["model"] == 1
    # historical 2-axis layout is preserved when no search axis is requested
    assert tuple(mesh_axis_sizes(make_test_mesh(1, 1))) == ("data", "model")


def test_make_search_mesh_defaults_and_clamping():
    mesh = make_search_mesh()
    sizes = mesh_axis_sizes(mesh)
    assert tuple(sizes) == ("search", "data")
    assert sizes["search"] == jax.device_count() and sizes["data"] == 1
    assert describe(mesh) == f"search={sizes['search']}xdata=1"
    # oversubscribed requests clamp instead of asserting
    big = make_search_mesh(3 * jax.device_count(), 5)
    bs = mesh_axis_sizes(big)
    assert bs["search"] * bs["data"] <= jax.device_count()


def test_sharded_eval_fn_tolerates_meshes_without_data_axis(ws):
    g = space.random_genomes(jax.random.PRNGKey(0), 32)
    ref = np.asarray(make_eval_fn(ws, "ela", 150.0)(g))
    for axes in [("model",), ("search",)]:
        mesh = make_mesh((1,), axes)
        assert pop_axes(mesh) == ()
        f = sharded_eval_fn(mesh, ws, "ela", 150.0)
        np.testing.assert_array_equal(np.asarray(f(g)), ref)


def test_sharded_eval_fn_odd_population_replicates(ws):
    mesh = make_search_mesh(1, jax.device_count())
    f = sharded_eval_fn(mesh, ws, "ela", 150.0)
    g = space.random_genomes(jax.random.PRNGKey(1), 17)  # prime: never divides
    ref = np.asarray(make_eval_fn(ws, "ela", 150.0)(g))
    np.testing.assert_array_equal(np.asarray(f(g)), ref)


def test_batch_axes_and_specs_degrade():
    m2 = make_test_mesh(1, 1)  # no search axis: batch dim replicates
    assert batch_axes(m2) == ((), ("data",))
    assert batch_spec(m2, 3, pop_dim=1) == jax.sharding.PartitionSpec(
        None, ("data",), None
    )
    m3 = make_mesh((1,), ("model",))  # neither group present
    assert batch_axes(m3) == ((), ())
    assert batch_spec(m3, 2, pop_dim=1) == jax.sharding.PartitionSpec(None, None)
    sm = make_search_mesh(1, 1)
    s_ax, p_ax = batch_axes(sm)
    assert s_ax == ("search",) and p_ax == ("data",)
    # shape_spec never shards a ragged dim
    assert shape_spec(sm, (7, 13, 9), pop_dim=1)[0] in (("search",), None)


def test_batched_search_with_trivial_mesh_parity(ws):
    """mesh= plumbing must be a no-op numerically even at 1 device."""
    mesh = make_search_mesh(1, 1)
    B = 2
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=8, generations=2)
    sh = batched_search(keys, feats, mask, pop_size=8, generations=2, mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


def test_separate_search_mesh_requires_batched(ws):
    with pytest.raises(ValueError, match="batched"):
        separate_search(jax.random.PRNGKey(0), ws, batched=False,
                        mesh=make_search_mesh(1, 1), pop_size=8, generations=1)


# ------------------------------------------------------- fused fast path
@pytest.mark.multidevice
@pytest.mark.parametrize("searches,pop", MESH_LAYOUTS)
def test_batched_search_sharded_fused_parity(ws, searches, pop):
    """Fused x sharded, crossed: the sharded FUSED table run equals the
    unsharded UNFUSED reference bit-for-bit — neither the mesh layout nor
    the fused program shape may move a result bit."""
    mesh = make_search_mesh(searches, pop)
    B = 8
    keys = jnp.stack([jax.random.PRNGKey(900 + i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=POP, generations=GENS,
                         backend="table", fused=False)
    sh = batched_search(keys, feats, mask, pop_size=POP, generations=GENS,
                        backend="table", fused=True, mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


@pytest.mark.multidevice
def test_sharded_direct_seed_parity(ws):
    """The direct table seeder's precomputed CDF is just another placed
    leaf: sharded direct-seed == unsharded direct-seed, bit-identical."""
    from repro.core.engine import SearchEngine

    mesh = make_search_mesh(2, 4)
    B = 8
    keys = jnp.stack([jax.random.PRNGKey(700 + i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    kw = dict(pop_size=POP, generations=GENS, backend="table")
    ref = batched_search(keys, feats, mask,
                         engine=SearchEngine(direct_seed=True, fused=True),
                         **kw)
    sh = batched_search(keys, feats, mask,
                        engine=SearchEngine(direct_seed=True, fused=True,
                                            mesh=mesh),
                        **kw)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


def test_fused_trivial_mesh_parity(ws):
    """Single-device envelope of the fused x mesh cross (tier-1)."""
    mesh = make_search_mesh(1, 1)
    B = 2
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=8, generations=2,
                         backend="table", fused=False)
    sh = batched_search(keys, feats, mask, pop_size=8, generations=2,
                        backend="table", fused=True, mesh=mesh)
    for r, s in zip(ref, sh):
        _assert_results_equal(r, s)


# ------------------------------------------------------ pareto front search
def _assert_pareto_equal(a, b):
    """Pareto results: front membership, (E, L, A) vectors and the
    convergence curve must all be mesh-invariant bit-for-bit."""
    np.testing.assert_array_equal(a.top_scores, b.top_scores)
    np.testing.assert_array_equal(a.top_genomes, b.top_genomes)
    np.testing.assert_array_equal(a.objective_vectors, b.objective_vectors)
    np.testing.assert_array_equal(a.convergence, b.convergence)
    assert a.top_designs == b.top_designs


@pytest.mark.multidevice
@pytest.mark.parametrize("searches,pop", MESH_LAYOUTS)
def test_pareto_search_sharded_parity(ws, searches, pop):
    """NSGA-II front search over the fake-8-device mesh: the in-jit
    non-dominated sort, crowding passes and front epilogue are all plain
    lax ops over placed leaves, so every mesh layout must return the
    meshless front bit-for-bit (table backend, mixed per-element areas)."""
    mesh = make_search_mesh(searches, pop)
    B = 8
    keys = jnp.stack([jax.random.PRNGKey(500 + i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    kw = dict(pop_size=POP, generations=GENS, backend="table",
              objective="pareto", pareto_k=5)
    ref = batched_search(keys, feats, mask, **kw)
    sh = batched_search(keys, feats, mask, mesh=mesh, **kw)
    for r, s in zip(ref, sh):
        _assert_pareto_equal(r, s)


def test_pareto_trivial_mesh_parity(ws):
    """Single-device envelope of the pareto x mesh cross (tier-1)."""
    mesh = make_search_mesh(1, 1)
    B = 2
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    kw = dict(pop_size=8, generations=2, backend="table",
              objective="pareto", pareto_k=4)
    ref = batched_search(keys, feats, mask, **kw)
    sh = batched_search(keys, feats, mask, mesh=mesh, **kw)
    for r, s in zip(ref, sh):
        _assert_pareto_equal(r, s)
