"""Serving engine: continuous batching correctness on a reduced model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import Engine, Request
from repro.serve.steps import greedy_sample


@pytest.fixture(scope="module")
def model():
    cfg = get_config("llama3.2-1b").reduced()
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    """Token-by-token greedy decode via full forward (oracle)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = transformer.forward(
            cfg, params, jnp.asarray([toks], jnp.int32)
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_completes_all_requests(model):
    cfg, params = model
    eng = Engine(cfg, params, slots=3, max_len=64)
    rng = np.random.default_rng(0)
    n = 7
    for rid in range(n):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 10))).astype(np.int32),
            max_new=int(rng.integers(3, 8)),
        ))
    done = eng.run()
    assert len(done) == n
    assert all(r.done and len(r.out) == r.max_new for r in done)


def test_engine_matches_greedy_reference(model):
    """The batched continuous engine must produce exactly the tokens of a
    sequential full-context greedy decode."""
    cfg, params = model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in (5, 9, 7)]
    eng = Engine(cfg, params, slots=2, max_len=64)  # slots < requests
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new=6))
    done = {r.rid: r.out for r in eng.run()}
    for rid, p in enumerate(prompts):
        ref = _greedy_reference(cfg, params, p, 6)
        assert done[rid] == ref, (rid, done[rid], ref)


def test_greedy_sample_shape():
    logits = jnp.zeros((3, 1, 11)).at[:, :, 4].set(1.0)
    s = greedy_sample(logits)
    assert s.shape == (3, 1)
    assert (np.asarray(s) == 4).all()
