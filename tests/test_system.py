"""System-level behaviour: cells lower end-to-end, HLO analysis parses,
roofline terms are sane, launchers run."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_stats, op_census, shape_bytes
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs.base import SHAPES_BY_NAME, ShapeSpec, get_config, list_configs
from repro.launch.cells import all_cells, build_step, input_specs, skipped_cells
from repro.launch.mesh import make_test_mesh


# -------------------------------------------------------------- cells/ skips
def test_cell_enumeration_counts():
    cells = all_cells()
    skips = skipped_cells()
    # 10 archs x 4 shapes = 40; skips are the pure-full-attention long_500k
    assert len(cells) + len(skips) == 40
    assert len(skips) == 7
    long_runners = {c.cfg.name for c in cells if c.shape.name == "long_500k"}
    assert long_runners == {"mamba2-780m", "jamba-v0.1-52b", "mixtral-8x7b"}


def test_input_specs_cover_all_cells():
    for cell in all_cells():
        specs = input_specs(cell.cfg, cell.shape)
        assert specs, cell.name
        for name, s in specs.items():
            assert all(d > 0 for d in s.shape), (cell.name, name)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m", "mixtral-8x7b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_cell_lowers_on_test_mesh(arch, kind):
    """Reduced configs of three families lower for all three step kinds on
    the single-device test mesh (same builder code as the 512-dev dry-run)."""
    cfg = get_config(arch).reduced()
    shape = ShapeSpec("t", 64, 2, kind)
    mesh = make_test_mesh(1, 1)
    bundle = build_step(cfg, shape, mesh)
    jitted = jax.jit(bundle.fn, donate_argnums=bundle.donate_argnums)
    lowered = jitted.lower(*bundle.args)
    assert lowered is not None


# ------------------------------------------------------------- HLO analysis
HLO_SAMPLE = """
HloModule test
ENTRY main {
  p0 = f32[128,256]{1,0} parameter(0)
  ag = f32[128,4096]{1,0} all-gather(p0), dimensions={1}
  ar = f32[128,256]{1,0} all-reduce(p0), to_apply=add
  rs = f32[8,256]{1,0} reduce-scatter(p0), dimensions={0}
  cp = f32[128,256]{1,0} collective-permute(p0), source_target_pairs={{0,1}}
  d = f32[128,128]{1,0} dot(p0, p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT t = (f32[128,4096]{1,0}) tuple(ag)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[2,4]") == 16
    assert shape_bytes("(f32[8], s32[2])") == 40
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("pred[16]") == 16


def test_collective_stats_parses_all_kinds():
    st = collective_stats(HLO_SAMPLE)
    assert st.counts == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "collective-permute": 1,
    }
    assert st.by_kind["all-gather"] == 128 * 4096 * 4
    assert st.by_kind["all-reduce"] == 128 * 256 * 4
    assert st.total_bytes == sum(st.by_kind.values())


def test_op_census():
    c = op_census(HLO_SAMPLE)
    assert c["dot"] == 1 and c["all-gather"] == 1


# ----------------------------------------------------------------- roofline
def test_roofline_bottleneck_selection():
    from repro.analysis.hlo import CollectiveStats

    rf = roofline_terms(
        cell="x", mesh_name="m", chips=256,
        hlo_flops=1e12, hlo_bytes=1e9,
        coll=CollectiveStats(total_bytes=10**12, by_kind={}, counts={}),
        model_flops_global=2.56e14,
    )
    assert rf.bottleneck == "collective"
    assert rf.t_collective == pytest.approx(1e12 / (2 * 50e9))
    assert rf.useful_ratio == pytest.approx(1.0)


def test_model_flops_train_is_6nd():
    cfg = get_config("llama3.2-1b")
    shape = SHAPES_BY_NAME["train_4k"]
    mf = model_flops(cfg, shape)
    n = cfg.param_count()
    tokens = shape.global_batch * shape.seq_len
    assert mf >= 6 * n * tokens  # plus attention term
    assert mf < 6 * n * tokens * 1.5


def test_model_flops_decode_much_smaller_than_prefill():
    cfg = get_config("yi-9b")
    f_pre = model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    f_dec = model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    assert f_dec < f_pre / 10


# ----------------------------------------------------------------- launchers
@pytest.mark.slow
def test_train_launcher_end_to_end(tmp_path):
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "llama3.2-1b", "--d-model", "64", "--layers", "2",
        "--seq", "64", "--batch", "2", "--steps", "6",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3", "--log-every", "2",
    ]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout
