"""Factorized table cost model (imc/tables.py) vs the dense jnp oracle.

The dense ``evaluate_designs_arrays`` path stays the source of truth; the
table path must reproduce it: allclose metrics, identical fits/valid, the
same GA trajectories, and identical top-design grid indices on the paper
CNN set.  (Hypothesis variants live in test_properties.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import space
from repro.core.search import batched_search, make_eval_fn, run_search
from repro.imc.cost import evaluate_designs, evaluate_designs_arrays
from repro.imc.tables import (
    build_tables_arrays,
    build_tables_batched,
    evaluate_genomes_tables,
)
from repro.imc.tech import TECH
from repro.workloads.cnn import PAPER_WORKLOADS, cnn_workload
from repro.workloads.pack import WorkloadSet, pack_workloads

POP, GENS = 16, 4


@pytest.fixture(scope="module")
def ws():
    return pack_workloads([(n, cnn_workload(n)) for n in PAPER_WORKLOADS])


def _assert_result_close(tab, ref, rtol=1e-5):
    np.testing.assert_allclose(tab.energy_pj, ref.energy_pj, rtol=rtol)
    np.testing.assert_allclose(tab.latency_ns, ref.latency_ns, rtol=rtol)
    np.testing.assert_allclose(tab.area_mm2, ref.area_mm2, rtol=rtol)
    np.testing.assert_allclose(tab.util, ref.util, rtol=rtol)
    np.testing.assert_array_equal(np.asarray(tab.fits), np.asarray(ref.fits))
    np.testing.assert_array_equal(np.asarray(tab.valid), np.asarray(ref.valid))


def test_table_eval_matches_dense(ws):
    g = space.random_genomes(jax.random.PRNGKey(0), 512)
    ref = evaluate_designs(space.decode(g), ws)
    tab = evaluate_genomes_tables(g, ws.tables())
    _assert_result_close(tab, ref)


def test_table_eval_ragged_and_fully_masked():
    """Padded (ragged) layer tables and an all-masked workload: the table
    reduction must honor the mask exactly like the dense path."""
    feats = np.zeros((3, 5, 6), np.float32)
    feats[0, :2] = [(196, 1152, 128, 4096, 2048, 1), (49, 512, 64, 1024, 512, 2)]
    feats[1, :5] = [(8, 64, 16, 128, 128, 1)] * 5
    # workload 2: mask entirely False (feats left zero)
    mask = np.zeros((3, 5), bool)
    mask[0, :2] = True
    mask[1, :5] = True
    feats, mask = jnp.asarray(feats), jnp.asarray(mask)

    g = space.random_genomes(jax.random.PRNGKey(1), 128)
    ref = evaluate_designs_arrays(space.decode(g), feats, mask)
    tab = evaluate_genomes_tables(g, build_tables_arrays(feats, mask))
    _assert_result_close(tab, ref)
    # fully-masked workload: no demand, fits everywhere, zero latency
    assert bool(np.asarray(tab.fits)[:, 2].all())
    np.testing.assert_array_equal(np.asarray(tab.latency_ns)[:, 2], 0.0)


def test_table_eval_deep_lm_workload():
    """Layer-depth independence must not cost accuracy: parity on a deep
    LM layer table (the workloads the table path makes free)."""
    from repro.configs.base import get_config
    from repro.workloads.lm import lm_workload

    cfg = get_config("llama3.2-1b")
    ws = pack_workloads([("lm", lm_workload(cfg, mode="decode"))])
    g = space.random_genomes(jax.random.PRNGKey(2), 128)
    ref = evaluate_designs(space.decode(g), ws)
    tab = evaluate_genomes_tables(g, ws.tables())
    _assert_result_close(tab, ref)


def test_build_tables_batched_matches_single(ws):
    B = 3
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    batched = build_tables_batched(feats, mask)
    single = build_tables_arrays(ws.feats, ws.mask)
    for bt, st in zip(batched, single):
        assert bt.shape == (B,) + st.shape
        for b in range(B):
            np.testing.assert_array_equal(np.asarray(bt[b]), np.asarray(st))


def test_workloadset_tables_cached(ws):
    t1 = ws.tables()
    t2 = ws.tables()
    assert t1 is t2  # memoized per tech
    tech2 = TECH._replace(weight_bits=4)
    t3 = ws.tables(tech2)
    assert t3 is not t1
    assert t3 is ws.tables(tech2)


def test_make_eval_fn_table_matches_jnp(ws):
    g = space.random_genomes(jax.random.PRNGKey(3), 256)
    s_ref = np.asarray(make_eval_fn(ws, "ela", 150.0, backend="jnp")(g))
    s_tab = np.asarray(make_eval_fn(ws, "ela", 150.0, backend="table")(g))
    finite = np.isfinite(s_ref)
    np.testing.assert_array_equal(finite, np.isfinite(s_tab))
    np.testing.assert_allclose(s_tab[finite], s_ref[finite], rtol=1e-5)


def test_run_search_table_backend(ws):
    """Sequential driver: the table backend follows the same GA trajectory
    (scores allclose per generation) as the dense oracle."""
    r_ref = run_search(jax.random.PRNGKey(0), ws, pop_size=POP,
                       generations=GENS, backend="jnp")
    r_tab = run_search(jax.random.PRNGKey(0), ws, pop_size=POP,
                       generations=GENS, backend="table")
    np.testing.assert_allclose(
        np.asarray(r_tab.ga.scores), np.asarray(r_ref.ga.scores), rtol=1e-5
    )


def test_batched_search_table_top_designs_match(ws):
    """Acceptance: batched table-backend searches on the four paper CNNs
    follow identical trajectories and pick identical top designs (top-1
    grid indices equal; top-k equal as a set — within-top-k order of
    sub-1e-6-relative near-ties may differ)."""
    B, pop, gens = 3, 32, 6  # big enough that every seed finds feasibles
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    ref = batched_search(keys, feats, mask, pop_size=pop, generations=gens)
    tab = batched_search(keys, feats, mask, pop_size=pop, generations=gens,
                         backend="table")
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(tab[b].ga.scores), np.asarray(ref[b].ga.scores),
            rtol=1e-5,
        )
        i_ref = space.decode_indices_np(ref[b].top_genomes)
        i_tab = space.decode_indices_np(tab[b].top_genomes)
        assert len(i_ref) and len(i_tab)
        np.testing.assert_array_equal(i_tab[0], i_ref[0])  # same best design
        assert {tuple(r) for r in i_tab} == {tuple(r) for r in i_ref}
        np.testing.assert_allclose(
            tab[b].top_scores[0], ref[b].top_scores[0], rtol=1e-5
        )


def test_batched_search_table_obj_weights(ws):
    """Weighted-objective ctx carries tables + weights."""
    B = 2
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
    feats = jnp.broadcast_to(ws.feats[None], (B,) + ws.feats.shape)
    mask = jnp.broadcast_to(ws.mask[None], (B,) + ws.mask.shape)
    w = jnp.tile(jnp.asarray([1.0, 1.0, 1.0])[None], (B, 1))
    plain = batched_search(keys, feats, mask, pop_size=POP, generations=GENS,
                           backend="table")
    weighted = batched_search(keys, feats, mask, pop_size=POP,
                              generations=GENS, backend="table", obj_weights=w)
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(weighted[b].ga.scores), np.asarray(plain[b].ga.scores),
            rtol=1e-5,
        )


def test_top_unique_vectorized_semantics():
    """The np.unique fast path keeps the old loop's contract: best-first,
    unique in grid-index space, truncated at non-finite scores."""
    from repro.core.search import _top_unique

    idx = np.array([[2, 1, 0, 3, 4, 0, 1, 2, 5],
                    [0, 0, 0, 0, 0, 0, 0, 0, 0]])
    g_a = space.genome_from_indices(idx[[0]])[0]
    g_b = space.genome_from_indices(idx[[1]])[0]
    genomes = np.stack([g_a, g_b, g_a, g_b], axis=0).astype(np.float32)
    scores = np.array([3.0, 1.0, 2.0, np.inf], np.float32)
    top_g, top_s = _top_unique(genomes, scores, 10)
    # duplicates of a collapse to its best occurrence; inf dropped
    np.testing.assert_array_equal(top_s, [1.0, 2.0])
    np.testing.assert_array_equal(
        space.decode_indices_np(top_g), idx[[1, 0]]
    )
    # k truncation
    _, s1 = _top_unique(genomes, scores, 1)
    np.testing.assert_array_equal(s1, [1.0])
