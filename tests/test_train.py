"""Training semantics: chunked loss == naive loss, accumulation equivalence,
loss decreases, schedule/clip/optimizer unit behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec, get_config
from repro.launch.cells import make_inputs
from repro.models import transformer
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.train.step import chunked_softmax_xent, loss_fn, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = transformer.init(cfg, key)
    batch = make_inputs(cfg, ShapeSpec("t", 32, 4, "train"), key)
    return cfg, params, batch


def test_chunked_xent_equals_naive(setup):
    cfg, params, batch = setup
    hidden, _ = transformer.forward(
        cfg, params, batch["inputs"], return_hidden=True
    )
    w = transformer.head_weight(cfg, params)
    for chunk in (8, 16, 32):
        x_chunked = chunked_softmax_xent(hidden, w, batch["targets"], chunk=chunk)
        logits = (hidden @ w.astype(hidden.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["targets"][..., None], -1)[..., 0]
        naive = (logz - gold).mean()
        # chunked path accumulates the bf16 head matmul in f32 on the MXU
        # (preferred_element_type) vs the naive bf16 output — tiny rounding gap
        np.testing.assert_allclose(float(x_chunked), float(naive), rtol=2e-4)


def test_chunked_xent_gradient_matches(setup):
    cfg, params, batch = setup

    def loss_chunked(p):
        return loss_fn(cfg, p, batch, loss_chunk=8)[0]

    def loss_naive(p):
        logits, aux = transformer.forward(cfg, p, batch["inputs"])
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["targets"][..., None], -1)[..., 0]
        return (logz - gold).mean() + 0.01 * aux

    g1 = jax.grad(loss_chunked)(params)
    g2 = jax.grad(loss_naive)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_accumulation_equivalence(setup):
    """accum=2 must give (numerically) the same update as accum=1."""
    cfg, params, batch = setup
    opt = adamw_init(params)
    s1 = make_train_step(cfg, total_steps=10, accum=1)
    s2 = make_train_step(cfg, total_steps=10, accum=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params), batch)
    worst = max(
        float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert worst < 5e-4, worst


def test_loss_decreases(setup):
    cfg, params, batch = setup
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, total_steps=30, warmup_steps=2))
    opt = adamw_init(params)
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, batch)  # same batch: must overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), peak_lr=1.0,
                                 warmup_steps=10, total_steps=100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6  # peak at end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)  # min_ratio floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # decays


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(10.0)
    from repro.optim import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_decoupled_weight_decay():
    p = {"w": jnp.ones((2,))}
    g = {"w": jnp.zeros((2,))}
    st = adamw_init(p)
    new_p, _ = adamw_update(g, st, p, lr=jnp.asarray(0.1), weight_decay=0.5)
    # zero grad: update = -lr * wd * p
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 0.05, rtol=1e-5)


def test_data_pipeline_determinism_and_signal():
    from repro.data.pipeline import SyntheticLM, make_batch_fn

    src = make_batch_fn(1000, 64, 4, seed=3)
    b1, b2 = src(7), src(7)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert not np.array_equal(src(7)["inputs"], src(8)["inputs"])
    # targets are inputs shifted by one (LM objective)
    np.testing.assert_array_equal(b1["inputs"][:, 1:], b1["targets"][:, :-1])


def test_prefetch_iter_order():
    from repro.data.pipeline import prefetch_iter

    it = prefetch_iter(lambda s: {"x": np.asarray([s])}, start_step=5)
    got = [next(it)[0] for _ in range(4)]
    assert got == [5, 6, 7, 8]
