"""CI regression gate: the fused fast path must outrun the unfused table
row.

Reads ``experiments/search_throughput.json`` (as written by the
bench-smoke / perf-smoke legs just before this runs) and fails when the
``fused`` row's warm designs/s fell below the ``table`` row's separate
config — the fused generation step plus direct seeding exists ONLY as a
speedup over that baseline, so "slower than unfused" is a regression by
definition, whatever the absolute host speed.  Comparing two rows
measured on the SAME host in the SAME job keeps the gate meaningful on
throttled CI runners where an absolute designs/s floor would flake.

Exit 0 with a one-line verdict, exit 1 with both numbers on regression.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

EXP = Path(__file__).resolve().parents[1] / "experiments"


def main() -> int:
    path = EXP / "search_throughput.json"
    if not path.exists():
        print(f"[fused-gate] {path} missing — run the bench first")
        return 1
    data = json.loads(path.read_text())
    fused = data.get("fused", {}).get("designs_per_s")
    table = data.get("table", {}).get("separate", {}).get("designs_per_s")
    if fused is None or table is None:
        print("[fused-gate] need both 'fused' and 'table' rows recorded "
              f"(have fused={fused is not None}, table={table is not None})")
        return 1
    if fused < table:
        print(f"[fused-gate] REGRESSION: fused warm {fused:,.0f} designs/s "
              f"< unfused table row {table:,.0f} designs/s")
        return 1
    print(f"[fused-gate] ok: fused warm {fused:,.0f} designs/s >= "
          f"unfused table row {table:,.0f} designs/s "
          f"({fused / table:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
