"""CI regression gate: the fused fast path must outrun the unfused table
row, and the pipelined transfer-thin path must not fall behind fused.

Reads ``experiments/search_throughput.json`` (as written by the
bench-smoke / perf-smoke legs just before this runs) and fails when

  * the ``fused`` row's warm designs/s fell below the ``table`` row's
    separate config — the fused generation step plus direct seeding
    exists ONLY as a speedup over that baseline, so "slower than
    unfused" is a regression by definition, whatever the absolute host
    speed; or
  * a recorded ``pipelined`` row fell below the ``fused`` row on the
    same B=seeds x W separate/table configuration — the on-device top-k
    epilogue exists to remove host transfer, never to cost throughput;
    or
  * the pipelined row's ``transfer_reduction_x`` (history bytes/launch
    over thin bytes/launch, measured in the same job) dropped under
    10x — the transfer-thin contract itself.

With ``--cache`` the gate instead checks the ``cache`` row's
``pipelined_resubmit`` record (written by ``bench_dse_service --cache``):
a pipelined engine's thin full results must populate the result cache,
so the identical resubmitted mix drains with ZERO new GA launches and a
positive hit rate — the ISSUE-10 thin-result caching fix.  The
``cache-smoke`` CI leg runs this mode right after recording the row.

Comparing rows measured on the SAME host in the SAME job keeps the gate
meaningful on throttled CI runners where an absolute designs/s floor
would flake.  The pipelined checks only engage when the row exists, so
legs that record just fused/table keep their original gate.

Exit 0 with one-line verdicts, exit 1 with both numbers on regression.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

EXP = Path(__file__).resolve().parents[1] / "experiments"

MIN_TRANSFER_REDUCTION_X = 10.0


def check_cache(data: dict) -> int:
    """The pipelined/cache gate: thin-result caching keeps resubmits free."""
    row = data.get("cache")
    if row is None:
        print("[fused-gate] --cache: no 'cache' row recorded — run "
              "bench_dse_service --cache first")
        return 1
    sub = row.get("pipelined_resubmit")
    if sub is None:
        print("[fused-gate] --cache: 'cache' row predates the pipelined-"
              "resubmit record — re-run bench_dse_service --cache")
        return 1
    launches = sub.get("new_launches")
    hit_rate = sub.get("hit_rate")
    if launches is None or hit_rate is None:
        print(f"[fused-gate] --cache: incomplete pipelined_resubmit record "
              f"(new_launches={launches}, hit_rate={hit_rate})")
        return 1
    if launches != 0:
        print(f"[fused-gate] REGRESSION: pipelined resubmit launched "
              f"{launches} new GA runs (thin results not cached?) over "
              f"{sub.get('requests')} requests")
        return 1
    if hit_rate <= 0:
        print(f"[fused-gate] REGRESSION: pipelined resubmit hit rate "
              f"{hit_rate} (cache never hit)")
        return 1
    print(f"[fused-gate] ok: pipelined resubmit x{sub.get('requests')} "
          f"drained with 0 new launches, hit rate {hit_rate:.2f}")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = EXP / "search_throughput.json"
    if not path.exists():
        print(f"[fused-gate] {path} missing — run the bench first")
        return 1
    data = json.loads(path.read_text())
    if "--cache" in argv:
        return check_cache(data)
    fused = data.get("fused", {}).get("designs_per_s")
    table = data.get("table", {}).get("separate", {}).get("designs_per_s")
    if fused is None or table is None:
        print("[fused-gate] need both 'fused' and 'table' rows recorded "
              f"(have fused={fused is not None}, table={table is not None})")
        return 1
    if fused < table:
        print(f"[fused-gate] REGRESSION: fused warm {fused:,.0f} designs/s "
              f"< unfused table row {table:,.0f} designs/s")
        return 1
    print(f"[fused-gate] ok: fused warm {fused:,.0f} designs/s >= "
          f"unfused table row {table:,.0f} designs/s "
          f"({fused / table:.2f}x)")

    pipe = data.get("pipelined")
    if pipe is None:
        return 0
    pipe_dps = pipe.get("designs_per_s")
    red = pipe.get("transfer_reduction_x")
    if pipe_dps is None or red is None:
        print("[fused-gate] 'pipelined' row present but incomplete "
              f"(designs_per_s={pipe_dps}, transfer_reduction_x={red})")
        return 1
    if pipe_dps < fused:
        print(f"[fused-gate] REGRESSION: pipelined warm {pipe_dps:,.0f} "
              f"designs/s < fused row {fused:,.0f} designs/s")
        return 1
    if red < MIN_TRANSFER_REDUCTION_X:
        print(f"[fused-gate] REGRESSION: pipelined transfer reduction "
              f"{red:.1f}x < {MIN_TRANSFER_REDUCTION_X:.0f}x "
              f"({pipe.get('transfer_bytes_per_launch', 0):,.0f} B/launch "
              f"thin vs {pipe.get('history_transfer_bytes_per_launch', 0):,.0f}"
              f" B/launch history)")
        return 1
    print(f"[fused-gate] ok: pipelined warm {pipe_dps:,.0f} designs/s >= "
          f"fused row ({pipe_dps / fused:.2f}x), transfer "
          f"{red:.1f}x thinner than history sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
