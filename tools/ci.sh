#!/usr/bin/env bash
# Tier-1 gate + benchmark smoke, as run by .github/workflows/ci.yml:
#   bash tools/ci.sh               # tier-1 on the host's real device set
#   bash tools/ci.sh multidevice   # tier-1 + sharding tests + sharded bench
#                                  # row on a fake 8-device host
#   bash tools/ci.sh bench-smoke   # tiny search-throughput run per backend;
#                                  # appends the 'table', 'service',
#                                  # 'fused' and 'pipelined' rows of
#                                  # experiments/search_throughput.json so
#                                  # the perf trajectory is recorded per
#                                  # PR, then FAILS if the fused fast
#                                  # path's warm designs/s fell below the
#                                  # unfused table row (the fusion must
#                                  # never regress into a slowdown), if
#                                  # the pipelined row fell below fused,
#                                  # or if its host-transfer reduction
#                                  # dropped under 10x
#   bash tools/ci.sh perf-smoke    # fused-path gate: the fused/kernel/
#                                  # seeder/grid parity suite plus the
#                                  # pipelined-engine parity suite
#                                  # (tests/test_pipelined.py), a quick
#                                  # fused bench row and a pipelined row,
#                                  # and the fused>=table /
#                                  # pipelined>=fused / 10x-transfer
#                                  # regression gates
#   bash tools/ci.sh serve-smoke   # DSE-service smoke, three legs: sync
#                                  # fifo (~32 mixed requests, all results
#                                  # finite), sync EDF (launch order ==
#                                  # earliest-absolute-deadline-first on a
#                                  # mixed-deadline paper_request_mix) and
#                                  # async priority (mixed-priority mix
#                                  # through AsyncDSEService, futures all
#                                  # finite) — plus the virtual-clock
#                                  # scheduler-sim suite
#   bash tools/ci.sh fault-smoke   # anytime fault-tolerance gate: the
#                                  # segmented-GA parity + checkpoint/
#                                  # resume suite, the fault-injection
#                                  # sim suite (retry/backoff/quarantine/
#                                  # partials on the virtual clock), and
#                                  # the retry lane recovering injected
#                                  # chunk faults over the REAL engine
#   bash tools/ci.sh cache-smoke   # result-cache gate: the request_key /
#                                  # plan_key / LRU / disk-tier /
#                                  # streaming test suite, then an
#                                  # identical paper mix resubmitted
#                                  # through a cache-armed service (sync
#                                  # + async + PIPELINED thin-result
#                                  # engine) — zero new GA launches,
#                                  # bit-identical results; records the
#                                  # 'cache' row and gates its pipelined-
#                                  # resubmit record (launches == 0)
#   bash tools/ci.sh pareto-smoke  # Pareto-front gate: the NSGA-II
#                                  # numpy-oracle parity suite
#                                  # (tests/test_pareto.py) and a quick
#                                  # pareto bench recording the 'pareto'
#                                  # row of search_throughput.json
#
# The scheduler-sim suite (tests/test_scheduler_sim.py) is part of the
# plain pytest run, so it executes in BOTH the tier-1 (1-device) and
# multidevice (fake-8-device) jobs — the harness is device-count-free.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "multidevice" ]]; then
  # fake 8 XLA host devices so the @pytest.mark.multidevice sharding tests
  # (tests/test_search_sharded.py, tests/test_engine.py) actually exercise
  # the 2-D mesh on CPU CI
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
  python -m pytest -x -q
  python -m benchmarks.bench_search_throughput --quick --mesh 2x4
elif [[ "${1:-}" == "bench-smoke" ]]; then
  python -m benchmarks.bench_search_throughput --quick
  python -m benchmarks.bench_search_throughput --quick --backend table
  python -m benchmarks.bench_search_throughput --quick --fused --grid-density 1,2
  python -m benchmarks.bench_search_throughput --quick --pipelined
  python -m benchmarks.bench_search_throughput --quick --pareto
  python -m benchmarks.bench_dse_service --quick
  python -m tools.check_fused_gate
elif [[ "${1:-}" == "perf-smoke" ]]; then
  python -m pytest -x -q tests/test_fused_gen.py tests/test_pipelined.py
  python -m benchmarks.bench_search_throughput --quick --fused --grid-density 1,2
  python -m benchmarks.bench_search_throughput --quick --pipelined
  python -m tools.check_fused_gate
elif [[ "${1:-}" == "serve-smoke" ]]; then
  python -m pytest -x -q tests/test_scheduler_sim.py
  python -m benchmarks.bench_dse_service --smoke
elif [[ "${1:-}" == "fault-smoke" ]]; then
  python -m pytest -x -q tests/test_fault_sim.py tests/test_ga_segments.py
  python -m benchmarks.bench_dse_service --fault-smoke
elif [[ "${1:-}" == "cache-smoke" ]]; then
  python -m pytest -x -q tests/test_result_cache.py
  python -m benchmarks.bench_dse_service --cache-smoke
  python -m benchmarks.bench_dse_service --cache --quick
  python -m tools.check_fused_gate --cache
elif [[ "${1:-}" == "pareto-smoke" ]]; then
  python -m pytest -x -q tests/test_pareto.py
  python -m benchmarks.bench_search_throughput --quick --pareto
else
  python -m pytest -x -q
  python -m benchmarks.run --quick
fi
