#!/usr/bin/env bash
# Tier-1 gate + benchmark smoke, as run by .github/workflows/ci.yml:
#   bash tools/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.run --quick
