"""Assemble the data-driven sections of EXPERIMENTS.md from experiments/*.json.

    PYTHONPATH=src python tools/make_report.py > /tmp/report_sections.md
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
EXP = ROOT / "experiments"
SINGLE = "data=16xmodel=16"
MULTI = "pod=2xdata=16xmodel=16"


def load(mesh):
    recs = []
    d = EXP / "dryrun" / mesh
    if d.exists():
        for p in sorted(d.glob("*.json")):
            if p.name.startswith("paper-dse"):
                continue
            recs.append(json.loads(p.read_text()))
    return recs


def dryrun_table(mesh):
    rows = [
        "| cell | chips | fits | mem/dev (GiB) | FLOPs/dev | bytes/dev | coll bytes/dev | compile (s) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        fits = "yes" if r["memory"]["per_device_gb"] <= 16.0 else f"**{r['memory']['per_device_gb']:.0f}G**"
        rows.append(
            f"| {r['cell']} | {r['chips']} | {fits} | {r['memory']['per_device_gb']:.2f} "
            f"| {r['cost']['flops_per_device']:.2e} | {r['cost']['bytes_per_device']:.2e} "
            f"| {r['collectives']['total_bytes']:.2e} | {r['compile_s']:.0f} |"
        )
    return "\n".join(rows)


def roofline_table():
    rows = [
        "| cell | t_compute (ms) | t_memory (ms) | t_coll (ms) | bottleneck | useful 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in load(SINGLE):
        rf = r["roofline"]
        rows.append(
            f"| {r['cell']} | {rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} "
            f"| {rf['t_collective_s']*1e3:.2f} | {rf['bottleneck']} "
            f"| {rf['useful_ratio']:.2f} | {rf['peak_fraction']:.1%} |"
        )
    return "\n".join(rows)


def fig2_summary():
    p = EXP / "fig2_joint_vs_separate.json"
    if not p.exists():
        return "(run benchmarks first)"
    d = json.loads(p.read_text())
    lines = []
    for s in d["seeds"]:
        imp = s["joint_vs_largest_improvement"]
        fails = s["separate_failed_frac"]
        lines.append(
            f"- seed {s['seed']}: joint best {s['joint_top10'][0]:.3g}; "
            f"separate failed-design %: "
            + ", ".join(f"{k} {v:.0%}" for k, v in fails.items())
            + "; joint-vs-vgg16-chip improvement: "
            + ", ".join(
                f"{k} {'fail' if v is None or v != v else f'{v:.0%}'}"
                for k, v in imp.items()
            )
        )
    return "\n".join(lines)


def fig3_summary():
    p = EXP / "fig3_generalization.json"
    if not p.exists():
        return "(run benchmarks first)"
    d = json.loads(p.read_text())
    rows = ["| objective | joint best | generalization loss per workload |", "|---|---|---|"]
    for obj, e in d.items():
        loss = ", ".join(f"{k} {v:.0%}" for k, v in e["generalization_loss"].items())
        rows.append(f"| {obj} | {e['joint_best']:.3g} | {loss} |")
    return "\n".join(rows)


def throughput_summary():
    p = EXP / "throughput.json"
    if not p.exists():
        return "(run benchmarks first)"
    d = json.loads(p.read_text())
    lines = []
    for e in d["eval"]:
        lines.append(
            f"- pop {e['pop']}: {e['designs_per_s']:.0f} designs/s "
            f"({e['speedup_vs_paper']:.0f}x the paper's 1/36 s^-1)"
        )
    for e in d["ga"]:
        lines.append(
            f"- full GA P={e['pop']} G={e['gens']}: {e['s']:.2f}s "
            f"(paper: ~14,400s on 64 cores)"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod (16x16 = 256 chips)\n")
        print(dryrun_table(SINGLE))
        print("\n### multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(MULTI))
    if which in ("all", "roofline"):
        print("\n### roofline\n")
        print(roofline_table())
    if which in ("all", "paper"):
        print("\n### fig2\n")
        print(fig2_summary())
        print("\n### fig3\n")
        print(fig3_summary())
        print("\n### throughput\n")
        print(throughput_summary())
